//! Conformance suite for the paged KV store and the radix-tree prefix
//! cache (the PR-8 tentpole), on top of the per-store unit tests in
//! `model/kv.rs` / `model/prefix.rs`:
//!
//! - **paged == contiguous**: token streams through the real serving
//!   stack are bit-identical between the contiguous slab and the paged
//!   pool, across prefill chunk {1,16} × pool width {1,2,8} × NUMA
//!   {off,auto} × FaultPlan {off,healing} — and across page sizes,
//!   including non-divisors of the context and pages larger than it;
//! - **shared-prefix == cold-prefill**: a prompt admitted against cached
//!   prefix pages produces exactly the stream a cold prefill would;
//! - **prefix hits skip work**: a prefix-hit admission never feeds the
//!   shared span, so it builds zero LUTs for it (`DecodeStats` delta);
//! - **COW faults stay contained**: an injected KV fault on the write
//!   that would copy a shared page finishes only that request
//!   `EngineFault`; the shared original is never mutated (survivors and
//!   later re-users stay bit-identical) and page refcounts balance once
//!   the faulted slot resets;
//! - the batcher's split clamp: a cached prefix covering the whole
//!   context window still leaves one feedable position for an over-long
//!   prompt, which finishes `ContextFull` exactly like a cold run.

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use sail::coordinator::{Batcher, BatcherConfig, FinishReason, Request};
use sail::model::{DecodeItem, DecodeSpec, DecodeStats, KvCacheSpec, KvRuntimeConfig, LutTransformer};
use sail::runtime::{FaultKind, FaultPlan, NumaPolicy, WorkerPool};

use common::engine_with_kv;

const PAGE_TOKENS: usize = 4;

fn spec() -> DecodeSpec {
    common::tiny_spec(2, KvCacheSpec::q8())
}

/// The shared 8-token system prompt: exactly two whole pages at the
/// suite's page size, so a full-head hit maps both and the re-run of the
/// head's last token lands inside a shared page (the COW path).
fn head() -> Vec<i32> {
    (2..10).collect()
}

/// Six requests sharing [`head`] with distinct 1–3 token tails and 4–6
/// token budgets — enough to cycle every slot of a 3-wide batcher through
/// prefix-hit admission, and short enough (max pos 16 < 24) that
/// `ContextFull` is unreachable.
fn requests() -> Vec<Request> {
    (0..6u64)
        .map(|id| {
            let mut prompt = head();
            prompt.extend((0..1 + id as i32 % 3).map(|p| 20 + id as i32 + p));
            Request::new(id, prompt, 4 + id as usize % 3)
        })
        .collect()
}

fn collect(done: Vec<sail::coordinator::Response>) -> BTreeMap<u64, (Vec<i32>, FinishReason)> {
    done.into_iter().map(|r| (r.id, (r.tokens, r.finish))).collect()
}

/// Serve [`requests`] to completion on a fresh engine with the given KV
/// store, pool shape, prefill chunk, and (optionally) an armed fault
/// plan.
fn serve(
    kv: KvRuntimeConfig,
    width: usize,
    policy: &NumaPolicy,
    chunk: usize,
    plan: Option<Arc<FaultPlan>>,
) -> BTreeMap<u64, (Vec<i32>, FinishReason)> {
    let pool = Arc::new(WorkerPool::with_policy(width, policy));
    if let Some(p) = &plan {
        pool.arm_faults(Arc::clone(p));
    }
    let engine = engine_with_kv(spec(), 3, Arc::clone(&pool), kv);
    let mut b =
        Batcher::new(engine, BatcherConfig { prefill_chunk: chunk, ..BatcherConfig::default() });
    for r in requests() {
        b.submit(r);
    }
    let done = b.run_to_completion().unwrap();
    pool.disarm_faults();
    collect(done)
}

/// Pool-level faults only (worker deaths, slow tiles, poisoned scratch):
/// the kinds that must heal bit-identically. No KV faults — every
/// request finishes clean under this plan.
fn healing_plan() -> Arc<FaultPlan> {
    common::healing_plan(4242)
}

fn total_luts(s: &DecodeStats) -> u64 {
    s.layers.iter().map(|l| l.total().luts_built).sum::<u64>() + s.head.luts_built
}

#[test]
fn paged_matches_contiguous_across_chunk_width_numa_and_healing_faults() {
    // One contiguous oracle; every paged leg of the acceptance matrix
    // must reproduce its streams bit-for-bit. The paged legs run with
    // the prefix cache on and a shared-head workload, so page sharing,
    // COW rewrites of the split position, and (on the healing legs)
    // worker deaths are all active while the streams must not move.
    let want = serve(KvRuntimeConfig::contiguous(), 1, &NumaPolicy::Off, 1, None);
    assert!(want.values().all(|(t, f)| !t.is_empty() && *f == FinishReason::MaxTokens));
    for chunk in [1usize, 16] {
        for width in [1usize, 2, 8] {
            for policy in [NumaPolicy::Off, NumaPolicy::Auto] {
                for faults in [None, Some(healing_plan())] {
                    let leg = format!(
                        "chunk {chunk} width {width} numa {policy} faults {}",
                        faults.is_some()
                    );
                    let got =
                        serve(KvRuntimeConfig::paged(PAGE_TOKENS), width, &policy, chunk, faults);
                    assert_eq!(got, want, "paged run diverged from contiguous ({leg})");
                }
            }
        }
    }
}

#[test]
fn page_size_sweep_is_bit_identical_to_contiguous() {
    // Page sizes that divide the 24-token context, ones that don't, one
    // token per page, and a page larger than the whole window: the
    // layout arithmetic changes completely, the tokens must not.
    let want = serve(KvRuntimeConfig::contiguous(), 2, &NumaPolicy::Off, 1, None);
    for pt in [1usize, 3, 5, 16, 64] {
        let got = serve(KvRuntimeConfig::paged(pt), 2, &NumaPolicy::Off, 1, None);
        assert_eq!(got, want, "paged:{pt} diverged from contiguous");
    }
}

#[test]
fn shared_prefix_admission_matches_cold_prefill() {
    // Warm: one engine serves request A (caching its head pages at
    // prefill completion), then request B sharing the head. Cold: a
    // fresh engine serves only B. The streams must match exactly —
    // attaching cached pages and re-running the split token is
    // indistinguishable from prefilling the whole prompt.
    let mut b_prompt = head();
    b_prompt.extend([40, 41, 42]);
    let warm = {
        let pool = WorkerPool::shared(2);
        let engine = engine_with_kv(spec(), 2, pool, KvRuntimeConfig::paged(PAGE_TOKENS));
        let mut b = Batcher::new(engine, BatcherConfig::default());
        b.submit(Request::new(0, head(), 4));
        b.run_to_completion().unwrap();
        b.submit(Request::new(1, b_prompt.clone(), 5));
        let done = b.run_to_completion().unwrap();
        let kv = b.engine().model().kv_metrics().unwrap();
        assert!(kv.prefix_hits >= 1, "second admission never hit the cached head");
        collect(done)
    };
    let cold = {
        let pool = WorkerPool::shared(2);
        let engine = engine_with_kv(spec(), 2, pool, KvRuntimeConfig::paged(PAGE_TOKENS));
        let mut b = Batcher::new(engine, BatcherConfig::default());
        b.submit(Request::new(1, b_prompt, 5));
        collect(b.run_to_completion().unwrap())
    };
    assert_eq!(warm[&1], cold[&1], "prefix-hit stream diverged from cold prefill");
}

#[test]
fn prefix_hit_admission_builds_no_luts_for_the_shared_span() {
    // The "skip prefill entirely" acceptance bar, in kernel-counter
    // terms: the same 8-token prompt served twice. Run 1 is cold and
    // feeds all 8 prompt positions; run 2 attaches the cached pages at
    // split 7 (= min(matched, len−1)) and feeds exactly one. At prefill
    // chunk 1 with a single slot, every fed token is one forward with a
    // constant number of LUT builds, so the second run's build count
    // must drop in exact proportion to the tokens it skipped.
    let pool = WorkerPool::shared(1);
    let engine = engine_with_kv(spec(), 1, pool, KvRuntimeConfig::paged(PAGE_TOKENS));
    let mut b =
        Batcher::new(engine, BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() });

    b.submit(Request::new(0, head(), 4));
    let first = collect(b.run_to_completion().unwrap());
    let cold_tokens = b.engine().stats().tokens;
    let cold_luts = total_luts(b.engine().stats());
    // Cold: 8 prompt positions + 3 more decode steps (the last prefill
    // forward samples the first token).
    assert_eq!(cold_tokens, 8 + 4 - 1);
    assert_eq!(cold_luts % cold_tokens, 0, "builds per forward are not constant");
    let luts_per_token = cold_luts / cold_tokens;

    b.submit(Request::new(1, head(), 4));
    let second = collect(b.run_to_completion().unwrap());
    let warm_tokens = b.engine().stats().tokens - cold_tokens;
    let warm_luts = total_luts(b.engine().stats()) - cold_luts;
    // Warm: split 7 skips 7 of the 8 prompt positions.
    assert_eq!(warm_tokens, cold_tokens - 7, "prefix hit did not skip the shared span");
    assert_eq!(
        warm_luts,
        luts_per_token * warm_tokens,
        "prefix-hit admission built LUTs for the shared span"
    );
    assert_eq!(second[&1], first[&0], "identical prompts must stream identically");
    let kv = b.engine().model().kv_metrics().unwrap();
    assert_eq!((kv.prefix_hits, kv.prefix_misses), (1, 1));
}

#[test]
fn cow_faults_leave_the_shared_original_untouched_and_refcounts_balance() {
    // Transformer-level precision test: slot 1 attaches the cached head
    // and its first write lands at position 7 — inside shared page 1, so
    // it must copy-on-write. Both KV fault kinds are injected on exactly
    // that write. The store's validation-first ordering means the failed
    // COW publishes nothing: slot 0 (mapping the original) keeps
    // decoding bit-identically, the healed retry reproduces the
    // fault-free logits, and resetting the slots leaves exactly the
    // tree-retained pages in use.
    let h = head();
    for kind in [FaultKind::KvWriteFail, FaultKind::KvCorrupt] {
        let run = |plan: Option<Arc<FaultPlan>>| -> (Vec<i32>, Vec<i32>) {
            let pool = WorkerPool::shared(2);
            let mut m = LutTransformer::random_with_kv(
                spec(),
                common::SEED,
                2,
                Arc::clone(&pool),
                KvRuntimeConfig::paged(PAGE_TOKENS),
            )
            .unwrap();
            for (pos, &t) in h.iter().enumerate() {
                m.step(&[DecodeItem { slot: 0, token: t, pos }]).unwrap();
            }
            m.prefix_insert(0, &h).unwrap();
            assert_eq!(m.prefix_attach(1, &h).unwrap(), 7);
            if let Some(p) = plan {
                pool.arm_faults(p);
                let err =
                    m.step(&[DecodeItem { slot: 1, token: h[7], pos: 7 }]).unwrap_err();
                pool.disarm_faults();
                assert!(!err.to_string().is_empty());
                // Heal: the reset releases slot 1's shared references
                // and clears any latched fault; a fresh attach hits the
                // (intact) cached head again.
                m.reset_slot(1).unwrap();
                assert_eq!(m.prefix_attach(1, &h).unwrap(), 7);
            }
            // The COW write (fault-free here, or the healed retry).
            m.step(&[DecodeItem { slot: 1, token: h[7], pos: 7 }]).unwrap();
            let s1 = m.logits().row(0).to_vec();
            // The shared original, read through slot 0.
            m.step(&[DecodeItem { slot: 0, token: 42, pos: 8 }]).unwrap();
            let s0 = m.logits().row(0).to_vec();
            let kv = m.kv_metrics().unwrap();
            assert!(kv.cow_copies >= 1, "split-position rewrite never copied");
            // Refcount balance: after both slots reset, every page still
            // in use is exactly a tree-retained page (the 2-page head).
            m.reset_slot(0).unwrap();
            m.reset_slot(1).unwrap();
            let kv = m.kv_metrics().unwrap();
            assert_eq!(kv.pages_in_use, kv.prefix_pages_held, "leaked page references");
            assert_eq!(kv.prefix_pages_held, 2);
            (s0, s1)
        };
        let want = run(None);
        let got = run(Some(Arc::new(FaultPlan::new(1).with(kind, 1))));
        assert_eq!(got, want, "{kind:?} on the COW write leaked into surviving state");
    }
}

#[test]
fn serving_cow_fault_finishes_typed_and_survivors_match_the_oracle() {
    // The same containment through the whole serving stack: request B
    // (the COW victim) finishes `EngineFault` with no tokens, while its
    // batch-mate C and a later re-user D of the same shared head stream
    // bit-identically to a fault-free oracle run — the faulted copy
    // never mutated the pages everyone else reads.
    let h = head();
    let tailed = |id: u64, tail: &[i32], n: usize| {
        let mut p = h.clone();
        p.extend_from_slice(tail);
        Request::new(id, p, n)
    };
    let run = |plan: Option<Arc<FaultPlan>>| {
        let pool = Arc::new(WorkerPool::shared(2));
        let engine =
            engine_with_kv(spec(), 2, Arc::clone(&pool), KvRuntimeConfig::paged(PAGE_TOKENS));
        let mut b =
            Batcher::new(engine, BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() });
        // Round 1: A caches the head pages.
        b.submit(Request::new(0, h.clone(), 4));
        let r1 = collect(b.run_to_completion().unwrap());
        // Round 2: B re-serves the bare head (first write = the COW
        // rewrite of shared page 1, the armed plan's tick 1); C shares
        // the head with a tail (first write opens a fresh page).
        if let Some(p) = &plan {
            pool.arm_faults(Arc::clone(p));
        }
        b.submit(Request::new(1, h.clone(), 4));
        b.submit(tailed(2, &[50, 51], 5));
        let r2 = collect(b.run_to_completion().unwrap());
        pool.disarm_faults();
        // Round 3: D re-uses the head after the fault, clean.
        b.submit(Request::new(3, h.clone(), 4));
        let r3 = collect(b.run_to_completion().unwrap());
        let kv = b.engine().model().kv_metrics().unwrap();
        assert_eq!(kv.prefix_pages_held, 2, "tree retention drifted from the 2-page head");
        (r1, r2, r3)
    };
    let (w1, w2, w3) = run(None);
    let plan = Arc::new(FaultPlan::new(7).with(FaultKind::KvWriteFail, 1));
    let (g1, g2, g3) = run(Some(Arc::clone(&plan)));
    assert!(plan.fired_total() >= 1, "armed plan never fired");
    assert_eq!(g1, w1, "pre-fault round diverged");
    assert_eq!(g2[&1].1, FinishReason::EngineFault, "COW victim must finish typed");
    assert!(g2[&1].0.is_empty(), "the faulted prefill never sampled a token");
    assert_eq!(g2[&2], w2[&2], "batch-mate of the faulted COW drifted");
    assert_eq!(g3, w3, "post-fault re-user of the shared head drifted");
}

#[test]
fn full_window_cached_prefix_on_an_overlong_prompt_stays_context_full() {
    // The admission clamp: request A prefill-fills the entire 24-token
    // window (finishing `ContextFull` with exactly one token) and caches
    // all 6 pages. An over-long prompt sharing that full-window prefix
    // would raw-split at 24 = max_context — a zero-window slot and an
    // out-of-window KV write; the batcher clamps to 23 so one feedable
    // position remains, and the request finishes `ContextFull` mid-
    // prefill (no sampled tokens) exactly like a cold run.
    let ctx = spec().max_context;
    let full: Vec<i32> = (0..ctx as i32).map(|t| 2 + t % 80).collect();
    let mut overlong = full.clone();
    overlong.extend([81, 82, 83, 84]);
    let run = |warm: bool| {
        let pool = WorkerPool::shared(2);
        let engine = engine_with_kv(spec(), 1, pool, KvRuntimeConfig::paged(PAGE_TOKENS));
        let mut b = Batcher::new(engine, BatcherConfig::default());
        if warm {
            b.submit(Request::new(0, full.clone(), 3));
            let done = collect(b.run_to_completion().unwrap());
            assert_eq!(done[&0].1, FinishReason::ContextFull);
            assert_eq!(done[&0].0.len(), 1);
        }
        b.submit(Request::new(1, overlong.clone(), 3));
        collect(b.run_to_completion().unwrap())
    };
    let cold = run(false);
    let warm = run(true);
    assert_eq!(warm[&1], cold[&1], "clamped full-window attach changed the stream");
    assert_eq!(warm[&1].1, FinishReason::ContextFull);
    assert!(warm[&1].0.is_empty(), "no logits are ever sampled for the over-long prompt");
}
