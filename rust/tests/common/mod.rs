//! Shared builders for the integration suites.
//!
//! Every conformance suite in `tests/` compares engines against each
//! other ("bit-identical across widths/placements/layouts/specs"), so
//! the weights, seed, and workload shapes must be *literally* the same
//! on both sides of each comparison. Centralising the builders here
//! keeps that literal: two engines built by the same function from the
//! same spec are the same model, whatever suite asked for them.
//!
//! Each suite compiles its own copy of this module (`mod common;`) and
//! uses a subset of it, hence the file-level `dead_code` allowance.
#![allow(dead_code)]

use std::sync::Arc;

use sail::coordinator::{Request, SpecConfig, SpeculativeEngine, TransformerServeEngine};
use sail::model::{DecodeSpec, KvCacheSpec, KvRuntimeConfig};
use sail::runtime::{FaultKind, FaultPlan, NumaPolicy, WorkerPool};

/// The one weight seed the suites share. Engines built from the same
/// spec with this seed are bit-for-bit the same model.
pub const SEED: u64 = 9;

/// The suites' model shape: `layers` decoder layers at mixed per-layer
/// precision (Q8/Q4/Q6 cycle), hidden 32, GQA (4 query heads over 2 KV
/// heads), 24-token context.
pub fn tiny_spec(layers: usize, kv: KvCacheSpec) -> DecodeSpec {
    DecodeSpec::tiny(layers, kv)
}

/// Seeded engine on a shared serial/threaded pool, contiguous-or-env KV.
pub fn engine(spec: DecodeSpec, batch: usize, width: usize) -> TransformerServeEngine {
    TransformerServeEngine::random(spec, SEED, batch, WorkerPool::shared(width)).unwrap()
}

/// Seeded engine on a freshly placed pool (NUMA policy applied).
pub fn engine_placed(
    spec: DecodeSpec,
    batch: usize,
    width: usize,
    policy: &NumaPolicy,
) -> TransformerServeEngine {
    let pool = Arc::new(WorkerPool::with_policy(width, policy));
    TransformerServeEngine::random(spec, SEED, batch, pool).unwrap()
}

/// Seeded engine over an explicit pool and KV runtime configuration
/// (the paged/contiguous comparisons build both sides through this).
pub fn engine_with_kv(
    spec: DecodeSpec,
    batch: usize,
    pool: Arc<WorkerPool>,
    kv: KvRuntimeConfig,
) -> TransformerServeEngine {
    TransformerServeEngine::random_with_kv(spec, SEED, batch, pool, kv).unwrap()
}

/// Seeded self-speculative engine over the *same* weight stream as
/// [`engine_with_kv`]: the target is bit-for-bit the plain engine, the
/// draft is derived from the shared float weights per `cfg.draft`.
pub fn spec_engine_with_kv(
    spec: DecodeSpec,
    batch: usize,
    pool: Arc<WorkerPool>,
    kv: KvRuntimeConfig,
    cfg: SpecConfig,
) -> SpeculativeEngine {
    SpeculativeEngine::random_with_kv(spec, SEED, batch, pool, kv, cfg).unwrap()
}

/// The canonical mixed workload: six requests, prompt lengths 1–3,
/// budgets 4–6 — enough to cycle a 3-slot batcher through admission,
/// decode, and refill at least twice. With `with_ttft`, odd ids carry a
/// generous (1 h) TTFT deadline: against a huge SLO target their
/// headroom always reads "urgent", so urgency steering and preemption
/// genuinely fire, while the deadline itself can never expire in-test.
pub fn mixed_requests(with_ttft: bool) -> Vec<Request> {
    (0..6u64)
        .map(|id| {
            let plen = 1 + (id as usize % 3);
            let prompt: Vec<i32> = (0..plen).map(|p| 2 + id as i32 + p as i32).collect();
            let r = Request::new(id, prompt, 4 + id as usize % 3);
            if with_ttft && id % 2 == 1 {
                r.with_ttft_deadline(std::time::Duration::from_secs(3600))
            } else {
                r
            }
        })
        .collect()
}

/// Pool-level faults only (worker death, slow tiles, scratch
/// poisoning): the kinds that heal in-pool with a bit-identical result,
/// so an armed plan must leave every stream untouched. KV faults are
/// deliberately absent — those surface as typed `EngineFault` finishes
/// and belong to `tests/fault_injection.rs`.
pub fn healing_plan(seed: u64) -> Arc<FaultPlan> {
    Arc::new(
        FaultPlan::new(seed)
            .with_seeded(FaultKind::WorkerPanic, 6, 0)
            .with_seeded(FaultKind::SlowTile, 8, 0)
            .with_seeded(FaultKind::PoisonScratch, 8, 0),
    )
}
