//! Serving tests for the persistent shared worker pool: several
//! `LutGemvServeEngine`s (several models) decode off one `Arc<WorkerPool>`
//! with bit-identical results to each engine running alone on a serial
//! pool (isolation + determinism), and saturating the pool with far more
//! jobs than workers never deadlocks.

use std::sync::Arc;

use sail::coordinator::{Batcher, BatcherConfig, DecodeEngine, LutGemvServeEngine, Request};
use sail::lutgemv::{GemvOutput, LutGemvEngine};
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::WorkerPool;
use sail::util::Prng;

fn engine(seed: u64, batch: usize, pool: Arc<WorkerPool>) -> LutGemvServeEngine {
    // vocab 160 → 3 column tiles at the default tile width, so every
    // decode step genuinely dispatches multi-tile work onto the pool.
    LutGemvServeEngine::random(seed, 160, 32, QuantLevel::Q4, 16, 4, batch, 64, pool)
}

/// Greedy-decode `steps` positions from fixed seeds, returning the token
/// stream (one Vec per step).
fn decode_stream(e: &mut LutGemvServeEngine, steps: i32) -> Vec<Vec<i32>> {
    let mut toks = vec![3, 11];
    let mut got = Vec::new();
    for pos in 0..steps {
        toks = e.step(&toks, &[pos, pos], &[true, true]).unwrap();
        got.push(toks.clone());
    }
    got
}

#[test]
fn two_engines_interleaved_on_one_pool_match_isolated_serial() {
    // Baselines: each model alone on a serial pool.
    let mut a_alone = engine(7, 2, WorkerPool::shared(1));
    let mut b_alone = engine(21, 2, WorkerPool::shared(1));
    let want_a = decode_stream(&mut a_alone, 12);
    let want_b = decode_stream(&mut b_alone, 12);
    assert_ne!(want_a, want_b, "distinct seeds must give distinct models");

    // Two models, one shared persistent pool, steps interleaved.
    let pool = WorkerPool::shared(4);
    let mut a = engine(7, 2, Arc::clone(&pool));
    let mut b = engine(21, 2, Arc::clone(&pool));
    let (mut toks_a, mut toks_b) = (vec![3, 11], vec![3, 11]);
    let (mut got_a, mut got_b) = (Vec::new(), Vec::new());
    for pos in 0..12 {
        toks_a = a.step(&toks_a, &[pos, pos], &[true, true]).unwrap();
        got_a.push(toks_a.clone());
        toks_b = b.step(&toks_b, &[pos, pos], &[true, true]).unwrap();
        got_b.push(toks_b.clone());
    }
    assert_eq!(got_a, want_a, "engine A drifted on the shared pool");
    assert_eq!(got_b, want_b, "engine B drifted on the shared pool");
    assert!(pool.generations() > 0, "shared pool never dispatched");
}

#[test]
fn concurrent_engines_on_one_pool_stay_isolated() {
    // The same isolation invariant under real concurrency: two OS threads
    // drive their own engines against one pool simultaneously.
    let mut a_alone = engine(5, 2, WorkerPool::shared(1));
    let mut b_alone = engine(13, 2, WorkerPool::shared(1));
    let want_a = decode_stream(&mut a_alone, 16);
    let want_b = decode_stream(&mut b_alone, 16);

    let pool = WorkerPool::shared(4);
    let (got_a, got_b) = std::thread::scope(|scope| {
        let pa = Arc::clone(&pool);
        let pb = Arc::clone(&pool);
        let ha = scope.spawn(move || decode_stream(&mut engine(5, 2, pa), 16));
        let hb = scope.spawn(move || decode_stream(&mut engine(13, 2, pb), 16));
        (ha.join().unwrap(), hb.join().unwrap())
    });
    assert_eq!(got_a, want_a, "concurrent engine A diverged");
    assert_eq!(got_b, want_b, "concurrent engine B diverged");
}

#[test]
fn batchers_on_a_shared_pool_serve_identical_tokens() {
    let reqs = |base: u64| -> Vec<Request> {
        (0..5).map(|id| Request::new(base + id, vec![1 + (base + id) as i32, 2], 4)).collect()
    };
    let run = |e: LutGemvServeEngine, reqs: Vec<Request>| {
        let mut b = Batcher::new(e, BatcherConfig::default());
        for r in reqs {
            b.submit(r);
        }
        let mut done = b.run_to_completion().unwrap();
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>()
    };
    let want_a = run(engine(7, 3, WorkerPool::shared(1)), reqs(0));
    let want_b = run(engine(21, 3, WorkerPool::shared(1)), reqs(100));

    let pool = WorkerPool::shared(4);
    let got_a = run(engine(7, 3, Arc::clone(&pool)), reqs(0));
    let got_b = run(engine(21, 3, Arc::clone(&pool)), reqs(100));
    assert_eq!(got_a, want_a);
    assert_eq!(got_b, want_b);
}

#[test]
fn saturating_the_pool_with_excess_jobs_never_deadlocks() {
    // 2 workers, 4 caller threads, each dispatching 64-tile GEMVs (32×
    // more jobs than workers, plus queued dispatches from the other
    // callers). Everything must complete and stay bit-exact.
    let pool = WorkerPool::shared(2);
    let mut prng = Prng::new(31);
    let w: Vec<f32> = (0..64 * 64).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, 64, 64, QuantLevel::Q4, 32);
    let xs: Vec<QuantizedVector> = (0..4)
        .map(|_| {
            let x: Vec<f32> = (0..64).map(|_| prng.normal() as f32).collect();
            QuantizedVector::quantize(&x)
        })
        .collect();
    let mut ref_eng = LutGemvEngine::new(wt.clone(), 4);
    ref_eng.tile_cols = 1;
    let (want, want_stats) = ref_eng.gemv_batch(&xs);

    std::thread::scope(|scope| {
        for t in 0..4usize {
            let pool = Arc::clone(&pool);
            let wt = wt.clone();
            let xs = xs.clone();
            let want = want.clone();
            scope.spawn(move || {
                let mut eng = LutGemvEngine::new(wt, 4);
                eng.tile_cols = 1; // 64 single-column tiles per dispatch
                let mut out = GemvOutput::new();
                for round in 0..10 {
                    let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
                    assert_eq!(out, want, "caller {t} round {round}");
                    assert_eq!(stats, want_stats, "caller {t} round {round} stats");
                }
            });
        }
    });
    // 4 callers × 10 rounds all dispatched through the queue.
    assert_eq!(pool.generations(), 40);
}
