//! Integration tests over the PJRT runtime and the AOT artifacts.
//!
//! All tests skip (pass vacuously) when `artifacts/` has not been built —
//! `make artifacts && cargo test` runs them for real. Each test creates
//! its own CPU PJRT client.

use std::path::{Path, PathBuf};

use sail::coordinator::{Batcher, BatcherConfig, DecodeEngine, PjrtEngine, Request};
use sail::lutgemv::engine::LutGemvEngine;
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::{DecodeModel, GemvTile, Manifest};
use sail::util::Prng;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ not built; skipping PJRT test");
        None
    }
}

#[test]
fn gemv_tile_matches_rust_engine() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let tile = GemvTile::load(&client, &dir).unwrap();

    let mut prng = Prng::new(3);
    let (n, k) = (1024usize, 1024usize);
    let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, n, k, QuantLevel::Q4, 32);
    let eng = LutGemvEngine::new(wt, 4);
    let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
    let qx = QuantizedVector::quantize(&x);
    let rust_out = eng.gemv(&qx);

    let w_codes: Vec<i8> = (0..n)
        .flat_map(|r| (0..k).map(move |c| (r, c)))
        .map(|(r, c)| eng.weights().q(r, c) as i8)
        .collect();
    let w_scales: Vec<f32> = (0..n)
        .flat_map(|r| (0..k / 32).map(move |g| (r, g)))
        .map(|(r, g)| eng.weights().scale(r, g * 32))
        .collect();
    let pjrt_out = tile.run(&qx.q, &w_codes, &w_scales, qx.scale).unwrap();

    for (i, (a, b)) in rust_out.iter().zip(&pjrt_out).enumerate() {
        let rel = (a - b).abs() / a.abs().max(1e-3);
        assert!(rel < 5e-4, "output {i}: rust {a} vs pjrt {b} (rel {rel})");
    }
}

#[test]
fn decode_model_is_deterministic_and_context_sensitive() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let mut m1 = DecodeModel::load(&client, &dir, 1).unwrap();
    let mut m2 = DecodeModel::load(&client, &dir, 1).unwrap();

    // Same inputs → identical logits.
    let l1 = m1.step(&[7], &[0]).unwrap();
    let l2 = m2.step(&[7], &[0]).unwrap();
    assert_eq!(l1, l2, "decode must be deterministic");

    // Different history → different logits at the next step.
    let _ = m2.reset_kv(None).unwrap();
    let _ = m2.step(&[900], &[0]).unwrap();
    let a = m1.step(&[3], &[1]).unwrap();
    let b = m2.step(&[3], &[1]).unwrap();
    assert_ne!(a, b, "KV cache must influence the next step");
}

#[test]
fn decode_argmax_in_vocab_and_stable() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut m = DecodeModel::load(&client, &dir, 1).unwrap();
    let mut tok = 11i32;
    for pos in 0..4 {
        let logits = m.step(&[tok], &[pos]).unwrap();
        assert_eq!(logits.len(), manifest.config.vocab);
        let next = m.argmax(&logits)[0];
        assert!((0..manifest.config.vocab as i32).contains(&next));
        tok = next;
    }
    assert_eq!(m.steps_executed(), 4);
}

#[test]
fn batched_decode_slots_are_isolated() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let b = manifest.batch;
    let mut model = DecodeModel::load(&client, &dir, b).unwrap();

    // Slot 0 runs sequence A; other slots run unrelated tokens. Slot 0's
    // logits must match a batch-1 run of the same sequence.
    let mut single = DecodeModel::load(&client, &dir, 1).unwrap();
    let seq = [5i32, 9, 13];
    let mut batch_logits = Vec::new();
    let mut single_logits = Vec::new();
    for (pos, &t) in seq.iter().enumerate() {
        let mut toks = vec![(100 + pos as i32); b];
        toks[0] = t;
        let poss = vec![pos as i32; b];
        let lb = model.step(&toks, &poss).unwrap();
        batch_logits.push(lb[..manifest.config.vocab].to_vec());
        let ls = single.step(&[t], &[pos as i32]).unwrap();
        single_logits.push(ls);
    }
    for (pos, (a, b_)) in single_logits.iter().zip(&batch_logits).enumerate() {
        let max_rel = a
            .iter()
            .zip(b_)
            .map(|(x, y)| (x - y).abs() / x.abs().max(1e-3))
            .fold(0.0f32, f32::max);
        assert!(max_rel < 2e-3, "slot isolation violated at pos {pos}: {max_rel}");
    }
}

#[test]
fn pjrt_engine_through_batcher_generates() {
    let Some(dir) = artifacts() else { return };
    let engine = PjrtEngine::load(&dir, 1).unwrap();
    let vocab = engine.vocab();
    let mut batcher = Batcher::new(engine, BatcherConfig::default());
    batcher.submit(Request::new(0, vec![3, 5], 4));
    let done = batcher.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tokens.len(), 4);
    for &t in &done[0].tokens {
        assert!((0..vocab as i32).contains(&t));
    }
}
