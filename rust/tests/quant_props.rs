//! Direct property tests for the quantization substrate — the layers the
//! GEMV conformance suites exercise only indirectly: group-wise
//! quantize→dequantize error bounds, activation sign-plane invariants, and
//! the packed-stream word-boundary edges of `unpack_range_into`.

use sail::quant::pack::BitPacked;
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::util::{propcheck, Prng};

#[test]
fn groupwise_roundtrip_error_bounded_per_element() {
    // |w − q·scale| ≤ scale/2 for the element's *own* group scale — the
    // bound symmetric round-to-nearest guarantees (clamping never bites:
    // |x|/scale ≤ max_q by construction of scale).
    propcheck::check(
        "groupwise-roundtrip-bound",
        propcheck::Config { cases: 80, seed: 501 },
        |p, _| {
            let level = QuantLevel::ALL[p.usize_in(0, 6)];
            let rows = p.usize_in(1, 8);
            let group = [8usize, 16, 32][p.usize_in(0, 3)];
            let cols = group * p.usize_in(1, 5);
            let seed = p.next_u64();
            (level, rows, cols, group, seed)
        },
        |&(level, rows, cols, group, seed)| {
            let mut prng = Prng::new(seed);
            let w: Vec<f32> = (0..rows * cols).map(|_| (prng.normal() * 2.5) as f32).collect();
            let qm = QuantizedMatrix::quantize(&w, rows, cols, level, group);
            for r in 0..rows {
                for c in 0..cols {
                    let err = (w[r * cols + c] - qm.dequant(r, c)).abs();
                    let bound = qm.scale(r, c) * 0.500001;
                    if err > bound {
                        return Err(format!("{level} ({r},{c}): err {err} > scale/2 {bound}"));
                    }
                    if qm.q(r, c).abs() > level.max_q() {
                        return Err(format!("code outside ±max_q at ({r},{c})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn activation_sign_plane_invariants() {
    // The bit-serial contract the engine's plane loop relies on: planes
    // 0..bits−2 carry weight +2^p, the top plane carries −2^(bits−1), and
    // reassembling them recovers the exact int8 code. Quantization is
    // symmetric, so the unpaired −2^(bits−1) code never occurs.
    propcheck::check(
        "act-sign-planes",
        propcheck::Config { cases: 120, seed: 503 },
        |p, i| {
            let k = p.usize_in(1, 8 + 2 * i);
            let x: Vec<f32> = (0..k).map(|_| (p.normal() * 3.0) as f32).collect();
            x
        },
        |x| {
            let qv = QuantizedVector::quantize(x);
            if qv.scale <= 0.0 {
                return Err("non-positive activation scale".into());
            }
            for (i, &q) in qv.q.iter().enumerate() {
                if q == i8::MIN {
                    return Err(format!("asymmetric code -128 at {i}"));
                }
                let mut rec: i32 = 0;
                for plane in 0..qv.bits {
                    let w = 1i32 << plane;
                    let bit = qv.bit(i, plane) as i32;
                    if plane == qv.bits - 1 {
                        rec -= bit * w; // sign plane subtracts
                    } else {
                        rec += bit * w;
                    }
                }
                if rec != q as i32 {
                    return Err(format!("plane reassembly {rec} != code {q} at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn activation_patterns_follow_msb_first_convention() {
    // `pattern(start, nbw, plane)` maps element `start+j` to LUT address
    // bit `nbw−1−j` (Fig 2) and zero-pads past the end of the vector —
    // the exact indexing the engine's pattern table precomputation uses.
    let mut prng = Prng::new(505);
    for _ in 0..200 {
        let k = prng.usize_in(1, 40);
        let q: Vec<i8> = (0..k).map(|_| prng.signed_bits(8) as i8).collect();
        let qv = QuantizedVector { q, scale: 1.0, bits: 8 };
        let nbw = prng.usize_in(1, 9) as u32;
        let start = prng.usize_in(0, k + 4); // may run past the end
        let plane = prng.usize_in(0, 8) as u32;
        let pat = qv.pattern(start, nbw, plane);
        assert!(pat < (1 << nbw));
        for j in 0..nbw as usize {
            let want = if start + j < k { qv.bit(start + j, plane) as u32 } else { 0 };
            let got = (pat >> (nbw as usize - 1 - j)) & 1;
            assert_eq!(got, want, "k={k} start={start} nbw={nbw} plane={plane} j={j}");
        }
    }
}

#[test]
fn unpack_range_word_boundary_sweep() {
    // Every start offset at widths 1..=8 over a stream long enough that
    // ranges begin mid-word, straddle u64 boundaries, and end exactly on
    // them. `unpack_range_into` must agree with the per-element `get` at
    // every single alignment.
    let mut prng = Prng::new(507);
    for bits in 1u32..=8 {
        let n = 300usize; // up to 2400 bits ⇒ tens of word crossings
        let vals: Vec<i32> = (0..n).map(|_| prng.signed_bits(bits) as i32).collect();
        let packed = BitPacked::pack(&vals, bits);
        for start in 0..n {
            let len = (n - start).min(17);
            let mut out = vec![0i32; len];
            packed.unpack_range_into(start, &mut out);
            for (j, &o) in out.iter().enumerate() {
                assert_eq!(o, packed.get(start + j), "bits={bits} start={start} j={j}");
                assert_eq!(o, vals[start + j], "bits={bits} start={start} j={j} (vs input)");
            }
        }
        // Full-stream unpack as one range.
        let mut all = vec![0i32; n];
        packed.unpack_range_into(0, &mut all);
        assert_eq!(all, vals, "bits={bits} full range");
    }
}

#[test]
fn unpack_range_exact_word_edges() {
    // Deterministic corners: a value beginning at bit 63 (straddles into
    // word 1), a range whose last value ends exactly at a word boundary,
    // and a range starting exactly on one.
    for bits in [3u32, 5, 6, 7] {
        let per_word = 64usize.div_ceil(bits as usize) + 1;
        let n = per_word * 4;
        let vals: Vec<i32> =
            (0..n).map(|i| ((i as i32) % (1 << (bits - 1))) - (1 << (bits - 2))).collect();
        let packed = BitPacked::pack(&vals, bits);
        // First value that straddles a 64-bit boundary.
        let straddle = (0..n)
            .find(|i| {
                let lo = i * bits as usize;
                lo % 64 + bits as usize > 64
            })
            .unwrap();
        for start in [straddle.saturating_sub(1), straddle, straddle + 1] {
            let mut out = vec![0i32; 3.min(n - start)];
            packed.unpack_range_into(start, &mut out);
            for (j, &o) in out.iter().enumerate() {
                assert_eq!(o, vals[start + j], "bits={bits} start={start} j={j}");
            }
        }
        // A range ending exactly at bit 64·m: 64 and bits share gcd
        // structure; lcm(64,bits)/bits values end on a word edge.
        let lcm_vals = {
            let mut v = 1usize;
            while (v * bits as usize) % 64 != 0 {
                v += 1;
            }
            v
        };
        if lcm_vals <= n {
            let mut out = vec![0i32; lcm_vals];
            packed.unpack_range_into(0, &mut out);
            assert_eq!(&out, &vals[..lcm_vals], "bits={bits} word-aligned end");
        }
    }
}
