//! Chaos soak for the fault-tolerant serving stack.
//!
//! The degradation ladder under test (pool → engine → batcher):
//!
//! - a **pool** worker that panics is respawned on its node (bounded
//!   budget) and its lost items re-run, inline if need be — the GEMV
//!   result is bit-identical and the dispatch never deadlocks;
//! - an **engine** forward that fails (injected KV faults) surfaces as a
//!   typed `Err` from `step_runs`, never a panic;
//! - the **batcher** retries the failed iteration one run at a time:
//!   transient faults heal invisibly, a genuinely faulted request
//!   finishes with `FinishReason::EngineFault` and its tokens so far,
//!   and every *other* request's token stream is bit-identical to a
//!   fault-free run.
//!
//! Faults come from seeded [`FaultPlan`]s armed per pool, so every
//! scenario here is reproducible on any host at any parallelism. The CI
//! fault leg re-runs this suite under `SAIL_FAULTS` env plans as well.

use std::collections::BTreeMap;
use std::sync::Arc;

use sail::coordinator::{Batcher, BatcherConfig, FinishReason, Request, TransformerServeEngine};
use sail::lutgemv::{GemvOutput, LutGemvEngine};
use sail::model::{DecodeSpec, KvCacheSpec};
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::{FaultKind, FaultPlan, NumaPolicy, WorkerPool};
use sail::util::Prng;

fn spec() -> DecodeSpec {
    DecodeSpec::tiny(2, KvCacheSpec::q8())
}

/// Six requests with mixed prompt lengths and budgets — enough to cycle
/// every slot of a 3-wide batcher through admission at least twice.
fn requests() -> Vec<Request> {
    (0..6u64)
        .map(|id| {
            let plen = 1 + (id as usize % 3);
            let prompt: Vec<i32> = (0..plen).map(|p| 2 + id as i32 + p as i32).collect();
            Request::new(id, prompt, 4 + id as usize % 3)
        })
        .collect()
}

/// Serve [`requests`] to completion on a fresh engine over `pool`,
/// returning `id → (tokens, finish)`.
fn serve(pool: Arc<WorkerPool>) -> BTreeMap<u64, (Vec<i32>, FinishReason)> {
    let engine = TransformerServeEngine::random(spec(), 9, 3, pool).unwrap();
    let mut b = Batcher::new(engine, BatcherConfig::default());
    for r in requests() {
        b.submit(r);
    }
    let done = b.run_to_completion().unwrap();
    done.into_iter().map(|r| (r.id, (r.tokens, r.finish))).collect()
}

/// Every fault kind on one plan: the pool-level kinds land on seeded
/// ticks (different seeds → different interleavings) while the KV kinds
/// use fixed early ticks so a genuinely faulted request always exists.
fn chaos_plan(seed: u64) -> Arc<FaultPlan> {
    Arc::new(
        FaultPlan::new(seed)
            .with_seeded(FaultKind::WorkerPanic, 6, 0)
            .with_seeded(FaultKind::WorkerPanic, 6, 1)
            .with_seeded(FaultKind::SlowTile, 8, 0)
            .with_seeded(FaultKind::PoisonScratch, 8, 0)
            .with(FaultKind::KvWriteFail, 5)
            .with(FaultKind::KvCorrupt, 9),
    )
}

#[test]
fn chaos_soak_survivors_bit_identical_across_widths_and_placements() {
    // Fault-free oracle (serial pool).
    let want = serve(WorkerPool::shared(1));
    assert!(want.values().all(|(t, f)| !t.is_empty() && *f != FinishReason::EngineFault));

    let mut faulted_sets: Vec<Vec<u64>> = Vec::new();
    for policy in [NumaPolicy::Off, NumaPolicy::Auto] {
        for width in [1usize, 2, 8] {
            let pool = Arc::new(WorkerPool::with_policy(width, &policy));
            let plan = chaos_plan(4242);
            pool.arm_faults(Arc::clone(&plan));
            let got = serve(Arc::clone(&pool));
            pool.disarm_faults();

            // No deadlock, no lost request: every id is answered.
            assert_eq!(got.len(), want.len(), "{policy} width {width} lost requests");
            let mut faulted = Vec::new();
            for (id, (tokens, finish)) in &got {
                if *finish == FinishReason::EngineFault {
                    faulted.push(*id);
                } else {
                    assert_eq!(
                        (tokens, finish),
                        (&want[id].0, &want[id].1),
                        "survivor {id} drifted under faults ({policy} width {width})"
                    );
                }
            }
            // The latched KV write failure guarantees at least one
            // genuinely faulted request, finished typed.
            assert!(
                !faulted.is_empty(),
                "kv_write_fail never surfaced as EngineFault ({policy} width {width})"
            );
            assert!(plan.fired_total() >= 1, "armed plan never fired");
            faulted_sets.push(faulted);
        }
    }
    // The KV fault schedule is a function of the forward sequence alone,
    // so the same plan must pick the same victims everywhere — placement
    // and pool width are invisible even to the failure behaviour.
    for s in &faulted_sets[1..] {
        assert_eq!(*s, faulted_sets[0], "faulted set depends on pool width/placement");
    }
}

#[test]
fn seeded_plans_never_panic_the_batcher() {
    // Sweep seeds so the pool-level faults land at different points of
    // the run (including mid-prefill); every run must complete with
    // typed finishes — `run_to_completion` returning is the no-deadlock
    // check, `Ok` is the no-panic-no-abort check.
    for seed in [0u64, 1, 7, 31, 99] {
        let pool = WorkerPool::shared(2);
        pool.arm_faults(chaos_plan(seed));
        let got = serve(Arc::clone(&pool));
        pool.disarm_faults();
        assert_eq!(got.len(), requests().len(), "seed {seed} lost requests");
        for (id, (tokens, finish)) in got {
            match finish {
                FinishReason::EngineFault => {} // typed, tokens-so-far
                _ => assert!(!tokens.is_empty(), "seed {seed} req {id}: empty non-fault finish"),
            }
        }
    }
}

#[test]
fn respawn_budget_exhaustion_degrades_to_serial_bit_identically() {
    // More worker deaths than the budget allows: the pool must latch
    // degraded mode and keep serving inline — same bits, no hang.
    let mut prng = Prng::new(17);
    let w: Vec<f32> = (0..48 * 64).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, 48, 64, QuantLevel::Q4, 32);
    let xs: Vec<QuantizedVector> = (0..3)
        .map(|_| {
            let x: Vec<f32> = (0..64).map(|_| prng.normal() as f32).collect();
            QuantizedVector::quantize(&x)
        })
        .collect();
    let mut eng = LutGemvEngine::new(wt, 4);
    eng.tile_cols = 8; // several tiles per dispatch
    let (want, want_stats) = eng.gemv_batch(&xs);

    let pool = WorkerPool::shared(2);
    pool.set_respawn_budget(1);
    pool.arm_faults(Arc::new(
        FaultPlan::new(3)
            .with(FaultKind::WorkerPanic, 1)
            .with(FaultKind::WorkerPanic, 2)
            .with(FaultKind::WorkerPanic, 3),
    ));
    let mut out = GemvOutput::new();
    for round in 0..6 {
        let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        assert_eq!(out, want, "round {round} output drifted while degrading");
        assert_eq!(stats, want_stats, "round {round} stats drifted while degrading");
    }
    pool.disarm_faults();
    assert!(pool.degraded(), "budget exhaustion must latch degraded mode");
    assert!(
        pool.respawned_workers() <= 1,
        "pool respawned {} workers past its budget of 1",
        pool.respawned_workers()
    );
    // A degraded pool still serves fault-free work correctly.
    let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
    assert_eq!((out, stats), (want.clone(), want_stats));
}

#[test]
fn retry_path_counts_stats_exactly_once() {
    // Regression (satellite of the serving PR): the batcher's solo-retry
    // path used to double-count kernel work. A batched forward that
    // failed mid-way had already committed per-projection `GemvStats` for
    // the layers it finished; the solo retry then committed a whole
    // forward again, so a faulted-then-healed run inflated `DecodeStats`
    // versus a fault-free run. Stats are now staged during the forward
    // and committed only on success — a failed `step_runs` contributes
    // exactly nothing, and the retry contributes exactly one forward.
    //
    // Oracle: fault-free single-request run on a serial pool.
    let req = || Request::new(0, vec![2, 3], 5);
    let oracle_engine =
        TransformerServeEngine::random(spec(), 9, 1, WorkerPool::shared(1)).unwrap();
    let mut ob = Batcher::new(oracle_engine, BatcherConfig::default());
    ob.submit(req());
    let want = ob.run_to_completion().unwrap();
    assert_eq!(want.len(), 1);
    assert!(want[0].finish != FinishReason::EngineFault);
    let want_stats = ob.engine().stats().clone();
    assert!(want_stats.steps > 0 && want_stats.tokens > 0);

    // Same request under a transient KV corruption: tick 3 lands inside
    // the second forward (2 `kv_write_fault` calls per 2-layer forward),
    // which fails batched AND solo once, then heals — the one-shot fault
    // is consumed by the failed attempt, so the retry of the *next*
    // iteration succeeds. Tokens, finish, and kernel stats must all be
    // bit-identical to the fault-free oracle.
    let pool = WorkerPool::shared(2);
    pool.arm_faults(Arc::new(FaultPlan::new(9).with(FaultKind::KvCorrupt, 3)));
    let engine = TransformerServeEngine::random(spec(), 9, 1, Arc::clone(&pool)).unwrap();
    let mut b = Batcher::new(engine, BatcherConfig::default());
    b.submit(req());
    let got = b.run_to_completion().unwrap();
    pool.disarm_faults();
    assert_eq!(got.len(), 1);
    assert_eq!((&got[0].tokens, got[0].finish), (&want[0].tokens, want[0].finish));
    assert_eq!(
        b.engine().stats(),
        &want_stats,
        "retried iteration counted its stats more (or less) than once"
    );
}

#[test]
fn env_spec_grammar_drives_the_full_stack() {
    // The exact strings the CI fault leg exports via SAIL_FAULTS, parsed
    // through the strict grammar and armed on a serving pool. (The env
    // read itself is `FaultPlan::from_env` — a thin wrapper over this
    // parse — left untouched here because set_var races parallel tests.)
    let want = serve(WorkerPool::shared(1));
    for spec_str in
        ["11:worker_panic%4,poison_scratch%6,slow_tile%8", "23:kv_write_fail@3,worker_panic%5"]
    {
        let plan = Arc::new(FaultPlan::parse(spec_str).unwrap());
        let pool = WorkerPool::shared(2);
        pool.arm_faults(Arc::clone(&plan));
        let got = serve(Arc::clone(&pool));
        pool.disarm_faults();
        assert_eq!(got.len(), want.len(), "'{spec_str}' lost requests");
        for (id, (tokens, finish)) in &got {
            if *finish != FinishReason::EngineFault {
                assert_eq!(tokens, &want[id].0, "'{spec_str}' survivor {id} drifted");
            }
        }
        assert!(plan.fired_total() >= 1, "'{spec_str}' never fired");
    }
    // Malformed specs stay typed errors end to end.
    for bad in ["worker_panic@1", "5:", "5:worker_panic", "5:nope@1", "5:slow_tile%0"] {
        assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must be rejected, typed");
    }
}

#[test]
fn sail_faults_env_plan_is_honoured_when_set() {
    // The CI fault leg exports SAIL_FAULTS (.github/workflows/ci.yml);
    // in that leg this test arms the env plan on a serving pool and
    // holds the chaos invariants under it. In every other leg the env is
    // unset and this only pins that the unset read is `Ok(None)`. The
    // env is read, never written — `set_var` would race parallel tests.
    let plan = match FaultPlan::from_env() {
        Err(e) => panic!("malformed SAIL_FAULTS must fail the leg loudly: {e}"),
        Ok(None) => return,
        Ok(Some(p)) => Arc::new(p),
    };
    let want = serve(WorkerPool::shared(1));
    let pool = WorkerPool::shared(2);
    pool.arm_faults(Arc::clone(&plan));
    let got = serve(Arc::clone(&pool));
    pool.disarm_faults();
    assert_eq!(got.len(), want.len(), "env plan lost requests");
    for (id, (tokens, finish)) in &got {
        if *finish != FinishReason::EngineFault {
            assert_eq!(tokens, &want[id].0, "env-plan survivor {id} drifted");
        }
    }
    assert!(plan.fired_total() >= 1, "armed env plan never fired");
}
