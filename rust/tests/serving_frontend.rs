//! Conformance suite for the streaming serving front-end.
//!
//! The property under test is the front-end's **determinism contract**:
//! every scheduling decision — SLO row-budget retuning, preemption,
//! admission order, prefill chunking, pool width, NUMA placement, even a
//! healing fault plan — is invisible in the token streams. For a fixed
//! request set, the online per-request streams must be bit-identical to
//! offline [`Batcher::run_to_completion`] on a serial fault-free pool.
//!
//! Also here: the deadline-expiry stream shape (an expiree's stream is a
//! *prefix* of its fault-free stream, finished `DeadlineExceeded`), and
//! the tier-1 serving smoke — an arrival-driven workload replayed at
//! three offered-load points, persisting the latency/goodput artifact to
//! `BENCH_serving.json` (schema in EXPERIMENTS.md).

mod common;

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

use sail::coordinator::{
    workload, ArrivalProcess, Batcher, BatcherConfig, FinishReason, MockEngine, RequestId,
    ServingConfig, ServingFrontend, SloPolicy, TransformerServeEngine, WorkloadSpec,
};
use sail::model::{DecodeSpec, KvCacheSpec};
use sail::runtime::{NumaPolicy, WorkerPool};
use sail::util::json::Json;

use common::{healing_plan, mixed_requests as requests};

fn spec() -> DecodeSpec {
    common::tiny_spec(2, KvCacheSpec::q8())
}

/// The offline oracle: the same requests through `run_to_completion` on a
/// serial fault-free pool at prefill chunk 1.
fn oracle() -> HashMap<RequestId, (Vec<i32>, FinishReason)> {
    let engine =
        TransformerServeEngine::random(spec(), common::SEED, 3, WorkerPool::shared(1)).unwrap();
    let cfg = BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() };
    let mut b = Batcher::new(engine, cfg);
    for r in requests(false) {
        b.submit(r);
    }
    b.run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, (r.tokens, r.finish)))
        .collect()
}

#[test]
fn streams_bit_identical_across_widths_placements_chunks_and_faults() {
    let want = oracle();
    assert!(want.values().all(|(t, f)| !t.is_empty() && *f != FinishReason::EngineFault));

    for prefill_chunk in [1usize, 16] {
        for policy in [NumaPolicy::Off, NumaPolicy::Auto] {
            for width in [1usize, 2, 8] {
                for faults in [false, true] {
                    let ctx = format!(
                        "chunk {prefill_chunk} {policy} width {width} faults {faults}"
                    );
                    let pool = Arc::new(WorkerPool::with_policy(width, &policy));
                    let plan = healing_plan(4242);
                    if faults {
                        pool.arm_faults(Arc::clone(&plan));
                    }
                    let engine =
                        TransformerServeEngine::random(spec(), common::SEED, 3, Arc::clone(&pool))
                            .unwrap();
                    // Aggressive SLO: the 1 µs TPOT target forces a
                    // retune every iteration, and the odd requests' 1 h
                    // TTFT headroom is inside ttft/4 of the 20000 s
                    // target, so urgency + preemption fire constantly.
                    let cfg = ServingConfig {
                        batcher: BatcherConfig {
                            prefill_chunk,
                            ..BatcherConfig::default()
                        },
                        slo: Some(SloPolicy {
                            ttft: Duration::from_secs(20_000),
                            tpot: Duration::from_micros(1),
                            max_rows: 64,
                        }),
                        preemption: true,
                    };
                    let fe = ServingFrontend::spawn(engine, cfg);
                    let handles: Vec<_> = requests(true)
                        .into_iter()
                        .map(|r| fe.submit(r).unwrap())
                        .collect();
                    for h in handles {
                        let id = h.id;
                        let (streamed, resp) = h.wait().unwrap();
                        assert_eq!(
                            streamed, resp.tokens,
                            "stream {id} desynced from its response ({ctx})"
                        );
                        let (want_tokens, want_finish) = &want[&id];
                        assert_eq!(
                            (&resp.tokens, &resp.finish),
                            (want_tokens, want_finish),
                            "scheduling leaked into stream {id} ({ctx})"
                        );
                    }
                    let metrics = fe.shutdown();
                    if faults {
                        pool.disarm_faults();
                        assert!(plan.fired_total() >= 1, "armed plan never fired ({ctx})");
                    }
                    assert_eq!(metrics.completed, 6, "{ctx}");
                    assert_eq!(
                        (metrics.shed, metrics.deadline_exceeded, metrics.engine_faults),
                        (0, 0, 0),
                        "{ctx}"
                    );
                }
            }
        }
    }
}

#[test]
fn deadline_expirees_stream_a_prefix_and_survivors_exactly_match() {
    // Fault-free oracle without deadlines.
    let mut ob = Batcher::new(MockEngine::new(2, 97, 64), BatcherConfig::default());
    for r in requests(false) {
        ob.submit(r);
    }
    let want: HashMap<RequestId, Vec<i32>> =
        ob.run_to_completion().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();

    // Online: ids 1 and 4 carry an already-expired total-latency budget.
    // With 2 slots and 6 submissions, at least one expiree is still
    // *queued* when swept — it must finish typed without ever holding a
    // slot (the deadline clock starts at submit, not admission).
    let doomed = [1u64, 4];
    let fe = ServingFrontend::spawn(MockEngine::new(2, 97, 64), ServingConfig::default());
    let handles: Vec<_> = requests(false)
        .into_iter()
        .map(|r| {
            let r = if doomed.contains(&r.id) { r.with_deadline(Duration::ZERO) } else { r };
            fe.submit(r).unwrap()
        })
        .collect();
    for h in handles {
        let id = h.id;
        let (streamed, resp) = h.wait().unwrap();
        assert_eq!(streamed, resp.tokens, "stream {id} desynced from its response");
        if doomed.contains(&id) {
            assert_eq!(resp.finish, FinishReason::DeadlineExceeded, "request {id}");
            assert!(
                want[&id].starts_with(&resp.tokens),
                "expiree {id} streamed tokens that are not a prefix of its fault-free run"
            );
        } else {
            assert_eq!(resp.finish, FinishReason::MaxTokens, "request {id}");
            assert_eq!(streamed, want[&id], "deadline handling changed survivor {id}");
        }
    }
    let metrics = fe.shutdown();
    assert_eq!(metrics.completed, 6);
    assert_eq!(metrics.deadline_exceeded, doomed.len() as u64);
    // Expired work is not goodput; the four survivors' tokens all are.
    let survivor_tokens: u64 = want
        .iter()
        .filter(|(id, _)| !doomed.contains(id))
        .map(|(_, t)| t.len() as u64)
        .sum();
    assert_eq!(metrics.goodput_tokens, survivor_tokens);
}

/// Tier-1 serving smoke: replay one seeded arrival schedule at three
/// offered-load points (0.5×/1×/2× of the base rate), assert every
/// stream bit-matches the offline oracle at every load, and persist the
/// latency/goodput artifact to `BENCH_serving.json` (next to Cargo.toml
/// and at the repo root). `benches/serving_load.rs` overwrites it with
/// the release-build version; this test keeps the artifact alive (and the
/// schema honest) on plain `cargo test`.
#[test]
fn serving_smoke_replays_three_load_points_and_writes_artifact() {
    const BASE_RATE: f64 = 400.0; // requests/sec before time scaling
    const N: usize = 24;
    let wspec =
        WorkloadSpec::small(21, ArrivalProcess::Poisson { rate_per_sec: BASE_RATE });
    let schedule = workload::generate(&wspec, N);

    // Offline oracle for the whole request set.
    let mut ob = Batcher::new(MockEngine::new(4, 97, 64), BatcherConfig::default());
    for tr in &schedule {
        ob.submit(tr.req.clone());
    }
    let want: HashMap<RequestId, Vec<i32>> =
        ob.run_to_completion().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();

    let mut points = Vec::new();
    for (label, time_scale) in [("0.5x", 2.0f64), ("1x", 1.0), ("2x", 0.5)] {
        let cfg = ServingConfig {
            batcher: BatcherConfig::default(),
            slo: Some(SloPolicy {
                ttft: Duration::from_millis(250),
                tpot: Duration::from_millis(50),
                max_rows: 128,
            }),
            preemption: true,
        };
        let fe = ServingFrontend::spawn(MockEngine::new(4, 97, 64), cfg);
        let handles = workload::replay(&fe, &schedule, time_scale).unwrap();
        for h in handles {
            let id = h.id;
            let (streamed, resp) = h.wait().unwrap();
            assert_eq!(resp.finish, FinishReason::MaxTokens, "request {id} at {label}");
            assert_eq!(
                streamed, want[&id],
                "offered load changed stream {id} at {label}"
            );
            assert_eq!(streamed, resp.tokens);
        }
        let m = fe.shutdown();
        assert_eq!(m.completed, N as u64, "{label}");
        assert_eq!(m.goodput_tokens, m.tokens_generated, "{label}: no sheds expected");

        let mut o = BTreeMap::new();
        o.insert("load".to_string(), Json::Str(label.to_string()));
        o.insert("offered_rps".to_string(), Json::Num(BASE_RATE / time_scale));
        o.insert("time_scale".to_string(), Json::Num(time_scale));
        o.insert("requests".to_string(), Json::Num(m.completed as f64));
        o.insert("shed".to_string(), Json::Num(m.shed as f64));
        o.insert("shed_rate".to_string(), Json::Num(m.shed_rate()));
        o.insert("deadline_exceeded".to_string(), Json::Num(m.deadline_exceeded as f64));
        o.insert("ttft_p50_ms".to_string(), Json::Num(m.ttft.p50()));
        o.insert("ttft_p99_ms".to_string(), Json::Num(m.ttft.p99()));
        o.insert("tpot_p50_ms".to_string(), Json::Num(m.tpot.p50()));
        o.insert("tpot_p99_ms".to_string(), Json::Num(m.tpot.p99()));
        o.insert("tok_per_sec".to_string(), Json::Num(m.tokens_per_sec()));
        o.insert(
            "goodput_tok_per_sec".to_string(),
            Json::Num(m.goodput_tokens_per_sec()),
        );
        // KV/prefix columns stay in the schema with null values: the
        // mock engine carries no KV store, so `m.kv` is None here. The
        // release bench fills them from the paged online engines.
        for key in [
            "prefix_hit_rate",
            "prefix_hits",
            "prefix_misses",
            "cow_copies",
            "kv_pages_peak",
            "kv_pool_pages",
            "kv_contiguous_worst_case_pages",
        ] {
            o.insert(key.to_string(), Json::Null);
        }
        assert!(m.kv.is_none(), "{label}: mock engine must not report KV metrics");
        points.push(Json::Obj(o));
    }

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("serving_load".to_string()));
    top.insert("source".to_string(), Json::Str("test-smoke".to_string()));
    top.insert("engine".to_string(), Json::Str("mock".to_string()));
    top.insert("requests".to_string(), Json::Num(N as f64));
    top.insert("base_rate_rps".to_string(), Json::Num(BASE_RATE));
    top.insert("streams_bit_exact".to_string(), Json::Bool(true));
    top.insert("kv_oracle".to_string(), Json::Null);
    top.insert("kv_online".to_string(), Json::Null);
    top.insert("shared_prompt_heads".to_string(), Json::Null);
    top.insert("shared_prompt_head_len".to_string(), Json::Null);
    top.insert("shared_prompt_zipf_s".to_string(), Json::Null);
    top.insert("points".to_string(), Json::Arr(points));
    let doc = Json::Obj(top);
    for path in [
        concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serving.json"),
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"),
    ] {
        doc.write_atomic(std::path::Path::new(path)).unwrap();
    }
}
