//! Conformance suite for the work-stealing dispatch backend and the
//! epoch-based weight-reclamation path it enables.
//!
//! Four properties, each load-bearing for the steal pool being the
//! default backend:
//!
//! 1. **Steal-schedule bit-identity.** For seeded weights and
//!    activations, `LutGemvEngine` output *and* `GemvStats` are
//!    bit-for-bit identical across backends (steal / channel / serial),
//!    widths, NUMA placements, forced-steal chaos schedules, and healing
//!    worker-panic plans. The steal deque may reorder execution
//!    arbitrarily; none of that order is allowed to reach the numerics.
//! 2. **Exactly-once execution.** Under forced steals and mid-dispatch
//!    worker panics, every item of every dispatch executes exactly once
//!    (counted with per-item atomics) — no drop, no double-run.
//! 3. **Hot-swap mid-stream.** A live `ServingFrontend` swaps weight
//!    generations between iterations: streams admitted before the swap
//!    finish bit-identical to an offline oracle on the *old* weights,
//!    streams admitted after match an oracle on the *new* weights, no
//!    request faults, and the retired generation is reclaimed (observed
//!    via `ServingMetrics::reclaim`).
//! 4. **Reclamation soak.** Concurrent readers race `publish_weights`:
//!    every whole GEMV output matches exactly one published generation
//!    (no torn mix of old and new weights), and when the dust settles
//!    every retired snapshot has been dropped — no leak, no ABA.

mod common;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Weak};
use std::thread;

use sail::coordinator::{
    Batcher, BatcherConfig, FinishReason, Request, RequestId, ServingConfig, ServingFrontend,
    StreamEvent, TransformerServeEngine,
};
use sail::lutgemv::engine::reference_gemv;
use sail::lutgemv::{GemvOutput, LutGemvEngine};
use sail::model::{DecodeSpec, KvCacheSpec};
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::{FaultKind, FaultPlan, NumaPolicy, PoolMode, WorkerPool};
use sail::util::Prng;

/// Seeded GEMV problem shared by the dispatch-level tests. Rebuilt from
/// the same PRNG stream on every call, so two calls yield bit-identical
/// weights and activations without requiring `Clone` anywhere.
fn gemv_problem(seed: u64) -> (QuantizedMatrix, Vec<QuantizedVector>) {
    let mut prng = Prng::new(seed);
    let (n, k, group) = (16, 64, 32);
    let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, n, k, QuantLevel::Q4, group);
    let xs = (0..4)
        .map(|_| {
            let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
            QuantizedVector::quantize(&x)
        })
        .collect();
    (wt, xs)
}

/// A fake two-node NUMA map over `width` workers, so placement-aware
/// steal ordering (own deque → same node → cross-node) genuinely kicks
/// in on single-node CI hosts.
fn fake_two_node(width: usize) -> NumaPolicy {
    let split = width.div_ceil(2);
    NumaPolicy::Explicit(vec![(0..split).collect(), (split..width).collect()])
}

/// Property 1: the steal backend is schedule-invisible. Outputs and
/// stats from steal and channel pools — across widths, placements,
/// forced-steal chaos seeds, and a healing worker-panic plan — all equal
/// the naive reference and each other.
#[test]
fn steal_schedules_are_bit_identical_to_channel_and_reference() {
    let (wt_ref, xs) = gemv_problem(2026);
    let want: Vec<Vec<f32>> = xs.iter().map(|x| reference_gemv(&wt_ref, x)).collect();

    let mut baseline_stats = None;
    for width in [1usize, 2, 8] {
        for numa in [false, true] {
            if numa && width < 2 {
                // A two-node map needs at least one worker per node.
                continue;
            }
            let policy = if numa { fake_two_node(width) } else { NumaPolicy::Off };
            for chaos in [None, Some(7u64), Some(21)] {
                for faults in [false, true] {
                    for mode in [PoolMode::Steal, PoolMode::Channel] {
                        let ctx = format!(
                            "width {width} numa {numa} chaos {chaos:?} faults {faults} {mode:?}"
                        );
                        let pool = WorkerPool::with_policy_mode(width, &policy, mode);
                        pool.set_steal_chaos(chaos);
                        let plan = Arc::new(
                            FaultPlan::new(31 + width as u64)
                                .with_seeded(FaultKind::WorkerPanic, 6, 0),
                        );
                        if faults {
                            pool.arm_faults(Arc::clone(&plan));
                        }
                        let (wt, _) = gemv_problem(2026);
                        let eng = LutGemvEngine::with_pool(wt, 3, &pool);
                        let mut out = GemvOutput::new();
                        let stats = eng
                            .gemv_batch_into(&xs, &pool, &mut out)
                            .unwrap_or_else(|e| panic!("dispatch failed ({ctx}): {e}"));
                        pool.disarm_faults();
                        for (bi, want_row) in want.iter().enumerate() {
                            assert_eq!(
                                out.row(bi),
                                want_row.as_slice(),
                                "row {bi} desynced from reference ({ctx})"
                            );
                        }
                        match &baseline_stats {
                            None => baseline_stats = Some(stats),
                            Some(base) => assert_eq!(
                                &stats, base,
                                "GemvStats leaked the dispatch schedule ({ctx})"
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Property 2: exactly-once execution under forced steals and worker
/// panics. Each dispatched item bumps its own atomic counter; after
/// several chaotic rounds every counter equals the round count exactly.
#[test]
fn chaos_and_panics_never_drop_or_double_run_items() {
    const ITEMS: usize = 64;
    const ROUNDS: u32 = 5;
    for width in [2usize, 8] {
        for chaos_seed in [3u64, 17, 40] {
            let pool =
                WorkerPool::with_policy_mode(width, &fake_two_node(width), PoolMode::Steal);
            pool.set_steal_chaos(Some(chaos_seed));
            let plan = Arc::new(
                FaultPlan::new(chaos_seed).with_seeded(FaultKind::WorkerPanic, 5, 0),
            );
            pool.arm_faults(Arc::clone(&plan));
            let counters: Arc<Vec<AtomicU32>> =
                Arc::new((0..ITEMS).map(|_| AtomicU32::new(0)).collect());
            for _ in 0..ROUNDS {
                let got = pool.run_ctx(&counters, ITEMS, |c, i| {
                    c[i].fetch_add(1, Ordering::SeqCst);
                    i
                });
                assert_eq!(got, (0..ITEMS).collect::<Vec<_>>());
            }
            pool.disarm_faults();
            for (i, c) in counters.iter().enumerate() {
                assert_eq!(
                    c.load(Ordering::SeqCst),
                    ROUNDS,
                    "item {i} ran a wrong number of times \
                     (width {width} chaos {chaos_seed}, degraded={})",
                    pool.degraded()
                );
            }
        }
    }
}

const SEED_OLD: u64 = common::SEED;
const SEED_NEW: u64 = 4242;

fn swap_spec() -> DecodeSpec {
    common::tiny_spec(2, KvCacheSpec::q8())
}

fn pre_swap_requests() -> Vec<Request> {
    vec![Request::new(0, vec![3, 7], 5), Request::new(1, vec![9, 2, 4], 6)]
}

fn post_swap_requests() -> Vec<Request> {
    (10..16u64)
        .map(|id| {
            let plen = 1 + (id as usize % 3);
            let prompt: Vec<i32> = (0..plen).map(|p| 2 + id as i32 + p as i32).collect();
            Request::new(id, prompt, 4 + id as usize % 3)
        })
        .collect()
}

/// Offline oracle for one weight generation: the requests through
/// `run_to_completion` on a serial fault-free pool.
fn generation_oracle(
    seed: u64,
    requests: Vec<Request>,
) -> HashMap<RequestId, (Vec<i32>, FinishReason)> {
    let engine =
        TransformerServeEngine::random(swap_spec(), seed, 2, WorkerPool::shared(1)).unwrap();
    let cfg = BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() };
    let mut b = Batcher::new(engine, cfg);
    for r in requests {
        b.submit(r);
    }
    b.run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, (r.tokens, r.finish)))
        .collect()
}

/// Property 3: a live weight swap is generation-exact. Streams admitted
/// before the swap finish on the old weights, streams admitted after run
/// on the new ones, nothing faults, and the old generation is reclaimed.
#[test]
fn hot_swap_mid_stream_is_generation_exact_and_reclaims() {
    let want_old = generation_oracle(SEED_OLD, pre_swap_requests());
    let want_new = generation_oracle(SEED_NEW, post_swap_requests());
    assert!(want_old.values().chain(want_new.values()).all(|(t, f)| {
        !t.is_empty() && *f == FinishReason::MaxTokens
    }));

    for width in [1usize, 4] {
        let ctx = format!("width {width}");
        let engine = TransformerServeEngine::random(
            swap_spec(),
            SEED_OLD,
            2,
            WorkerPool::shared(width),
        )
        .unwrap();
        let fe = ServingFrontend::spawn(engine, ServingConfig::default());

        // Admit both pre-swap requests and *observe* a first token from
        // each, so the swap below provably lands mid-stream: both slots
        // hold old-generation KV state when the new weights arrive.
        let pre: Vec<_> = pre_swap_requests()
            .into_iter()
            .map(|r| fe.submit(r).unwrap())
            .collect();
        let mut first_tokens = Vec::new();
        for h in &pre {
            match h.recv().unwrap() {
                StreamEvent::Token(t) => first_tokens.push((h.id, t)),
                StreamEvent::Done(r) => {
                    panic!("request {} finished before the swap ({ctx}): {r:?}", h.id)
                }
            }
        }

        fe.swap_weights(SEED_NEW).unwrap();

        let post: Vec<_> = post_swap_requests()
            .into_iter()
            .map(|r| fe.submit(r).unwrap())
            .collect();

        // Pre-swap streams must finish on the OLD weights, untouched by
        // the swap. (The first token was consumed above, so `wait`'s
        // streamed tail is the response minus that token.)
        for h in pre {
            let id = h.id;
            let (tail, resp) = h.wait().unwrap();
            let first = first_tokens.iter().find(|(i, _)| *i == id).unwrap().1;
            assert_eq!(resp.tokens.first(), Some(&first), "{ctx}");
            assert_eq!(tail, resp.tokens[1..], "stream {id} desynced ({ctx})");
            let (want_tokens, want_finish) = &want_old[&id];
            assert_eq!(
                (&resp.tokens, &resp.finish),
                (want_tokens, want_finish),
                "pre-swap stream {id} left its weight generation ({ctx})"
            );
        }
        // Post-swap streams must match the NEW-generation oracle.
        for h in post {
            let id = h.id;
            let (streamed, resp) = h.wait().unwrap();
            assert_eq!(streamed, resp.tokens, "stream {id} desynced ({ctx})");
            let (want_tokens, want_finish) = &want_new[&id];
            assert_eq!(
                (&resp.tokens, &resp.finish),
                (want_tokens, want_finish),
                "post-swap stream {id} is not on the new weights ({ctx})"
            );
        }

        let metrics = fe.shutdown();
        assert_eq!(metrics.completed, 8, "{ctx}");
        assert_eq!(
            (metrics.shed, metrics.deadline_exceeded, metrics.engine_faults),
            (0, 0, 0),
            "{ctx}"
        );
        let pool = metrics.pool.as_ref().unwrap_or_else(|| panic!("no pool snapshot ({ctx})"));
        assert!(pool.dispatches > 0, "{ctx}");
        let rs = metrics
            .reclaim
            .unwrap_or_else(|| panic!("no reclaim snapshot ({ctx})"));
        assert!(rs.retired >= 1, "old generation never retired ({ctx})");
        assert_eq!(rs.reclaimed, rs.retired, "retired generation leaked ({ctx})");
        assert_eq!((rs.pending, rs.active_pins), (0, 0), "{ctx}");
    }
}

/// Property 4: reclamation soak. Readers hammer the GEMV path while the
/// main thread republishes two alternating weight generations. Every
/// whole output must match exactly one generation's reference (pinned
/// snapshots are immutable — a torn old/new mix is impossible to
/// produce without breaking the epoch), and afterwards every retired
/// snapshot has been dropped.
#[test]
fn publish_soak_has_no_torn_reads_and_reclaims_every_generation() {
    const PUBLISHES: usize = 20;
    let (wt0, xs) = gemv_problem(77);
    let (wt1_src, _) = gemv_problem(78);
    let want0: Vec<Vec<f32>> = xs.iter().map(|x| reference_gemv(&wt0, x)).collect();
    let want1: Vec<Vec<f32>> = xs.iter().map(|x| reference_gemv(&wt1_src, x)).collect();

    let pool = Arc::new(WorkerPool::with_policy_mode(4, &NumaPolicy::Off, PoolMode::Steal));
    let eng = Arc::new(LutGemvEngine::with_pool(wt0, 3, &pool));
    let stale: Weak<QuantizedMatrix> = Arc::downgrade(&eng.weights());
    let xs = Arc::new(xs);
    let want0 = Arc::new(want0);
    let want1 = Arc::new(want1);

    let readers: Vec<_> = (0..2)
        .map(|r| {
            let (eng, pool, xs) = (Arc::clone(&eng), Arc::clone(&pool), Arc::clone(&xs));
            let (want0, want1) = (Arc::clone(&want0), Arc::clone(&want1));
            thread::spawn(move || {
                let mut out = GemvOutput::new();
                for it in 0..200 {
                    eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
                    let whole_gen = [&want0, &want1].iter().position(|want| {
                        (0..xs.len()).all(|bi| out.row(bi) == want[bi].as_slice())
                    });
                    assert!(
                        whole_gen.is_some(),
                        "reader {r} iteration {it}: output is a torn mix of generations"
                    );
                }
            })
        })
        .collect();

    for i in 0..PUBLISHES {
        let (src, _) = if i % 2 == 0 { gemv_problem(78) } else { gemv_problem(77) };
        eng.publish_weights(src, &pool).unwrap();
    }
    for r in readers {
        r.join().unwrap();
    }
    // Readers are gone; one collect pass (piggybacked on a throwaway
    // GEMV's guard drop) must leave nothing pending.
    let _ = eng.gemv_batch_into(&xs, &pool, &mut GemvOutput::new()).unwrap();
    let rs = eng.reclaim_stats();
    assert_eq!(rs.retired, PUBLISHES as u64, "one retire per publish");
    assert_eq!(rs.reclaimed, rs.retired, "retired snapshots leaked");
    assert_eq!((rs.pending, rs.active_pins), (0, 0));
    assert!(
        stale.upgrade().is_none(),
        "the original weight generation is still reachable after {PUBLISHES} publishes"
    );
}
