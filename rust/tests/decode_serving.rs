//! Conformance suite for multi-layer KV-cached decode on the LUT serving
//! path (the repo's core invariant, extended to the real transformer
//! workload):
//!
//! - token streams are **bit-identical at pool widths 1/2/8**, for both
//!   fp16- and q8-backed KV caches;
//! - **batched decode equals isolated decode** bit-for-bit;
//! - every projection of every layer (Q/K/V/O/gate/up/down + head) runs
//!   on the LUT path, visible in the per-layer `GemvStats` rollup;
//! - the KV store's element allocation matches the accounting —
//!   `KvCacheSpec::seq_bytes` on the contiguous slab, pool pages ×
//!   `KvCacheSpec::page_bytes` on the paged store (whichever `SAIL_KV`
//!   selected for the leg);
//! - admission hardening holds on the real engine: over-long prompts
//!   finish `ContextFull` during prefill (no out-of-window KV write, which
//!   the cache would catch with a panic), and empty prompts are answered
//!   without taking the server worker down.

mod common;

use std::collections::HashMap;

use sail::coordinator::{
    Batcher, BatcherConfig, FinishReason, Request, Server, TransformerServeEngine,
};
use sail::model::{DecodeSpec, KvCacheSpec, KvLayout};
use sail::runtime::NumaPolicy;

use common::{engine_placed, mixed_requests};

/// 3 decoder layers at mixed per-layer precision (Q8/Q4/Q6), hidden 32,
/// GQA (4 query heads over 2 KV heads), 24-token context.
fn spec(kv: KvCacheSpec) -> DecodeSpec {
    common::tiny_spec(3, kv)
}

fn engine(kv: KvCacheSpec, batch: usize, width: usize) -> TransformerServeEngine {
    common::engine(spec(kv), batch, width)
}

fn requests() -> Vec<Request> {
    mixed_requests(false)
}

fn run_tokens(
    kv: KvCacheSpec,
    batch: usize,
    width: usize,
    reqs: &[Request],
) -> HashMap<u64, Vec<i32>> {
    let mut b = Batcher::new(engine(kv, batch, width), BatcherConfig::default());
    for r in reqs {
        b.submit(r.clone());
    }
    let done = b.run_to_completion().unwrap();
    assert_eq!(done.len(), reqs.len());
    done.into_iter()
        .inspect(|r| assert!(!r.tokens.is_empty(), "request {} got no tokens", r.id))
        .map(|r| (r.id, r.tokens))
        .collect()
}

#[test]
fn token_streams_bit_identical_across_pool_widths() {
    let reqs = requests();
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        let base = run_tokens(kv, 3, 1, &reqs);
        for width in [2usize, 8] {
            let got = run_tokens(kv, 3, width, &reqs);
            assert_eq!(got, base, "{kv:?}: width {width} diverged from width 1");
        }
    }
}

#[test]
fn token_streams_bit_identical_across_numa_placements() {
    // The NUMA acceptance bar on the serving path: identical token
    // streams whether workers are unpinned (SAIL_NUMA=off), auto-placed,
    // or forced onto explicit fake node groups with per-node weight
    // shards — at every pool width. Placement moves bytes, never tokens.
    let reqs = requests();
    let fake = NumaPolicy::Explicit(vec![vec![0], vec![1]]);
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        let run = |policy: &NumaPolicy, width: usize| {
            let mut b =
                Batcher::new(engine_placed(spec(kv), 3, width, policy), BatcherConfig::default());
            for r in &reqs {
                b.submit(r.clone());
            }
            let done = b.run_to_completion().unwrap();
            done.into_iter().map(|r| (r.id, r.tokens)).collect::<HashMap<_, _>>()
        };
        let base = run(&NumaPolicy::Off, 1);
        for policy in [NumaPolicy::Off, NumaPolicy::Auto, fake.clone()] {
            for width in [1usize, 2, 8] {
                assert_eq!(
                    run(&policy, width),
                    base,
                    "{kv:?}: policy {policy} width {width} changed the token stream"
                );
            }
        }
    }
}

#[test]
fn batched_decode_matches_isolated_decode() {
    let reqs = requests();
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        // Isolated: fresh single-slot engine per request, serial pool.
        let mut isolated = HashMap::new();
        for r in &reqs {
            isolated.extend(run_tokens(kv, 1, 1, std::slice::from_ref(r)));
        }
        // Co-scheduled: 4 slots, threaded pool, all requests at once.
        let batched = run_tokens(kv, 4, 2, &reqs);
        assert_eq!(batched, isolated, "{kv:?}: co-scheduling changed a token stream");
    }
}

#[test]
fn every_projection_ran_on_the_lut_path() {
    let mut b = Batcher::new(engine(KvCacheSpec::q8(), 2, 2), BatcherConfig::default());
    for r in requests() {
        b.submit(r);
    }
    b.run_to_completion().unwrap();
    let stats = b.engine().stats();
    assert_eq!(stats.layers.len(), 3);
    for (l, layer) in stats.layers.iter().enumerate() {
        for (name, s) in layer.projections() {
            assert!(s.luts_built > 0, "layer {l} projection {name} built no LUTs");
            assert!(s.lut_reads > 0, "layer {l} projection {name} read no LUTs");
        }
    }
    assert!(stats.head.lut_reads > 0, "output head never ran on the LUT path");
    assert!(stats.tokens >= 6 * 4, "fewer decode tokens than the workload implies");
}

#[test]
fn kv_allocation_matches_seq_bytes_accounting() {
    // Layout-aware: the engine resolves its store from SAIL_KV, so the
    // paged CI legs exercise the page-pool arithmetic here. Contiguous
    // allocates exactly batch × seq_bytes; the paged pool allocates
    // pool_pages whole pages (per-slot worst case + shared-page budget).
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        for batch in [1usize, 3] {
            let e = engine(kv, batch, 1);
            let cfg = e.model().spec().to_model_config();
            let got = e.model().kv().data_bytes();
            match e.model().kv().layout() {
                KvLayout::Contiguous => assert_eq!(
                    got,
                    kv.batch_bytes(&cfg, cfg.max_context, batch),
                    "{kv:?} batch {batch}: allocation disagrees with seq_bytes accounting"
                ),
                KvLayout::Paged { page_tokens } => {
                    let pool = e.model().kv().paged().unwrap().pool_pages() as u64;
                    assert_eq!(
                        got,
                        pool * kv.page_bytes(&cfg, page_tokens),
                        "{kv:?} batch {batch}: pool allocation disagrees with \
                         page_bytes accounting"
                    );
                }
            }
        }
    }
}

#[test]
fn overlong_prompt_finishes_context_full_without_touching_the_window() {
    // Pre-hardening, prefill walked past max_context and the now-real KV
    // cache would abort on the out-of-window write; the batcher must stop
    // it first.
    let ctx = spec(KvCacheSpec::q8()).max_context;
    let mut b = Batcher::new(engine(KvCacheSpec::q8(), 2, 2), BatcherConfig::default());
    b.submit(Request::new(0, (0..ctx as i32 + 6).collect(), 5));
    b.submit(Request::new(1, vec![3, 4], 3));
    let done = b.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    let long = done.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(long.finish, FinishReason::ContextFull);
    assert!(long.tokens.is_empty(), "no logits were ever sampled for the over-long prompt");
    let ok = done.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(ok.finish, FinishReason::MaxTokens);
    assert_eq!(ok.tokens.len(), 3);
}

#[test]
fn prompt_exactly_context_length_yields_one_token() {
    let ctx = spec(KvCacheSpec::fp16()).max_context;
    let mut b = Batcher::new(engine(KvCacheSpec::fp16(), 1, 1), BatcherConfig::default());
    b.submit(Request::new(0, (0..ctx as i32).collect(), 5));
    let done = b.run_to_completion().unwrap();
    assert_eq!(done[0].finish, FinishReason::ContextFull);
    assert_eq!(done[0].tokens.len(), 1, "the last prompt position still yields its logits");
}

#[test]
fn empty_prompt_through_the_server_keeps_the_worker_alive() {
    let server = Server::spawn(engine(KvCacheSpec::q8(), 2, 2), BatcherConfig::default());
    server.submit(Request::new(0, vec![], 4)).unwrap();
    server.submit(Request::new(1, vec![7, 8], 3)).unwrap();
    let mut got = HashMap::new();
    for _ in 0..2 {
        let r = server.recv().unwrap();
        got.insert(r.id, r);
    }
    assert_eq!(got[&0].finish, FinishReason::EmptyPrompt);
    assert!(got[&0].tokens.is_empty());
    assert_eq!(got[&1].finish, FinishReason::MaxTokens);
    assert_eq!(got[&1].tokens.len(), 3);
    // The worker survived the malformed request and still drains cleanly.
    server.submit(Request::new(2, vec![5], 2)).unwrap();
    let r = server.recv().unwrap();
    assert_eq!(r.id, 2);
    let metrics = server.shutdown();
    assert_eq!(metrics.completed, 3);
}

#[test]
fn kv_precision_changes_the_model_but_each_is_deterministic() {
    // fp16 and q8 KV round history differently, so the streams may
    // legitimately differ — but each precision must be exactly
    // reproducible run-to-run.
    let reqs = requests();
    let f1 = run_tokens(KvCacheSpec::fp16(), 2, 2, &reqs);
    let f2 = run_tokens(KvCacheSpec::fp16(), 2, 2, &reqs);
    assert_eq!(f1, f2);
    let q1 = run_tokens(KvCacheSpec::q8(), 2, 2, &reqs);
    let q2 = run_tokens(KvCacheSpec::q8(), 2, 2, &reqs);
    assert_eq!(q1, q2);
}
