//! Integration suite for NUMA-aware tile placement.
//!
//! Covers the full placement stack end to end:
//!
//! - policy → placement planning against *fixture* topologies (so
//!   multi-node behaviour is tested on single-node CI hosts),
//! - pool construction under every policy (worker groups, pinning is
//!   best-effort, routed dispatch),
//! - engine weight sharding (shard bounds == the placement contract,
//!   per-shard arenas actually used, steady-state reuse per node),
//! - decode-level bit-identity: `LutTransformer` token streams are
//!   identical under `off` / `auto` / explicit placements at pool widths
//!   1/2/8 — placement is invisible in the output, by construction.
//!
//! The environment-variable form of the override (`SAIL_NUMA=off|auto|…`)
//! selects between exactly the [`NumaPolicy`] values constructed directly
//! here (`NumaPolicy::from_env` is a thin parse, unit-tested in
//! `runtime::topology`); tests build policies explicitly so they stay
//! parallel-safe, and the CI matrix additionally runs the whole suite
//! under `SAIL_NUMA=off` and `SAIL_NUMA=auto` legs.

use std::sync::Arc;

use sail::coordinator::argmax_logits;
use sail::lutgemv::{GemvOutput, LutGemvEngine};
use sail::model::{DecodeItem, DecodeSpec, KvCacheSpec, LutTransformer};
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::{NumaPolicy, Placement, WorkerPool};
use sail::util::Prng;

fn fake_two_node() -> NumaPolicy {
    NumaPolicy::Explicit(vec![vec![0], vec![1]])
}

#[test]
fn every_policy_builds_a_working_pool() {
    for policy in [
        NumaPolicy::Off,
        NumaPolicy::Auto,
        fake_two_node(),
        NumaPolicy::Explicit(vec![vec![0, 1], vec![2], vec![3]]),
    ] {
        for threads in [1usize, 2, 8] {
            let pool = WorkerPool::with_policy(threads, &policy);
            assert_eq!(pool.threads(), threads, "{policy} t={threads}");
            assert!(pool.nodes() >= 1);
            assert!(pool.nodes() <= threads.max(1));
            assert_eq!(pool.placement().total_workers(), threads);
            let got = pool.run(19, |i| i * 3 + 1);
            assert_eq!(got, (0..19).map(|i| i * 3 + 1).collect::<Vec<_>>());
        }
    }
}

#[test]
fn engine_sharding_follows_the_placement_contract() {
    let mut prng = Prng::new(31);
    let w: Vec<f32> = (0..29 * 64).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, 29, 64, QuantLevel::Q4, 32);
    let policy = NumaPolicy::Explicit(vec![vec![0], vec![1], vec![2]]);
    let pool = WorkerPool::with_policy(6, &policy);
    let eng = LutGemvEngine::with_pool(wt, 4, &pool);
    assert_eq!(eng.shard_count(), pool.nodes());
    assert_eq!(eng.shard_bounds(), pool.placement().shard_ranges(29));
}

#[test]
fn per_node_arenas_reach_steady_state() {
    // On a placed engine each node group has its own scratch arena; after
    // warmup, repeated dispatches on the placed pool must stop allocating
    // (the per-node analogue of the single-arena steady-state test).
    let mut prng = Prng::new(33);
    let w: Vec<f32> = (0..40 * 64).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, 40, 64, QuantLevel::Q4, 32);
    let xs: Vec<QuantizedVector> = (0..4)
        .map(|_| {
            let x: Vec<f32> = (0..64).map(|_| prng.normal() as f32).collect();
            QuantizedVector::quantize(&x)
        })
        .collect();
    let pool = WorkerPool::with_policy(4, &fake_two_node());
    let mut eng = LutGemvEngine::with_pool(wt, 4, &pool);
    eng.tile_cols = 8;
    let mut out = GemvOutput::new();
    let baseline = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
    for _ in 0..10 {
        assert_eq!(eng.gemv_batch_into(&xs, &pool, &mut out).unwrap(), baseline);
    }
    let after_warm =
        (eng.scratch_arena().scratches_created(), eng.scratch_arena().out_bufs_created());
    for _ in 0..10 {
        assert_eq!(eng.gemv_batch_into(&xs, &pool, &mut out).unwrap(), baseline);
    }
    assert_eq!(
        (eng.scratch_arena().scratches_created(), eng.scratch_arena().out_bufs_created()),
        after_warm,
        "steady-state placed GEMV allocated fresh buffers"
    );
}

#[test]
fn decode_streams_identical_across_placements_and_widths() {
    // The tentpole acceptance criterion at the model level: greedy decode
    // over the full multi-layer KV-cached transformer yields the same
    // token stream under off/auto/explicit placement at widths 1/2/8.
    let spec = || DecodeSpec::tiny(3, KvCacheSpec::q8());
    let run = |policy: &NumaPolicy, width: usize| -> Vec<Vec<i32>> {
        let pool = Arc::new(WorkerPool::with_policy(width, policy));
        let mut m = LutTransformer::random(spec(), 55, 2, pool).unwrap();
        let mut toks = vec![5i32, 19];
        let mut stream = Vec::new();
        for pos in 0..12usize {
            let items: Vec<DecodeItem> = toks
                .iter()
                .enumerate()
                .map(|(s, &t)| DecodeItem { slot: s, token: t, pos })
                .collect();
            m.step(&items).unwrap();
            toks = (0..2).map(|s| argmax_logits(m.logits().row(s))).collect();
            stream.push(toks.clone());
        }
        stream
    };
    let base = run(&NumaPolicy::Off, 1);
    for policy in [NumaPolicy::Off, NumaPolicy::Auto, fake_two_node()] {
        for width in [1usize, 2, 8] {
            assert_eq!(
                run(&policy, width),
                base,
                "decode stream drifted at policy {policy} width {width}"
            );
        }
    }
}

#[test]
fn auto_on_multi_node_fixture_pins_and_shards() {
    // `auto` resolved against a fixture 2-node topology must produce a
    // pinned 2-group placement whose shard ranges halve the columns —
    // the exact plan a real dual-socket host would get.
    use sail::runtime::Topology;
    let root = std::env::temp_dir()
        .join(format!("sail-numa-fixture-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for (id, list) in [(0, "0-3\n"), (1, "4-7\n")] {
        let dir = root.join(format!("node{id}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("cpulist"), list).unwrap();
    }
    let topo = Topology::from_sysfs_root(&root).unwrap();
    let placement = Placement::plan_on(&topo, 8);
    assert!(placement.pinned());
    assert_eq!(placement.nodes().len(), 2);
    assert_eq!(placement.shard_ranges(128), vec![(0, 64), (64, 128)]);
    // And a pool spawned from that plan serves work correctly even though
    // this host does not actually have those CPUs (pinning best-effort).
    let pool = WorkerPool::with_placement(placement);
    assert_eq!(pool.nodes(), 2);
    let got = pool.run(11, |i| i + 100);
    assert_eq!(got, (0..11).map(|i| i + 100).collect::<Vec<_>>());
    std::fs::remove_dir_all(&root).ok();
}
