//! Conformance suite for chunked multi-token prefill on the LUT serving
//! path (the PR-5 tentpole):
//!
//! - token streams are **bit-identical across prefill chunk sizes
//!   1/4/16/64**, at pool widths 1/2/8, for fp16- and q8-backed KV, under
//!   NUMA placement off and auto — chunking, like threading and
//!   placement, moves work, never tokens;
//! - mixed prefill+decode iterations (continuous batching with a
//!   per-iteration row budget) equal isolated one-request runs;
//! - admission semantics survive chunking: over-long prompts still finish
//!   `ContextFull` with zero tokens *before* any out-of-window KV write
//!   (the real cache would panic on one), empty prompts still answer
//!   `EmptyPrompt`, exact-window prompts still yield their one token;
//! - TTFT sanity: iterations-to-first-token is monotone non-increasing in
//!   the chunk size;
//! - the amortization is real: layer LUT builds fall exactly 1/C with
//!   chunk size C (LUT builds per GEMV call don't depend on rows).

mod common;

use std::collections::HashMap;

use sail::coordinator::{
    Batcher, BatcherConfig, FinishReason, MockEngine, Request, TransformerServeEngine,
};
use sail::model::{DecodeSpec, KvCacheSpec};
use sail::runtime::NumaPolicy;

/// 2 decoder layers at mixed precision, hidden 32, GQA, 24-token window.
fn spec(kv: KvCacheSpec) -> DecodeSpec {
    common::tiny_spec(2, kv)
}

fn engine(
    kv: KvCacheSpec,
    batch: usize,
    width: usize,
    policy: &NumaPolicy,
) -> TransformerServeEngine {
    common::engine_placed(spec(kv), batch, width, policy)
}

fn config(chunk: usize, rows: usize) -> BatcherConfig {
    // Explicit chunk/rows so every cell of the matrix is what it says it
    // is, independent of the SAIL_PREFILL_CHUNK CI leg.
    BatcherConfig { prefill_chunk: chunk, iteration_rows: rows, ..BatcherConfig::default() }
}

/// Prompt lengths straddle every tested chunk size (1/4/16/64 against a
/// 24-token window); budgets keep every request inside the window.
fn requests() -> Vec<Request> {
    let lens = [1usize, 3, 7, 12, 17];
    lens.iter()
        .enumerate()
        .map(|(i, &plen)| {
            let prompt: Vec<i32> = (0..plen).map(|p| 2 + 5 * i as i32 + p as i32).collect();
            Request::new(i as u64, prompt, 2 + i % 3)
        })
        .collect()
}

fn run_tokens(
    kv: KvCacheSpec,
    batch: usize,
    width: usize,
    policy: &NumaPolicy,
    chunk: usize,
    reqs: &[Request],
) -> HashMap<u64, Vec<i32>> {
    let mut b = Batcher::new(engine(kv, batch, width, policy), config(chunk, usize::MAX));
    for r in reqs {
        b.submit(r.clone());
    }
    let done = b.run_to_completion().unwrap();
    assert_eq!(done.len(), reqs.len());
    done.into_iter()
        .inspect(|r| assert!(!r.tokens.is_empty(), "request {} got no tokens", r.id))
        .map(|r| (r.id, r.tokens))
        .collect()
}

#[test]
fn token_streams_bit_identical_across_chunk_sizes_widths_kv_and_placement() {
    let reqs = requests();
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        let base = run_tokens(kv, 3, 1, &NumaPolicy::Off, 1, &reqs);
        for policy in [NumaPolicy::Off, NumaPolicy::Auto] {
            for width in [1usize, 2, 8] {
                for chunk in [1usize, 4, 16, 64] {
                    assert_eq!(
                        run_tokens(kv, 3, width, &policy, chunk, &reqs),
                        base,
                        "{kv:?}: chunk {chunk} width {width} policy {policy} diverged \
                         from chunk-1 width-1"
                    );
                }
            }
        }
    }
}

#[test]
fn mixed_prefill_decode_iterations_match_isolated_runs() {
    // Two long prompts and two short ones co-scheduled on 3 slots with a
    // tight per-iteration row budget: prefill chunks and single-token
    // decode rows share iterations, and every stream still equals its
    // isolated chunk-1 single-slot run.
    let reqs = requests();
    for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
        let mut isolated = HashMap::new();
        for r in &reqs {
            isolated.extend(run_tokens(kv, 1, 1, &NumaPolicy::Off, 1, std::slice::from_ref(r)));
        }
        let mut b = Batcher::new(engine(kv, 3, 2, &NumaPolicy::Off), config(8, 10));
        for r in &reqs {
            b.submit(r.clone());
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), reqs.len());
        for r in done {
            assert_eq!(
                r.tokens, isolated[&r.id],
                "{kv:?}: request {} diverged under mixed chunked batching",
                r.id
            );
        }
    }
}

#[test]
fn admission_semantics_survive_chunking() {
    // The KV cache asserts on any out-of-window write, so completing at
    // all proves the chunked prefill path never touched position
    // `max_context`.
    let ctx = spec(KvCacheSpec::q8()).max_context;
    for chunk in [4usize, 16, 64] {
        let mut b = Batcher::new(
            engine(KvCacheSpec::q8(), 2, 2, &NumaPolicy::Off),
            config(chunk, usize::MAX),
        );
        b.submit(Request::new(0, (0..ctx as i32 + 6).collect(), 5)); // over-long
        b.submit(Request::new(1, vec![], 4)); // empty
        b.submit(Request::new(2, vec![3, 4, 5], 3)); // ordinary
        b.submit(Request::new(3, (0..ctx as i32).collect(), 5)); // exact fit
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 4, "chunk {chunk}");
        let by_id: HashMap<u64, _> = done.into_iter().map(|r| (r.id, r)).collect();
        assert_eq!(by_id[&0].finish, FinishReason::ContextFull, "chunk {chunk}");
        assert!(by_id[&0].tokens.is_empty(), "chunk {chunk}: over-long prompt sampled logits");
        assert_eq!(by_id[&1].finish, FinishReason::EmptyPrompt, "chunk {chunk}");
        assert!(by_id[&1].tokens.is_empty());
        assert_eq!(by_id[&2].finish, FinishReason::MaxTokens, "chunk {chunk}");
        assert_eq!(by_id[&2].tokens.len(), 3);
        assert_eq!(by_id[&3].finish, FinishReason::ContextFull, "chunk {chunk}");
        assert_eq!(
            by_id[&3].tokens.len(),
            1,
            "chunk {chunk}: the exact-window prompt's last position still yields its token"
        );
    }
}

#[test]
fn ttft_iterations_monotone_non_increasing_in_chunk() {
    // Wall-clock TTFT is noisy in CI; iterations-to-first-token is its
    // exact deterministic skeleton. With a single 20-token prompt and a
    // 1-token budget the whole run is prefill: ceil(20 / C) iterations.
    let mut prev = u64::MAX;
    for chunk in [1usize, 4, 16, 64] {
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), config(chunk, usize::MAX));
        b.submit(Request::new(0, (1..=20).collect(), 1));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 1);
        assert_eq!(b.iterations(), 20u64.div_ceil(chunk.min(20) as u64), "chunk {chunk}");
        assert!(
            b.iterations() <= prev,
            "chunk {chunk}: TTFT iterations regressed ({} > {prev})",
            b.iterations()
        );
        prev = b.iterations();
    }
}

#[test]
fn lut_builds_amortize_with_chunk_size() {
    // The acceptance metric behind the bench matrix: serving the same
    // 16-token prompt with chunk C must build exactly 1/C of the layer
    // LUTs that chunk-1 builds (LUT construction per GEMV call is
    // row-count-independent; each chunk's LUT is reused by every row).
    let prompt: Vec<i32> = (1..=16).collect();
    let luts_with_chunk = |chunk: usize| -> (u64, Vec<i32>) {
        let mut b = Batcher::new(
            engine(KvCacheSpec::q8(), 1, 1, &NumaPolicy::Off),
            config(chunk, usize::MAX),
        );
        b.submit(Request::new(0, prompt.clone(), 1));
        let done = b.run_to_completion().unwrap();
        let stats = b.engine().stats();
        let layer_luts: u64 = stats.layers.iter().map(|l| l.total().luts_built).sum();
        (layer_luts, done.into_iter().next().unwrap().tokens)
    };
    let (luts1, toks1) = luts_with_chunk(1);
    let (luts4, toks4) = luts_with_chunk(4);
    let (luts16, toks16) = luts_with_chunk(16);
    assert_eq!(toks1, toks4);
    assert_eq!(toks1, toks16);
    assert_eq!(luts1, 4 * luts4, "chunk 4 must build exactly 1/4 of the layer LUTs");
    assert_eq!(luts1, 16 * luts16, "chunk 16 must build exactly 1/16 of the layer LUTs");
}
