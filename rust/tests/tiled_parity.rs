//! Parity suite for the tiled multi-threaded LUT-GEMV execution backend.
//!
//! The acceptance bar for the backend is *bit-exactness*: at every thread
//! count, for every quant level / NBW / group size / tile width — and,
//! since the NUMA placement layer, for every placement policy and weight
//! sharding — the tiled path must produce outputs identical to the scalar
//! engine and to the naive integer-dot-product reference, and its
//! `GemvStats` must not depend on how work was partitioned or where it
//! ran.

use sail::lutgemv::engine::{reference_gemv, GemvStats, LutGemvEngine};
use sail::lutgemv::GemvOutput;
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::{NumaPolicy, WorkerPool};
use sail::util::{propcheck, Prng};

fn random_setup(
    prng: &mut Prng,
    n: usize,
    k: usize,
    level: QuantLevel,
    group: usize,
    batch: usize,
) -> (QuantizedMatrix, Vec<QuantizedVector>) {
    let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, n, k, level, group);
    let xs = (0..batch)
        .map(|_| {
            let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
            QuantizedVector::quantize(&x)
        })
        .collect();
    (wt, xs)
}

#[test]
fn tiled_backend_bit_identical_property() {
    propcheck::check(
        "tiled-gemv-parity",
        propcheck::Config { cases: 50, seed: 2024 },
        |p, _| {
            let level = QuantLevel::ALL[p.usize_in(0, 6)];
            let nbw = p.usize_in(1, 6) as u32;
            let group = [8usize, 16, 32][p.usize_in(0, 3)];
            let k = group * p.usize_in(1, 4);
            let n = p.usize_in(1, 40);
            let batch = p.usize_in(1, 6);
            let tile_cols = p.usize_in(1, 9);
            let seed = p.next_u64();
            (level, nbw, group, k, n, batch, tile_cols, seed)
        },
        |&(level, nbw, group, k, n, batch, tile_cols, seed)| {
            let mut prng = Prng::new(seed);
            let (wt, xs) = random_setup(&mut prng, n, k, level, group, batch);
            let mut eng = LutGemvEngine::new(wt, nbw);
            eng.tile_cols = tile_cols;
            let (serial, serial_stats) = eng.gemv_batch(&xs);
            // Scalar engine vs naive reference, bit-for-bit.
            for (bi, x) in xs.iter().enumerate() {
                let want = reference_gemv(&eng.weights(), x);
                if serial.row(bi) != want.as_slice() {
                    return Err(format!("scalar vs reference mismatch at level={level} nbw={nbw}"));
                }
            }
            // Threaded backend vs scalar, at several pool widths.
            for threads in [1usize, 2, 8] {
                let pool = WorkerPool::new(threads);
                let mut out = GemvOutput::new();
                let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
                if out != serial {
                    return Err(format!("output drift at threads={threads} tile_cols={tile_cols}"));
                }
                if stats != serial_stats {
                    return Err(format!("stats drift at threads={threads}: {stats:?} vs {serial_stats:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tiled_backend_bit_identical_with_prt() {
    propcheck::check(
        "tiled-gemv-parity-prt",
        propcheck::Config { cases: 25, seed: 2025 },
        |p, _| {
            let nbw = p.usize_in(1, 5) as u32;
            let n = p.usize_in(1, 24);
            let batch = p.usize_in(1, 5);
            let tile_cols = p.usize_in(1, 7);
            let seed = p.next_u64();
            (nbw, n, batch, tile_cols, seed)
        },
        |&(nbw, n, batch, tile_cols, seed)| {
            let mut prng = Prng::new(seed);
            let (wt, xs) = random_setup(&mut prng, n, 64, QuantLevel::Q4, 32, batch);
            let mut eng = LutGemvEngine::new(wt, nbw);
            eng.use_prt = true;
            eng.tile_cols = tile_cols;
            let (serial, serial_stats) = eng.gemv_batch(&xs);
            for threads in [2usize, 8] {
                let pool = WorkerPool::new(threads);
                let mut out = GemvOutput::new();
                let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
                if out != serial {
                    return Err(format!("PRT output drift at threads={threads}"));
                }
                if stats != serial_stats {
                    return Err(format!(
                        "PRT stats drift at threads={threads}: {stats:?} vs {serial_stats:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn stats_invariant_across_thread_counts_fixed_shape() {
    // The §Perf acceptance shape, shrunk: stats must be a function of the
    // problem, not of the execution schedule.
    let mut prng = Prng::new(88);
    let (wt, xs) = random_setup(&mut prng, 128, 128, QuantLevel::Q4, 32, 8);
    let eng = LutGemvEngine::new(wt, 4);
    let mut all_stats: Vec<GemvStats> = Vec::new();
    for threads in [1usize, 2, 4, 8, 16] {
        let pool = WorkerPool::new(threads);
        let mut out = GemvOutput::new();
        all_stats.push(eng.gemv_batch_into(&xs, &pool, &mut out).unwrap());
    }
    {
        // Ambient width too (SAIL_POOL_THREADS in the CI matrix).
        let pool = WorkerPool::auto();
        let mut out = GemvOutput::new();
        all_stats.push(eng.gemv_batch_into(&xs, &pool, &mut out).unwrap());
    }
    for (i, s) in all_stats.iter().enumerate().skip(1) {
        assert_eq!(*s, all_stats[0], "stats at pool #{i} differ");
    }
    // Sanity: the counters describe the work actually done.
    // chunks/column = (128/32 groups × 32/4 chunks) = 32; columns = 128.
    assert_eq!(all_stats[0].luts_built, 32 * 128);
    assert_eq!(all_stats[0].lut_reads, 32 * 128 * 8 * 8); // ×planes ×batch
}

#[test]
fn numa_sharded_backend_bit_identical_property() {
    // NUMA placement is a locality lever only: an engine sharded for any
    // node-group layout, dispatched on pinned or unpinned pools of any
    // width, must reproduce the serial single-shard engine bit-for-bit —
    // outputs and stats. Fake explicit maps let this run (and mean
    // something) on single-node CI hosts: routing, sharding, and the
    // affinity calls all exercise the real code paths.
    propcheck::check(
        "numa-sharded-gemv-parity",
        propcheck::Config { cases: 30, seed: 4046 },
        |p, _| {
            let level = QuantLevel::ALL[p.usize_in(0, 6)];
            let nbw = p.usize_in(1, 5) as u32;
            let group = [8usize, 16, 32][p.usize_in(0, 3)];
            let k = group * p.usize_in(1, 4);
            let n = p.usize_in(1, 40);
            let batch = p.usize_in(1, 5);
            let tile_cols = p.usize_in(1, 9);
            let groups = p.usize_in(2, 5); // 2..4 fake node groups
            let threads = p.usize_in(2, 9);
            let seed = p.next_u64();
            (level, nbw, group, k, n, batch, tile_cols, groups, threads, seed)
        },
        |&(level, nbw, group, k, n, batch, tile_cols, groups, threads, seed)| {
            let mut prng = Prng::new(seed);
            let (wt, xs) = random_setup(&mut prng, n, k, level, group, batch);
            let reference = LutGemvEngine::new(wt.clone(), nbw);
            let (want, want_stats) = reference.gemv_batch(&xs);

            let map: Vec<Vec<usize>> = (0..groups).map(|g| vec![g]).collect();
            let policy = NumaPolicy::Explicit(map);
            let pool = WorkerPool::with_policy(threads, &policy);
            let mut eng = LutGemvEngine::with_pool(wt, nbw, &pool);
            eng.tile_cols = tile_cols;
            if eng.shard_count() != pool.nodes() {
                return Err(format!(
                    "engine built {} shards for a {}-group pool",
                    eng.shard_count(),
                    pool.nodes()
                ));
            }
            let mut out = GemvOutput::new();
            // Routed on the placed pool, fallback on a plain one, serial.
            let off = WorkerPool::with_policy(threads, &NumaPolicy::Off);
            for (mode, p) in
                [("routed", &pool), ("fallback", &off), ("serial", &WorkerPool::serial())]
            {
                let stats = eng.gemv_batch_into(&xs, p, &mut out).unwrap();
                if out != want {
                    return Err(format!("{mode} output drift (groups={groups})"));
                }
                if stats != want_stats {
                    return Err(format!("{mode} stats drift: {stats:?} vs {want_stats:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shard_bounds_partition_the_columns() {
    let mut prng = Prng::new(404);
    let (wt, _) = random_setup(&mut prng, 53, 64, QuantLevel::Q4, 32, 1);
    let policy = NumaPolicy::Explicit(vec![vec![0, 1], vec![2], vec![3]]);
    let pool = WorkerPool::with_policy(4, &policy);
    let eng = LutGemvEngine::with_pool(wt, 4, &pool);
    let bounds = eng.shard_bounds();
    assert_eq!(bounds.len(), 3);
    assert_eq!(bounds.first().unwrap().0, 0);
    assert_eq!(bounds.last().unwrap().1, 53);
    for w in bounds.windows(2) {
        assert_eq!(w[0].1, w[1].0, "shards must tile [0, N): {bounds:?}");
    }
    // Sharding follows the placement's worker proportions exactly.
    assert_eq!(
        bounds,
        pool.placement().shard_ranges(53),
        "engine shard bounds disagree with the pool placement contract"
    );
}

#[test]
fn flat_output_layout_matches_rows() {
    let mut prng = Prng::new(99);
    let (wt, xs) = random_setup(&mut prng, 10, 32, QuantLevel::Q8, 32, 3);
    let eng = LutGemvEngine::new(wt, 4);
    let (out, _) = eng.gemv_batch(&xs);
    assert_eq!(out.batch(), 3);
    assert_eq!(out.n(), 10);
    assert_eq!(out.as_slice().len(), 30);
    let vecs = out.to_vecs();
    for bi in 0..3 {
        assert_eq!(vecs[bi].as_slice(), out.row(bi));
        assert_eq!(&out.as_slice()[bi * 10..(bi + 1) * 10], out.row(bi));
    }
}
