//! Cross-path conformance suite for the lane-parallel plane accumulation.
//!
//! The engine picks, per scale group, between the i32 lane kernels
//! (`lutgemv::planes`) and the i64 scalar path, based on a range proof
//! computed from the built LUT's basis weights. The acceptance bar is
//! *bit-identity*: for every adversarial shape — max-magnitude weights
//! sitting exactly on the range-proof boundary, NBW 1..4, activation
//! widths 2/4/8, group tails not divisible by NBW, batch 1/7/32 — the
//! auto path must produce `GemvOutput` and `GemvStats` identical to the
//! forced-i64 reference at 1/2/8 threads, with and without the PRT, at
//! every DFM (PRT) capacity.

use sail::lutgemv::engine::{reference_gemv, LutGemvEngine};
use sail::lutgemv::{planes, GemvOutput};
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::runtime::WorkerPool;
use sail::util::{propcheck, Prng};

/// A quantized activation vector of an arbitrary bit width `act_bits`
/// (2/4/8): codes uniform over the full two's-complement range so sign
/// planes and extreme magnitudes are always exercised.
fn random_activation(prng: &mut Prng, k: usize, act_bits: u32) -> QuantizedVector {
    let q: Vec<i8> = (0..k).map(|_| prng.signed_bits(act_bits) as i8).collect();
    let scale = 0.05 + prng.f64() as f32;
    QuantizedVector { q, scale, bits: act_bits }
}

/// Run one shape through the forced-scalar engine (serial) and the auto
/// lane engine (serial + 1/2/8-thread pools), asserting bit-identical
/// outputs and stats everywhere, and agreement with the naive reference.
#[allow(clippy::too_many_arguments)]
fn assert_conformance(
    wt: &QuantizedMatrix,
    xs: &[QuantizedVector],
    nbw: u32,
    tile_cols: usize,
    use_prt: bool,
    prt_capacity: usize,
    check_reference: bool,
    label: &str,
) -> Result<(), String> {
    let mut scalar_eng = LutGemvEngine::new(wt.clone(), nbw);
    scalar_eng.force_scalar_accum = true;
    scalar_eng.tile_cols = tile_cols;
    scalar_eng.use_prt = use_prt;
    scalar_eng.prt_capacity = prt_capacity;
    let (want, want_stats) = scalar_eng.gemv_batch(xs);

    if check_reference && !use_prt {
        for (bi, x) in xs.iter().enumerate() {
            let r = reference_gemv(wt, x);
            if want.row(bi) != r.as_slice() {
                return Err(format!("{label}: scalar-i64 vs naive reference, row {bi}"));
            }
        }
    }

    let mut lane_eng = LutGemvEngine::new(wt.clone(), nbw);
    lane_eng.tile_cols = tile_cols;
    lane_eng.use_prt = use_prt;
    lane_eng.prt_capacity = prt_capacity;
    let (got, got_stats) = lane_eng.gemv_batch(xs);
    if got != want {
        return Err(format!("{label}: lane-i32 output != scalar-i64 output"));
    }
    if got_stats != want_stats {
        return Err(format!("{label}: lane stats {got_stats:?} != scalar {want_stats:?}"));
    }

    for threads in [1usize, 2, 8] {
        let pool = WorkerPool::new(threads);
        let mut out = GemvOutput::new();
        let stats = lane_eng.gemv_batch_into(xs, &pool, &mut out).unwrap();
        if out != want {
            return Err(format!("{label}: output drift at threads={threads}"));
        }
        if stats != want_stats {
            return Err(format!("{label}: stats drift at threads={threads}"));
        }
    }
    // And at the ambient width (SAIL_POOL_THREADS in the CI matrix).
    let auto = WorkerPool::auto();
    let mut out = GemvOutput::new();
    let stats = lane_eng.gemv_batch_into(xs, &auto, &mut out).unwrap();
    if out != want || stats != want_stats {
        return Err(format!("{label}: drift on auto pool ({} threads)", auto.threads()));
    }
    Ok(())
}

#[test]
fn lane_path_bit_identical_adversarial_shapes() {
    propcheck::check(
        "plane-conformance",
        propcheck::Config { cases: 36, seed: 7001 },
        |p, _| {
            let level = QuantLevel::ALL[p.usize_in(0, 6)];
            let nbw = p.usize_in(1, 5) as u32; // NBW ∈ 1..4
            // Groups deliberately include sizes with NBW-ragged tails
            // (e.g. 8/3, 24/5).
            let group = [8usize, 16, 24, 32][p.usize_in(0, 4)];
            let k = group * p.usize_in(1, 4);
            let n = p.usize_in(1, 20);
            let batch = [1usize, 7, 32][p.usize_in(0, 3)];
            let act_bits = [2u32, 4, 8][p.usize_in(0, 3)];
            let tile_cols = p.usize_in(1, 8);
            let use_prt = p.usize_in(0, 2) == 1;
            let prt_capacity = [1usize, 2, 32][p.usize_in(0, 3)];
            let seed = p.next_u64();
            (level, nbw, group, k, n, batch, act_bits, tile_cols, use_prt, prt_capacity, seed)
        },
        |&(level, nbw, group, k, n, batch, act_bits, tile_cols, use_prt, prt_capacity, seed)| {
            if nbw as usize > group {
                return Ok(()); // engine rejects this combination by design
            }
            let mut prng = Prng::new(seed);
            let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
            let wt = QuantizedMatrix::quantize(&w, n, k, level, group);
            let xs: Vec<QuantizedVector> =
                (0..batch).map(|_| random_activation(&mut prng, k, act_bits)).collect();
            assert_conformance(
                &wt,
                &xs,
                nbw,
                tile_cols,
                use_prt,
                prt_capacity,
                true,
                &format!("level={level} nbw={nbw} group={group} act={act_bits} b={batch}"),
            )
        },
    );
}

/// Weights at the symmetric quantization maximum (`±max_q`) quantize to
/// exact integer codes when fed as integral floats — the knob that lets
/// the tests place `Σ|w|` exactly against the range-proof limit.
fn max_magnitude_matrix(n: usize, group: usize) -> QuantizedMatrix {
    let w = vec![127.0f32; n * group];
    let wt = QuantizedMatrix::quantize(&w, n, group, QuantLevel::Q8, group);
    // Sanity: the codes really are ±max_q (scale is exactly 1.0).
    assert_eq!(wt.q(0, 0), 127);
    assert_eq!(wt.q(n - 1, group - 1), 127);
    wt
}

#[test]
fn range_proof_boundary_shapes_stay_bit_identical() {
    // Σ|w| = 127 × group against the 8-bit-activation limit
    // (⌊(2³¹−1)/255⌋ = 8 421 504): the largest group that passes the
    // proof runs the lane path at its extreme; one element more and the
    // engine must fall back to i64. Both sides must be bit-identical to
    // the forced-scalar reference — that *is* the boundary case the
    // narrowing argument lives or dies on.
    let limit = planes::i32_safe_abs_weight_sum(8);
    let group_ok = (limit / 127) as usize; // 66 311: 127·g ≤ limit
    let group_over = group_ok + 1; //          66 312: 127·g > limit
    assert!(planes::group_fits_i32(127 * group_ok as u64, 8));
    assert!(!planes::group_fits_i32(127 * group_over as u64, 8));

    let mut prng = Prng::new(7002);
    for (group, side) in [(group_ok, "at-limit"), (group_over, "over-limit")] {
        let wt = max_magnitude_matrix(2, group);
        // Max-magnitude activations too: every LUT read returns the
        // largest entry, so the accumulator actually walks to the bound.
        let extreme = QuantizedVector { q: vec![127i8; group], scale: 1.0, bits: 8 };
        let mixed = random_activation(&mut prng, group, 8);
        let xs = vec![extreme, mixed];
        assert_conformance(&wt, &xs, 4, 1, false, 32, true, side).unwrap();
    }
}

#[test]
fn range_proof_boundary_with_prt_and_tails() {
    // The over-limit fallback with a ragged NBW tail (66 312 % 5 ≠ 0) and
    // the PRT enabled: the i64 path's PRT bookkeeping must match the
    // forced-scalar engine access for access.
    let limit = planes::i32_safe_abs_weight_sum(8);
    let group = (limit / 127) as usize + 1;
    let wt = max_magnitude_matrix(1, group);
    let mut prng = Prng::new(7003);
    let xs = vec![
        QuantizedVector { q: vec![127i8; group], scale: 0.25, bits: 8 },
        random_activation(&mut prng, group, 8),
    ];
    assert_conformance(&wt, &xs, 5, 1, true, 32, false, "over-limit-prt").unwrap();
}

#[test]
fn small_groups_always_take_the_lane_path_exactly() {
    // Realistic llama.cpp-style groups (32 × Q4) sit far below the proof
    // limit — Σ|w| ≤ 32·7 = 224 — so the auto engine is the lane kernel
    // in production. Pin the proof down and the numerics with it.
    assert!(planes::group_fits_i32(224, 8));
    let mut prng = Prng::new(7004);
    let w: Vec<f32> = (0..8 * 128).map(|_| prng.normal() as f32).collect();
    let wt = QuantizedMatrix::quantize(&w, 8, 128, QuantLevel::Q4, 32);
    for batch in [1usize, 7, 32] {
        let xs: Vec<QuantizedVector> = (0..batch)
            .map(|_| {
                let x: Vec<f32> = (0..128).map(|_| prng.normal() as f32).collect();
                QuantizedVector::quantize(&x)
            })
            .collect();
        assert_conformance(&wt, &xs, 4, 3, false, 32, true, &format!("b{batch}")).unwrap();
    }
}
