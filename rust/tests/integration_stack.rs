//! Cross-module integration tests: the full Rust stack without PJRT.
//!
//! These exercise paths that cut across quant → isa → lutgemv → sim →
//! baselines → cost → coordinator, pinning the system-level claims the
//! benches print.

use sail::baselines::{CpuModel, GpuModel, NeuralCacheModel};
use sail::coordinator::{Batcher, BatcherConfig, MockEngine, Request};
use sail::cost::{tokens_per_dollar, Platform};
use sail::isa::{emit_gemv, LutMm1k};
use sail::lutgemv::engine::{reference_gemv, LutGemvEngine};
use sail::model::ModelConfig;
use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use sail::sim::{SailPerfModel, TensorSchedule};
use sail::util::Prng;

/// The coordinator's instruction stream covers exactly the tiles the
/// schedule stages, for every model/quant combination.
#[test]
fn isa_stream_covers_schedule_tiles() {
    for m in [ModelConfig::llama2_7b(), ModelConfig::tiny_e2e()] {
        let sched = TensorSchedule::build(&m, QuantLevel::Q4, 32);
        // Every schedule entry decomposes into whole 1024-tiles (after
        // padding); emit_gemv for a padded width must produce that many
        // column tiles.
        for e in &sched.entries {
            let padded_n = e.n.div_ceil(1024) * 1024;
            if padded_n <= 8192 {
                let insts = emit_gemv(padded_n, QuantLevel::Q4, 1, 2, 3).unwrap();
                assert_eq!(insts.len(), padded_n / 1024, "{}-{}", e.tensor, e.shard);
                // Round-trip each instruction word.
                for i in &insts {
                    assert_eq!(LutMm1k::decode(i.encode()).unwrap(), *i);
                }
            }
        }
    }
}

/// End-to-end numeric path at the GEMV level: quantize → engine → exact
/// match, for every quant level the ISA supports, on a realistic
/// projection shape.
#[test]
fn gemv_exactness_projection_shapes() {
    let mut prng = Prng::new(404);
    for level in QuantLevel::ALL {
        let (k, n) = (256usize, 96usize);
        let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, n, k, level, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
        let qx = QuantizedVector::quantize(&x);
        assert_eq!(eng.gemv(&qx), reference_gemv(&eng.weights(), &qx), "{level}");
    }
}

/// The paper's headline ordering at the system level: SAIL > NC > AMX >
/// ARM on 7B-Q4 at 16 threads; SAIL's advantage grows at Q2.
#[test]
fn system_ordering_headline() {
    let m = ModelConfig::llama2_7b();
    let q4 = QuantLevel::Q4;
    let arm = CpuModel::arm_n1().tokens_per_sec(&m, q4, 16, 1);
    let amx = CpuModel::amx().tokens_per_sec(&m, q4, 16, 1);
    let nc = NeuralCacheModel::paper_config(q4, 16).tokens_per_sec(&m, 1);
    let sail = SailPerfModel::paper_config(q4, 16).tokens_per_sec(&m, 1);
    assert!(arm < amx && amx < sail, "ARM {arm} < AMX {amx} < SAIL {sail}");
    assert!(nc < sail, "NC {nc} < SAIL {sail}");

    let speedup_q4 = sail / arm;
    let q2 = QuantLevel::Q2;
    let speedup_q2 = SailPerfModel::paper_config(q2, 16).tokens_per_sec(&m, 1)
        / CpuModel::arm_n1().tokens_per_sec(&m, q2, 16, 1);
    assert!(
        speedup_q2 > speedup_q4 * 0.95,
        "advantage must not shrink at lower precision: {speedup_q2} vs {speedup_q4}"
    );
    // Abstract: "up to 10.7× speedup" — our strongest configuration must
    // land in that regime (5–13×).
    assert!((5.0..13.0).contains(&speedup_q2), "Q2 speedup {speedup_q2}");
}

/// Table III structure: SAIL overtakes the V100 at long context, loses at
/// short; the GPU's feasible batch shrinks with context.
#[test]
fn gpu_crossover_structure() {
    let m = ModelConfig::llama2_7b();
    let sail = SailPerfModel::paper_config(QuantLevel::Q4, 16).tokens_per_sec(&m, 8);
    let v100 = GpuModel::v100();
    let short = v100.best_tokens_per_sec(&m, QuantLevel::Q4, 512).unwrap();
    let long = v100.best_tokens_per_sec(&m, QuantLevel::Q4, 4096).unwrap();
    assert!(short.0 > sail && sail > long.0, "{} > {sail} > {}", short.0, long.0);
    assert!(short.1 >= long.1);
}

/// TPD headline: SAIL's tokens/dollar beats the 16-core CPU by >5× and
/// the V100 at low precision (paper: 19.9× and 7.04× "up to" numbers).
#[test]
fn tpd_headline_regime() {
    let m = ModelConfig::llama2_7b();
    let q2 = QuantLevel::Q2;
    let sail = tokens_per_dollar(
        SailPerfModel::paper_config(q2, 16).tokens_per_sec(&m, 8),
        Platform::sail_16core(),
    );
    let cpu = tokens_per_dollar(
        CpuModel::arm_n1().tokens_per_sec(&m, q2, 16, 8),
        Platform::cpu_16core(),
    );
    let gpu_rate = GpuModel::v100()
        .best_tokens_per_sec(&m, QuantLevel::Q4, 2048)
        .unwrap()
        .0;
    let gpu = tokens_per_dollar(gpu_rate, Platform::gpu_1xv100());
    assert!(sail / cpu > 5.0, "SAIL/CPU TPD = {}", sail / cpu);
    assert!(sail / gpu > 1.5, "SAIL/GPU TPD = {}", sail / gpu);
}

/// Coordinator under a heavy interleaved load with per-request budgets:
/// conservation (every prompt token consumed once, every response token
/// accounted) across thousands of iterations.
#[test]
fn coordinator_long_run_conservation() {
    let mut prng = Prng::new(777);
    let mut b = Batcher::new(MockEngine::new(6, 512, 128), BatcherConfig::default());
    let mut expected_tokens = 0usize;
    let n_req = 200u64;
    for id in 0..n_req {
        let plen = prng.usize_in(1, 20);
        let prompt: Vec<i32> = (0..plen).map(|_| prng.usize_in(1, 512) as i32).collect();
        let max_new = prng.usize_in(1, 30);
        expected_tokens += max_new;
        b.submit(Request::new(id, prompt, max_new));
    }
    let done = b.run_to_completion().unwrap();
    assert_eq!(done.len(), n_req as usize);
    let got: usize = done.iter().map(|r| r.tokens.len()).sum();
    // Every request hits its full budget (mock never emits EOS=None).
    assert_eq!(got, expected_tokens);
}

/// Report tables agree with the models they summarize (spot check one
/// cell of Table II against a direct model call).
#[test]
fn report_tables_consistent_with_models() {
    let tables = sail::report::table2_cpu_throughput();
    let rendered = tables[0].render();
    let direct = CpuModel::arm_n1().tokens_per_sec(
        &ModelConfig::llama2_7b(),
        QuantLevel::Q2,
        1,
        1,
    );
    let cell = format!("{:.2}", direct);
    assert!(
        rendered.lines().any(|l| l.starts_with("7B-Q2") && l.contains(&cell)),
        "Table II missing ARM 7B-Q2 1T = {cell}\n{rendered}"
    );
}
