//! Acceptance-equivalence harness for self-speculative decoding on the
//! LUT serving path (the PR-9 tentpole).
//!
//! The property under test: **speculation changes latency, never
//! tokens**. Every emitted token is a target argmax computed over an
//! exactly-plain cache prefix, so the speculative streams must be
//! bit-identical to plain decode for *any* draft — fewer bits, fewer
//! layers, even an adversarial always-wrong draft — across the full
//! serving matrix (prefill chunk × pool width × NUMA × KV layout ×
//! healing faults). On top of stream identity:
//!
//! - the engine's round/buffer accounting matches a reference oracle
//!   exactly for always-right and always-wrong drafts, and satisfies
//!   the structural conservation laws for any draft;
//! - KV rollback is total: after rejecting j of k draft tokens the
//!   cache is indistinguishable from a never-drafted run — contiguous
//!   bytes compare equal, and on the paged store the page tables,
//!   refcounts, free-list *order*, and dequantized contents all match,
//!   including pages shared copy-on-write through the prefix cache.

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use sail::coordinator::{
    spec_config_from_env, Batcher, BatcherConfig, DecodeEngine, FinishReason, SlotRun, SpecConfig,
    SpecStats, SpeculativeEngine, TransformerServeEngine,
};
use sail::model::{DecodeSpec, DraftSpec, KvCacheSpec, KvRuntimeConfig, KvStore, LutTransformer};
use sail::quant::QuantLevel;
use sail::runtime::{FaultPlan, NumaPolicy, WorkerPool};

fn spec() -> DecodeSpec {
    common::tiny_spec(2, KvCacheSpec::q8())
}

fn draft(bits: Option<QuantLevel>, layers: Option<usize>) -> DraftSpec {
    DraftSpec { bits, layers }
}

fn sabotage_cfg(k: usize) -> SpecConfig {
    SpecConfig { k, draft: draft(None, None), sabotage: true }
}

/// A genuinely reduced draft (2-bit weights) that accepts some rounds
/// and rejects others — the partial-rollback workhorse.
fn q2_cfg(k: usize) -> SpecConfig {
    SpecConfig { k, draft: draft(Some(QuantLevel::Q2), None), sabotage: false }
}

/// One multi-token prefill run, then `n` single-token decode feeds each
/// consuming the previous output — autoregressive serving without a
/// batcher, so the engine's round/buffer accounting is exactly
/// predictable by [`oracle_stats`].
fn drive(e: &mut dyn DecodeEngine, slot: usize, prompt: &[i32], n: usize) -> Vec<i32> {
    let b = e.batch();
    let mut out = Vec::with_capacity(n + 1);
    out.push(e.step_runs(&[SlotRun { slot, tokens: prompt, start_pos: 0 }]).unwrap()[0]);
    for i in 0..n {
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        tokens[slot] = *out.last().unwrap();
        positions[slot] = (prompt.len() + i) as i32;
        active[slot] = true;
        out.push(e.step(&tokens, &positions, &active).unwrap()[slot]);
    }
    out
}

/// The reference accounting oracle: simulate the round/buffer protocol
/// for a draft that is always right (`hit`) or always wrong. Each feed
/// is served from the accepted buffer, or falls back to a plain step
/// when the window leaves no room to draft, or opens a fresh round of
/// `min(k, window)` drafted tokens.
fn oracle_stats(k: usize, prompt_len: usize, n: usize, ctx: usize, hit: bool) -> SpecStats {
    let mut st = SpecStats::default();
    let mut pending = 0usize;
    for i in 0..n {
        let pos = prompt_len + i;
        if pending > 0 {
            pending -= 1;
            st.buffered += 1;
            continue;
        }
        let k_plan = k.min(ctx - pos - 1);
        if k_plan == 0 {
            st.fallback_steps += 1;
            continue;
        }
        st.rounds += 1;
        st.drafted += k_plan as u64;
        if hit {
            st.accepted += k_plan as u64;
            pending = k_plan;
        }
    }
    st
}

/// Serve [`common::mixed_requests`] to completion through the batcher:
/// plain decode when `cfg` is `None`, speculative otherwise.
fn serve(
    paged: Option<usize>,
    width: usize,
    chunk: usize,
    policy: &NumaPolicy,
    plan: Option<Arc<FaultPlan>>,
    cfg: Option<SpecConfig>,
) -> BTreeMap<u64, (Vec<i32>, FinishReason)> {
    let kv = match paged {
        Some(pt) => KvRuntimeConfig::paged(pt),
        None => KvRuntimeConfig::contiguous(),
    };
    let pool = Arc::new(WorkerPool::with_policy(width, policy));
    if let Some(p) = &plan {
        pool.arm_faults(Arc::clone(p));
    }
    let bcfg = BatcherConfig { prefill_chunk: chunk, ..BatcherConfig::default() };
    let done = match cfg {
        Some(sc) => {
            let e = common::spec_engine_with_kv(spec(), 3, Arc::clone(&pool), kv, sc);
            let mut b = Batcher::new(e, bcfg);
            for r in common::mixed_requests(false) {
                b.submit(r);
            }
            b.run_to_completion().unwrap()
        }
        None => {
            let e = common::engine_with_kv(spec(), 3, Arc::clone(&pool), kv);
            let mut b = Batcher::new(e, bcfg);
            for r in common::mixed_requests(false) {
                b.submit(r);
            }
            b.run_to_completion().unwrap()
        }
    };
    pool.disarm_faults();
    done.into_iter().map(|r| (r.id, (r.tokens, r.finish))).collect()
}

/// Snapshot of the paged store's bookkeeping that a total rollback must
/// restore bit-exactly: per-slot page tables, their refcounts, the
/// free-list *order* (the LIFO release discipline), and the in-use
/// count. Peak/COW counters are deliberately absent — they are
/// observability, and speculation legitimately moves them.
#[allow(clippy::type_complexity)]
fn paged_state(m: &LutTransformer) -> (Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<u32>, usize) {
    let p = m.kv().paged().unwrap();
    let tables: Vec<Vec<u32>> = (0..m.batch()).map(|s| p.table(s).to_vec()).collect();
    let refcounts =
        tables.iter().map(|t| t.iter().map(|&pg| p.refcount(pg)).collect()).collect();
    (tables, refcounts, p.free_pages().to_vec(), p.pages_in_use())
}

/// Dequantized K/V contents of one slot's first `positions` positions,
/// every layer.
fn kv_contents(m: &LutTransformer, slot: usize, positions: usize) -> Vec<f32> {
    let kv = m.kv();
    let mut buf = vec![0.0f32; kv.kv_dim()];
    let mut out = Vec::new();
    for layer in 0..m.spec().layers() {
        for pos in 0..positions {
            kv.read_k(layer, slot, pos, &mut buf);
            out.extend_from_slice(&buf);
            kv.read_v(layer, slot, pos, &mut buf);
            out.extend_from_slice(&buf);
        }
    }
    out
}

#[test]
fn speculative_streams_bit_identical_across_the_serving_matrix() {
    // One plain contiguous serial oracle; every speculative cell of the
    // acceptance matrix must reproduce its streams bit-for-bit.
    let want = serve(None, 1, 1, &NumaPolicy::Off, None, None);
    assert!(want.values().all(|(t, f)| !t.is_empty() && *f == FinishReason::MaxTokens));
    for paged in [None, Some(16usize)] {
        for chunk in [1usize, 16] {
            for width in [1usize, 2, 8] {
                for policy in [NumaPolicy::Off, NumaPolicy::Auto] {
                    for faults in [false, true] {
                        let plan = faults.then(|| common::healing_plan(4242));
                        let got =
                            serve(paged, width, chunk, &policy, plan, Some(SpecConfig::new(4)));
                        assert_eq!(
                            got, want,
                            "speculation moved a token (kv {paged:?} chunk {chunk} width \
                             {width} numa {policy} faults {faults})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn every_draft_config_streams_identically_through_the_batcher() {
    // Draft quality is a latency knob: k 1..8, bit-reduced drafts,
    // layer-truncated drafts, and the adversarial always-wrong draft
    // all serve the same streams, on both KV layouts.
    let want = serve(None, 1, 1, &NumaPolicy::Off, None, None);
    let cfgs = [
        SpecConfig::new(1),
        SpecConfig::new(2),
        SpecConfig::new(8),
        q2_cfg(4),
        SpecConfig { k: 4, draft: draft(None, Some(1)), sabotage: false },
        SpecConfig { k: 3, draft: draft(Some(QuantLevel::Q2), Some(1)), sabotage: false },
        sabotage_cfg(4),
    ];
    for paged in [None, Some(16usize)] {
        for cfg in cfgs {
            let got = serve(paged, 2, 1, &NumaPolicy::Off, None, Some(cfg));
            assert_eq!(got, want, "draft config {cfg:?} moved a token (kv {paged:?})");
        }
    }
}

#[test]
fn sail_spec_env_leg_streams_match_plain_decode() {
    // The CI matrix leg sets SAIL_SPEC (off / k:4) the same way the
    // fault job sets SAIL_FAULTS; this test picks the leg's config up
    // through the env parser and holds the equivalence bar under it, on
    // a busier cell than the sweeps above (paged KV, chunked prefill,
    // auto placement). On the off leg it degenerates to plain-vs-plain
    // — deliberately cheap, the explicit sweeps carry the coverage.
    let want = serve(None, 1, 1, &NumaPolicy::Off, None, None);
    let cfg = spec_config_from_env();
    let got = serve(Some(16), 2, 16, &NumaPolicy::Auto, None, cfg);
    assert_eq!(
        got,
        want,
        "SAIL_SPEC={:?} changed the token streams",
        std::env::var("SAIL_SPEC").unwrap_or_else(|_| "<unset>".to_string())
    );
}

#[test]
fn acceptance_accounting_matches_the_reference_oracle() {
    // Identical-weights drafts are always right (the draft *is* the
    // target, kept in KV lockstep); sabotaged drafts are always wrong
    // (off-by-one argmax). Both make every round's outcome predictable,
    // so the engine's counters must equal the oracle simulation exactly
    // — including the window-clamped rounds near the end of the context
    // and the final zero-room fallback step.
    let ctx = spec().max_context;
    let prompt = [3, 7, 11];
    let n = ctx - prompt.len(); // last feed lands at ctx − 1: k_plan = 0
    for seed in [5u64, 9, 123] {
        for k in [1usize, 2, 4, 8] {
            for sabotage in [false, true] {
                let cfg = SpecConfig { k, draft: draft(None, None), sabotage };
                let mut se = SpeculativeEngine::random_with_kv(
                    spec(),
                    seed,
                    1,
                    WorkerPool::shared(1),
                    KvRuntimeConfig::contiguous(),
                    cfg,
                )
                .unwrap();
                let mut pe = TransformerServeEngine::random_with_kv(
                    spec(),
                    seed,
                    1,
                    WorkerPool::shared(1),
                    KvRuntimeConfig::contiguous(),
                )
                .unwrap();
                let leg = format!("seed {seed} k {k} sabotage {sabotage}");
                let got = drive(&mut se, 0, &prompt, n);
                let want = drive(&mut pe, 0, &prompt, n);
                assert_eq!(got, want, "stream diverged ({leg})");
                let st = se.stats();
                assert_eq!(
                    st,
                    oracle_stats(k, prompt.len(), n, ctx, !sabotage),
                    "accounting diverged from the oracle ({leg})"
                );
                assert!(st.drafted > 0, "{leg}: no round ever drafted");
            }
        }
    }
}

#[test]
fn reduced_drafts_obey_the_accounting_conservation_laws() {
    // Bit-reduced and layer-truncated drafts accept unpredictably, so
    // the exact oracle does not apply — but every feed is still exactly
    // one of {buffered serve, fresh round, fallback}, acceptance never
    // exceeds drafting, and at most one round's accepted tail can be
    // left unserved in the buffer.
    let prompt = [3, 7, 11];
    let n = 16;
    for d in [draft(Some(QuantLevel::Q2), None), draft(None, Some(1))] {
        let cfg = SpecConfig { k: 4, draft: d, sabotage: false };
        let mut se = common::spec_engine_with_kv(
            spec(),
            1,
            WorkerPool::shared(1),
            KvRuntimeConfig::contiguous(),
            cfg,
        );
        let mut pe = common::engine_with_kv(
            spec(),
            1,
            WorkerPool::shared(1),
            KvRuntimeConfig::contiguous(),
        );
        let got = drive(&mut se, 0, &prompt, n);
        let want = drive(&mut pe, 0, &prompt, n);
        assert_eq!(got, want, "draft {d:?} moved a token");
        let st = se.stats();
        assert_eq!(
            st.rounds + st.buffered + st.fallback_steps,
            n as u64,
            "draft {d:?}: feeds are not conserved across rounds/buffer/fallback"
        );
        assert!(st.accepted <= st.drafted, "draft {d:?}: accepted more than drafted");
        assert!(st.drafted >= st.rounds, "draft {d:?}: a round drafted nothing");
        assert!(
            st.accepted - st.buffered <= cfg.k as u64,
            "draft {d:?}: more than one round's tail left in the buffer"
        );
    }
}

#[test]
fn rejected_drafts_leave_the_contiguous_cache_identical_to_plain_decode() {
    // Total-rollback bar, contiguous: after any mix of full rejection
    // (sabotage) and partial acceptance (a Q2 draft), the byte-compared
    // cache equals a never-drafted run's.
    let prompt = [3, 7, 11];
    let n = 12;
    for cfg in [sabotage_cfg(4), q2_cfg(4)] {
        let mut se = common::spec_engine_with_kv(
            spec(),
            2,
            WorkerPool::shared(1),
            KvRuntimeConfig::contiguous(),
            cfg,
        );
        let mut pe = common::engine_with_kv(
            spec(),
            2,
            WorkerPool::shared(1),
            KvRuntimeConfig::contiguous(),
        );
        let got = drive(&mut se, 0, &prompt, n);
        let want = drive(&mut pe, 0, &prompt, n);
        assert_eq!(got, want, "{cfg:?}");
        if cfg.sabotage {
            let st = se.stats();
            assert!(st.drafted > 0 && st.accepted == 0, "sabotage accepted a draft");
        }
        assert_eq!(
            se.target().model().kv().contiguous().unwrap(),
            pe.model().kv().contiguous().unwrap(),
            "{cfg:?}: rejected speculative writes survived in the contiguous cache"
        );
    }
}

#[test]
fn rejected_drafts_restore_paged_tables_refcounts_and_free_list() {
    // Total-rollback bar, paged: the verify forward allocates pages for
    // the speculative tail and the rejection must hand them back in
    // reverse order, so tables, refcounts, the free list (order
    // included) and the dequantized contents all match a never-drafted
    // run — page-for-page, not just byte-count.
    let prompt = [3, 7, 11];
    let n = 12;
    for cfg in [sabotage_cfg(4), q2_cfg(4)] {
        let mut se = common::spec_engine_with_kv(
            spec(),
            2,
            WorkerPool::shared(1),
            KvRuntimeConfig::paged(4),
            cfg,
        );
        let mut pe = common::engine_with_kv(
            spec(),
            2,
            WorkerPool::shared(1),
            KvRuntimeConfig::paged(4),
        );
        let got = drive(&mut se, 0, &prompt, n);
        let want = drive(&mut pe, 0, &prompt, n);
        assert_eq!(got, want, "{cfg:?}");
        let (sm, pm) = (se.target().model(), pe.model());
        assert_eq!(
            paged_state(sm),
            paged_state(pm),
            "{cfg:?}: rollback left different page bookkeeping than plain decode"
        );
        let written = prompt.len() + n;
        assert_eq!(
            kv_contents(sm, 0, written),
            kv_contents(pm, 0, written),
            "{cfg:?}: rejected speculative writes survived in the paged contents"
        );
    }
}

/// Cold-prefill slot 0 with the 8-token head (two whole pages at page
/// size 4), publish it to the prefix cache, attach it on slot 1 (split
/// 7 re-feeds the last head token — a COW write into the shared
/// boundary page), then decode `n` tokens on slot 1.
fn run_shared_head(e: &mut dyn DecodeEngine, head: &[i32], n: usize) -> Vec<i32> {
    e.step_runs(&[SlotRun { slot: 0, tokens: head, start_pos: 0 }]).unwrap();
    e.prefix_insert(0, head).unwrap();
    let split = e.prefix_attach(1, head).unwrap();
    assert_eq!(split, head.len() - 1, "full-head hit must split at len − 1");
    let b = e.batch();
    let first =
        e.step_runs(&[SlotRun { slot: 1, tokens: &head[split..], start_pos: split as i32 }])
            .unwrap()[0];
    let mut out = vec![first];
    for i in 0..n {
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        tokens[1] = *out.last().unwrap();
        positions[1] = (head.len() + i) as i32;
        active[1] = true;
        out.push(e.step(&tokens, &positions, &active).unwrap()[1]);
    }
    out
}

#[test]
fn rollback_leaves_prefix_shared_cow_pages_intact() {
    // Speculation over a prefix-cache hit: slot 1's verify forwards
    // start inside a page shared with slot 0 and the radix tree, so the
    // first write copies-on-write and every rejection truncates the
    // private copy's tail. The shared original must never move — the
    // whole paged state (and both slots' contents) must equal a plain
    // never-drafted run's, with the sabotaged draft rejected every
    // round.
    let head: Vec<i32> = (2..10).collect();
    let n = 8;
    let mut pe = common::engine_with_kv(
        spec(),
        2,
        WorkerPool::shared(1),
        KvRuntimeConfig::paged(4),
    );
    let want = run_shared_head(&mut pe, &head, n);
    let mut se = common::spec_engine_with_kv(
        spec(),
        2,
        WorkerPool::shared(1),
        KvRuntimeConfig::paged(4),
        sabotage_cfg(4),
    );
    let got = run_shared_head(&mut se, &head, n);
    assert_eq!(got, want, "speculation over a COW page moved a token");
    let st = se.stats();
    assert!(st.drafted > 0 && st.accepted == 0, "sabotage accepted a draft");
    let (sm, pm) = (se.target().model(), pe.model());
    assert_eq!(paged_state(sm), paged_state(pm), "COW rollback bookkeeping diverged");
    assert_eq!(
        kv_contents(sm, 0, head.len()),
        kv_contents(pm, 0, head.len()),
        "the shared original's contents moved under a speculating sharer"
    );
    assert_eq!(
        kv_contents(sm, 1, head.len() + n),
        kv_contents(pm, 1, head.len() + n),
        "the COW copy's contents diverged from plain decode"
    );
    // The head's first page is still genuinely shared (slot 0, slot 1,
    // and the tree); the boundary page was copied, so the slots map
    // different physical pages there.
    let p = sm.kv().paged().unwrap();
    assert!(p.refcount(p.table(0)[0]) >= 3, "first head page lost its sharers");
    assert_ne!(p.table(0)[1], p.table(1)[1], "the COW write never copied the boundary page");
}
