//! Serving metrics: throughput, latency percentiles, utilization.

use std::time::{Duration, Instant};

use super::request::Response;
use crate::util::stats::{Percentiles, Summary};

/// Aggregated serving metrics over a run.
#[derive(Debug)]
pub struct ServingMetrics {
    started: Instant,
    pub completed: u64,
    pub tokens_generated: u64,
    pub latency: Percentiles,
    pub ttft: Percentiles,
    pub tokens_per_req: Summary,
    finished_at: Option<Instant>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            started: Instant::now(),
            completed: 0,
            tokens_generated: 0,
            latency: Percentiles::new(),
            ttft: Percentiles::new(),
            tokens_per_req: Summary::new(),
            finished_at: None,
        }
    }

    pub fn record(&mut self, r: &Response) {
        self.completed += 1;
        self.tokens_generated += r.tokens.len() as u64;
        self.latency.push(r.latency.as_secs_f64() * 1e3);
        // Zero-token responses (EmptyPrompt rejections, ContextFull during
        // prefill) never had a first token; their placeholder ttft of 0
        // would deflate the percentiles, so they are excluded.
        if !r.tokens.is_empty() {
            self.ttft.push(r.ttft.as_secs_f64() * 1e3);
        }
        self.tokens_per_req.push(r.tokens.len() as f64);
        self.finished_at = Some(Instant::now());
    }

    pub fn elapsed(&self) -> Duration {
        self.finished_at.unwrap_or_else(Instant::now) - self.started
    }

    /// Aggregate decode throughput (generated tokens per second).
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / secs
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} elapsed={:.2}s throughput={:.2} tok/s\n\
             latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms   \
             ttft p50/p95 = {:.1}/{:.1} ms   mean tokens/req = {:.1}",
            self.completed,
            self.tokens_generated,
            self.elapsed().as_secs_f64(),
            self.tokens_per_sec(),
            self.latency.p50(),
            self.latency.p95(),
            self.latency.p99(),
            self.ttft.p50(),
            self.ttft.p95(),
            self.tokens_per_req.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::new();
        for i in 0..10u64 {
            m.record(&Response {
                id: i,
                tokens: vec![1; 5],
                ttft: Duration::from_millis(10 + i),
                latency: Duration::from_millis(50 + i),
                finish: FinishReason::MaxTokens,
            });
        }
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 50);
        assert!(m.latency.p50() >= 50.0 && m.latency.p50() <= 60.0);
        let rep = m.report();
        assert!(rep.contains("requests=10"));
        assert!(m.tokens_per_sec() > 0.0);
    }

    #[test]
    fn zero_token_responses_do_not_deflate_ttft() {
        let mut m = ServingMetrics::new();
        m.record(&Response {
            id: 0,
            tokens: vec![1; 3],
            ttft: Duration::from_millis(40),
            latency: Duration::from_millis(80),
            finish: FinishReason::MaxTokens,
        });
        // An admission rejection (or prefill ContextFull) carries ttft 0;
        // it must not drag the percentiles toward zero.
        m.record(&Response {
            id: 1,
            tokens: vec![],
            ttft: Duration::default(),
            latency: Duration::from_millis(1),
            finish: FinishReason::EmptyPrompt,
        });
        assert_eq!(m.completed, 2);
        assert!(m.ttft.p50() >= 40.0, "ttft p50 deflated: {}", m.ttft.p50());
    }
}
