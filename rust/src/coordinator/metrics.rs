//! Serving metrics: throughput, latency percentiles, SLO accounting.
//!
//! The serving front-end reports the load-test quartet the CPU-inference
//! papers use (xFasterTransformer; Intel's "Efficient LLM Inference on
//! CPUs"): **TTFT** (time to first token), **TPOT** (time per output
//! token after the first), aggregate tok/s, and **goodput** — tokens from
//! requests that ran to a *normal* finish, excluding sheds, deadline
//! expiries, and engine faults. Under overload, goodput is the honest
//! number: raw tok/s keeps rising while deadline-busted work makes it
//! useless.

use std::time::{Duration, Instant};

use super::engine::SpecStats;
use super::request::{FinishReason, Response};
use crate::model::KvMetrics;
use crate::runtime::{PoolStats, ReclaimStats};
use crate::util::stats::{Percentiles, Summary};

/// Aggregated serving metrics over a run.
#[derive(Debug)]
pub struct ServingMetrics {
    started: Instant,
    pub completed: u64,
    pub tokens_generated: u64,
    pub latency: Percentiles,
    pub ttft: Percentiles,
    /// Per-token decode cadence (ms per token after the first), one
    /// sample per response with ≥ 2 tokens ([`Response::tpot`]).
    pub tpot: Percentiles,
    pub tokens_per_req: Summary,
    /// Requests shed at submission (bounded queue full).
    pub shed: u64,
    /// Requests finished by TTFT/total-latency budget expiry.
    pub deadline_exceeded: u64,
    /// Requests finished by an engine fault (after the solo retry).
    pub engine_faults: u64,
    /// Tokens from requests that reached a normal finish (`MaxTokens`,
    /// `Eos`, `ContextFull`, `EmptyPrompt`) — the goodput numerator.
    pub goodput_tokens: u64,
    /// Paged-KV pool and prefix-cache counters, harvested from the engine
    /// at drain/shutdown ([`ServingMetrics::record_kv`]). `None` on the
    /// contiguous store.
    pub kv: Option<KvMetrics>,
    /// Speculative-decoding counters, harvested from the engine at
    /// drain/shutdown ([`ServingMetrics::record_spec`]). `None` on plain
    /// engines.
    pub spec: Option<SpecStats>,
    /// Dispatch-pool counters (per-worker execute/steal tallies, dispatch
    /// latency percentiles), harvested from the engine at drain/shutdown
    /// ([`ServingMetrics::record_pool`]). `None` on engines that never
    /// fan out on a worker pool.
    pub pool: Option<PoolStats>,
    /// Weight-generation reclamation counters, harvested at drain/shutdown
    /// ([`ServingMetrics::record_reclaim`]). `None` on engines without
    /// live weight swapping.
    pub reclaim: Option<ReclaimStats>,
    finished_at: Option<Instant>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            started: Instant::now(),
            completed: 0,
            tokens_generated: 0,
            latency: Percentiles::new(),
            ttft: Percentiles::new(),
            tpot: Percentiles::new(),
            tokens_per_req: Summary::new(),
            shed: 0,
            deadline_exceeded: 0,
            engine_faults: 0,
            goodput_tokens: 0,
            kv: None,
            spec: None,
            pool: None,
            reclaim: None,
            finished_at: None,
        }
    }

    /// Install the engine's paged-KV counters (latest snapshot wins; a
    /// `None` from a contiguous engine leaves any prior snapshot alone so
    /// harvesting at both drain and shutdown is safe).
    pub fn record_kv(&mut self, kv: Option<KvMetrics>) {
        if kv.is_some() {
            self.kv = kv;
        }
    }

    /// Install the engine's speculation counters (same sticky policy as
    /// [`record_kv`](ServingMetrics::record_kv): the latest `Some` wins,
    /// a `None` from a plain engine leaves any prior snapshot alone).
    pub fn record_spec(&mut self, spec: Option<SpecStats>) {
        if spec.is_some() {
            self.spec = spec;
        }
    }

    /// Install the engine's dispatch-pool counters (same sticky policy:
    /// the latest `Some` wins, a `None` leaves any prior snapshot alone).
    pub fn record_pool(&mut self, pool: Option<PoolStats>) {
        if pool.is_some() {
            self.pool = pool;
        }
    }

    /// Install the engine's weight-reclamation counters (same sticky
    /// policy as the other snapshots).
    pub fn record_reclaim(&mut self, reclaim: Option<ReclaimStats>) {
        if reclaim.is_some() {
            self.reclaim = reclaim;
        }
    }

    pub fn record(&mut self, r: &Response) {
        self.completed += 1;
        self.tokens_generated += r.tokens.len() as u64;
        self.latency.push(r.latency.as_secs_f64() * 1e3);
        // Zero-token responses (EmptyPrompt rejections, ContextFull during
        // prefill) never had a first token; their placeholder ttft of 0
        // would deflate the percentiles, so they are excluded.
        if !r.tokens.is_empty() {
            self.ttft.push(r.ttft.as_secs_f64() * 1e3);
        }
        if let Some(tpot) = r.tpot() {
            self.tpot.push(tpot.as_secs_f64() * 1e3);
        }
        self.tokens_per_req.push(r.tokens.len() as f64);
        match r.finish {
            FinishReason::Shed => self.shed += 1,
            FinishReason::DeadlineExceeded => self.deadline_exceeded += 1,
            FinishReason::EngineFault => self.engine_faults += 1,
            FinishReason::MaxTokens
            | FinishReason::Eos
            | FinishReason::ContextFull
            | FinishReason::EmptyPrompt => self.goodput_tokens += r.tokens.len() as u64,
        }
        self.finished_at = Some(Instant::now());
    }

    pub fn elapsed(&self) -> Duration {
        self.finished_at.unwrap_or_else(Instant::now) - self.started
    }

    /// Aggregate decode throughput (generated tokens per second).
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / secs
        }
    }

    /// Goodput: tokens per second counting only normally finished
    /// requests.
    pub fn goodput_tokens_per_sec(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.goodput_tokens as f64 / secs
        }
    }

    /// Fraction of recorded responses that were shed at submission.
    pub fn shed_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.shed as f64 / self.completed as f64
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} elapsed={:.2}s throughput={:.2} tok/s \
             goodput={:.2} tok/s\n\
             latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms   \
             ttft p50/p95 = {:.1}/{:.1} ms   tpot p50/p99 = {:.2}/{:.2} ms   \
             mean tokens/req = {:.1}\n\
             shed={} ({:.1}%)   deadline_exceeded={}   engine_faults={}",
            self.completed,
            self.tokens_generated,
            self.elapsed().as_secs_f64(),
            self.tokens_per_sec(),
            self.goodput_tokens_per_sec(),
            self.latency.p50(),
            self.latency.p95(),
            self.latency.p99(),
            self.ttft.p50(),
            self.ttft.p95(),
            self.tpot.p50(),
            self.tpot.p99(),
            self.tokens_per_req.mean(),
            self.shed,
            self.shed_rate() * 100.0,
            self.deadline_exceeded,
            self.engine_faults,
        );
        if let Some(kv) = &self.kv {
            s.push_str(&format!(
                "\nkv paged:{} pool={} pages   peak resident={} (contiguous worst case {})   \
                 cow_copies={}   prefix hit rate={:.1}% ({} hits / {} misses)   \
                 prefix pages held={} evictions={}",
                kv.page_tokens,
                kv.pool_pages,
                kv.peak_slot_resident_pages,
                kv.contiguous_worst_case_pages,
                kv.cow_copies,
                kv.prefix_hit_rate() * 100.0,
                kv.prefix_hits,
                kv.prefix_misses,
                kv.prefix_pages_held,
                kv.prefix_evictions,
            ));
        }
        if let Some(spec) = &self.spec {
            s.push_str(&format!(
                "\nspec rounds={} drafted={} accepted={} ({:.1}%)   \
                 buffered={}   fallback_steps={}",
                spec.rounds,
                spec.drafted,
                spec.accepted,
                spec.acceptance_rate() * 100.0,
                spec.buffered,
                spec.fallback_steps,
            ));
        }
        if let Some(pool) = &self.pool {
            let executed: u64 = pool.executed.iter().sum();
            let stolen: u64 = pool.stolen.iter().sum();
            s.push_str(&format!(
                "\npool backend={} workers={} dispatches={}   \
                 executed={} stolen={} cross_node={}   \
                 queue hwm={} inline_reclaims={}   \
                 dispatch p50/p99 = {:.1}/{:.1} us",
                pool.backend,
                pool.workers,
                pool.dispatches,
                executed,
                stolen,
                pool.cross_node_steals,
                pool.queue_depth_hwm,
                pool.inline_reclaims,
                pool.dispatch_p50_us,
                pool.dispatch_p99_us,
            ));
        }
        if let Some(rec) = &self.reclaim {
            s.push_str(&format!(
                "\nreclaim retired={} reclaimed={} pending={} active_pins={}",
                rec.retired, rec.reclaimed, rec.pending, rec.active_pins,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::new();
        for i in 0..10u64 {
            m.record(&Response {
                id: i,
                tokens: vec![1; 5],
                ttft: Duration::from_millis(10 + i),
                latency: Duration::from_millis(50 + i),
                finish: FinishReason::MaxTokens,
            });
        }
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 50);
        assert_eq!(m.goodput_tokens, 50, "normal finishes are all goodput");
        assert!(m.latency.p50() >= 50.0 && m.latency.p50() <= 60.0);
        // 5 tokens, ttft 10+i ms, latency 50+i ms ⇒ tpot = 40/4 = 10 ms.
        assert!((m.tpot.p50() - 10.0).abs() < 0.5, "tpot p50 = {}", m.tpot.p50());
        let rep = m.report();
        assert!(rep.contains("requests=10"));
        assert!(m.tokens_per_sec() > 0.0);
        assert_eq!(m.shed_rate(), 0.0);
    }

    #[test]
    fn zero_token_responses_do_not_deflate_ttft() {
        let mut m = ServingMetrics::new();
        m.record(&Response {
            id: 0,
            tokens: vec![1; 3],
            ttft: Duration::from_millis(40),
            latency: Duration::from_millis(80),
            finish: FinishReason::MaxTokens,
        });
        // An admission rejection (or prefill ContextFull) carries ttft 0;
        // it must not drag the percentiles toward zero.
        m.record(&Response {
            id: 1,
            tokens: vec![],
            ttft: Duration::default(),
            latency: Duration::from_millis(1),
            finish: FinishReason::EmptyPrompt,
        });
        assert_eq!(m.completed, 2);
        assert!(m.ttft.p50() >= 40.0, "ttft p50 deflated: {}", m.ttft.p50());
    }

    #[test]
    fn sheds_and_deadline_expiries_are_excluded_from_goodput() {
        let mut m = ServingMetrics::new();
        m.record(&Response {
            id: 0,
            tokens: vec![1; 4],
            ttft: Duration::from_millis(5),
            latency: Duration::from_millis(20),
            finish: FinishReason::MaxTokens,
        });
        m.record(&Response {
            id: 1,
            tokens: vec![],
            ttft: Duration::default(),
            latency: Duration::default(),
            finish: FinishReason::Shed,
        });
        // Deadline-busted work generated tokens, but they are not goodput.
        m.record(&Response {
            id: 2,
            tokens: vec![1; 7],
            ttft: Duration::from_millis(5),
            latency: Duration::from_millis(500),
            finish: FinishReason::DeadlineExceeded,
        });
        m.record(&Response {
            id: 3,
            tokens: vec![1; 2],
            ttft: Duration::from_millis(5),
            latency: Duration::from_millis(9),
            finish: FinishReason::EngineFault,
        });
        assert_eq!(m.completed, 4);
        assert_eq!(m.tokens_generated, 13);
        assert_eq!(m.goodput_tokens, 4);
        assert_eq!((m.shed, m.deadline_exceeded, m.engine_faults), (1, 1, 1));
        assert!((m.shed_rate() - 0.25).abs() < 1e-9);
        assert!(m.goodput_tokens_per_sec() <= m.tokens_per_sec());
        let rep = m.report();
        assert!(rep.contains("shed=1"));
    }

    #[test]
    fn kv_snapshot_is_optional_and_sticky() {
        let mut m = ServingMetrics::new();
        assert!(!m.report().contains("kv paged"), "no KV line without a paged engine");
        let kv = KvMetrics {
            page_tokens: 16,
            pool_pages: 40,
            pages_in_use: 12,
            peak_slot_resident_pages: 20,
            contiguous_worst_case_pages: 32,
            cow_copies: 3,
            prefix_hits: 6,
            prefix_misses: 2,
            prefix_insertions: 5,
            prefix_evictions: 1,
            prefix_pages_held: 4,
            numa_nodes: 1,
        };
        m.record_kv(Some(kv));
        // A later contiguous harvest (None) must not erase the snapshot.
        m.record_kv(None);
        let rep = m.report();
        assert!(rep.contains("kv paged:16"), "{rep}");
        assert!(rep.contains("peak resident=20 (contiguous worst case 32)"), "{rep}");
        assert!(rep.contains("hit rate=75.0%"), "{rep}");
        assert_eq!(m.kv.unwrap().cow_copies, 3);
    }

    #[test]
    fn spec_snapshot_is_optional_and_sticky() {
        let mut m = ServingMetrics::new();
        assert!(!m.report().contains("spec rounds"), "no spec line without a drafting engine");
        let st =
            SpecStats { rounds: 4, drafted: 16, accepted: 12, buffered: 12, fallback_steps: 1 };
        m.record_spec(Some(st));
        // A later harvest from a plain engine must not erase the snapshot.
        m.record_spec(None);
        let rep = m.report();
        assert!(rep.contains("spec rounds=4"), "{rep}");
        assert!(rep.contains("(75.0%)"), "{rep}");
        assert_eq!(m.spec.unwrap().accepted, 12);
    }

    #[test]
    fn pool_and_reclaim_snapshots_are_optional_and_sticky() {
        let mut m = ServingMetrics::new();
        let rep = m.report();
        assert!(!rep.contains("pool backend"), "no pool line without a pooled engine");
        assert!(!rep.contains("reclaim retired"), "no reclaim line without swapping");
        let ps = PoolStats {
            backend: "steal",
            workers: 4,
            dispatches: 9,
            executed: vec![3, 1, 2, 0],
            stolen: vec![0, 1, 0, 2],
            cross_node_steals: 1,
            queue_depth_hwm: 5,
            inline_reclaims: 0,
            dispatch_p50_us: 12.5,
            dispatch_p99_us: 40.0,
        };
        m.record_pool(Some(ps.clone()));
        m.record_reclaim(Some(ReclaimStats { retired: 2, reclaimed: 1, pending: 1, active_pins: 0 }));
        // Later harvests from engines without these counters must not
        // erase the snapshots.
        m.record_pool(None);
        m.record_reclaim(None);
        let rep = m.report();
        assert!(rep.contains("pool backend=steal workers=4 dispatches=9"), "{rep}");
        assert!(rep.contains("executed=6 stolen=3 cross_node=1"), "{rep}");
        assert!(rep.contains("reclaim retired=2 reclaimed=1 pending=1"), "{rep}");
        assert_eq!(m.pool.as_ref().unwrap(), &ps);
        assert_eq!(m.reclaim.unwrap().reclaimed, 1);
    }

    #[test]
    fn tpot_needs_at_least_two_tokens() {
        let one = Response {
            id: 0,
            tokens: vec![9],
            ttft: Duration::from_millis(4),
            latency: Duration::from_millis(4),
            finish: FinishReason::MaxTokens,
        };
        assert_eq!(one.tpot(), None);
        let three = Response {
            id: 1,
            tokens: vec![9, 9, 9],
            ttft: Duration::from_millis(4),
            latency: Duration::from_millis(10),
            finish: FinishReason::MaxTokens,
        };
        assert_eq!(three.tpot(), Some(Duration::from_millis(3)));
    }
}
