//! Request/response types and the synthetic multi-user workload generator.

use crate::util::Prng;
use std::time::{Duration, Instant};

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// An inference request from one user.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Prompt token ids (already tokenized — the paper's serving scenario
    /// receives pre-batched queries from Triton/RayLLM-style frontends).
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// Optional stop token.
    pub eos: Option<i32>,
    /// Arrival timestamp. Stamped at construction as a placeholder and
    /// **re-stamped by [`Batcher::submit`]** — deadline budgets measure
    /// queueing from the moment the serving system accepts the request,
    /// not from whenever the client happened to build it.
    ///
    /// [`Batcher::submit`]: super::Batcher::submit
    pub arrival: Instant,
    /// Total-latency budget from arrival. A request still running (or
    /// still queued) past this budget finishes with
    /// [`FinishReason::DeadlineExceeded`], carrying whatever tokens it
    /// generated so far.
    pub deadline: Option<Duration>,
    /// Time-to-first-token budget from arrival: if no token has been
    /// produced within it, the request finishes with
    /// [`FinishReason::DeadlineExceeded`].
    pub ttft_deadline: Option<Duration>,
}

impl Request {
    /// Build a request. An empty prompt is *accepted* here and rejected at
    /// admission with [`FinishReason::EmptyPrompt`] — panicking this deep
    /// would let one malformed client request abort the serving thread.
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            eos: None,
            arrival: Instant::now(),
            deadline: None,
            ttft_deadline: None,
        }
    }

    /// Attach a total-latency budget (measured from `arrival`).
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(budget);
        self
    }

    /// Attach a time-to-first-token budget (measured from `arrival`).
    pub fn with_ttft_deadline(mut self, budget: Duration) -> Self {
        self.ttft_deadline = Some(budget);
        self
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Time from arrival to first generated token.
    pub ttft: std::time::Duration,
    /// Time from arrival to completion.
    pub latency: std::time::Duration,
    /// Why generation stopped.
    pub finish: FinishReason,
}

impl Response {
    /// Time-per-output-token: mean decode cadence after the first token,
    /// `(latency - ttft) / (tokens - 1)`. `None` for responses with
    /// fewer than two tokens — a single token has TTFT but no cadence.
    pub fn tpot(&self) -> Option<Duration> {
        let n = self.tokens.len();
        if n < 2 {
            return None;
        }
        Some(self.latency.saturating_sub(self.ttft) / (n as u32 - 1))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    ContextFull,
    /// Rejected at admission: the prompt was empty, so there is nothing to
    /// prefill and no logits to sample from. The response carries zero
    /// tokens.
    EmptyPrompt,
    /// The engine's forward pass failed for this request even in
    /// isolation (after the batcher's solo retry). The response carries
    /// the tokens generated before the fault; every *other* in-flight
    /// request's token stream is unaffected.
    EngineFault,
    /// The request's TTFT or total-latency budget expired before it
    /// finished; the response carries the tokens generated so far.
    DeadlineExceeded,
    /// Shed at submission: the bounded admission queue was full. The
    /// response carries zero tokens and the caller may resubmit later.
    Shed,
}

/// Synthetic workload generator: Poisson arrivals, uniform prompt lengths,
/// geometric-ish output lengths — the multi-user serving mix of §V-A.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    prng: Prng,
    pub vocab: usize,
    pub prompt_len: (usize, usize),
    pub max_new: (usize, usize),
    pub rate_per_sec: f64,
    next_id: RequestId,
}

impl WorkloadGen {
    pub fn new(seed: u64, vocab: usize) -> Self {
        WorkloadGen {
            prng: Prng::new(seed),
            vocab,
            prompt_len: (4, 16),
            max_new: (8, 32),
            rate_per_sec: 50.0,
            next_id: 0,
        }
    }

    /// Next request plus the inter-arrival gap preceding it.
    pub fn next_request(&mut self) -> (Request, std::time::Duration) {
        let gap = self.prng.exp(self.rate_per_sec);
        let plen = self.prng.usize_in(self.prompt_len.0, self.prompt_len.1 + 1);
        let prompt: Vec<i32> = (0..plen)
            .map(|_| self.prng.usize_in(1, self.vocab) as i32)
            .collect();
        let max_new = self.prng.usize_in(self.max_new.0, self.max_new.1 + 1);
        let id = self.next_id;
        self.next_id += 1;
        (Request::new(id, prompt, max_new), std::time::Duration::from_secs_f64(gap))
    }

    /// A batch of requests all arriving now.
    pub fn burst(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request().0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_in_range() {
        let mut a = WorkloadGen::new(9, 100);
        let mut b = WorkloadGen::new(9, 100);
        for _ in 0..50 {
            let (ra, ga) = a.next_request();
            let (rb, gb) = b.next_request();
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ga, gb);
            assert!(ra.prompt.iter().all(|&t| t >= 1 && (t as usize) < 100));
            assert!(ra.prompt.len() >= 4 && ra.prompt.len() <= 16);
            assert!(ra.max_new_tokens >= 8 && ra.max_new_tokens <= 32);
        }
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let mut g = WorkloadGen::new(1, 100);
        let reqs = g.burst(20);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn empty_prompt_constructible_rejection_happens_at_admission() {
        // Regression (pre-PR this asserted): construction must not panic —
        // the batcher turns the request into a zero-token `EmptyPrompt`
        // response instead (see `coordinator::batcher` tests).
        let r = Request::new(0, vec![], 4);
        assert!(r.prompt.is_empty());
    }
}
