//! Iteration-level batching over a fixed slot set.
//!
//! Every call to [`Batcher::run_iteration`] advances all active slots by
//! one token (prompt tokens are consumed first — prefill-as-decode, the
//! token-at-a-time regime of the paper's generation-stage evaluation) and
//! admits pending requests into free slots FIFO. Completed requests are
//! returned with latency metadata.
//!
//! Invariants (property-tested):
//! - a slot is reset before every admission (no KV leakage),
//! - per-slot positions increase by exactly 1 per active iteration,
//! - FIFO admission: requests start in arrival order,
//! - every request eventually completes (no starvation),
//! - outputs are identical to running each request alone (isolation).

use std::time::Instant;

use anyhow::Result;

use super::engine::DecodeEngine;
use super::policy::{AdmissionPolicy, AdmissionQueue};
use super::request::{FinishReason, Request, Response};

/// Batcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Emit the prompt's last token's logits as the first generated token
    /// (standard next-token semantics).
    pub eos_enabled: bool,
    /// Queue discipline for admissions.
    pub policy: AdmissionPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { eos_enabled: true, policy: AdmissionPolicy::Fifo }
    }
}

#[derive(Debug)]
struct Slot {
    req: Request,
    /// Next prompt token to feed (prefill cursor).
    prompt_idx: usize,
    /// Position of the *next* token to be written to the KV cache.
    pos: i32,
    /// Token to feed this iteration.
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
}

/// The iteration-level batcher.
pub struct Batcher<E: DecodeEngine> {
    engine: E,
    slots: Vec<Option<Slot>>,
    queue: AdmissionQueue,
    cfg: BatcherConfig,
    iterations: u64,
    admitted: u64,
}

impl<E: DecodeEngine> Batcher<E> {
    pub fn new(engine: E, cfg: BatcherConfig) -> Self {
        let b = engine.batch();
        Batcher {
            engine,
            slots: (0..b).map(|_| None).collect(),
            queue: AdmissionQueue::new(cfg.policy),
            cfg,
            iterations: 0,
            admitted: 0,
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) {
        self.queue.push(req, self.iterations);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active_slots() == 0
    }

    /// Admit queued requests into free slots (FIFO), resetting slot KV.
    fn admit(&mut self) -> Result<()> {
        for s in 0..self.slots.len() {
            if self.slots[s].is_none() {
                if let Some(req) = self.queue.pop(self.iterations) {
                    self.engine.reset_slot(s)?;
                    self.admitted += 1;
                    let first = req.prompt[0];
                    self.slots[s] = Some(Slot {
                        req,
                        prompt_idx: 1,
                        pos: 0,
                        next_input: first,
                        generated: Vec::new(),
                        first_token_at: None,
                    });
                } else {
                    break;
                }
            }
        }
        Ok(())
    }

    /// One iteration: admit, step the engine once, harvest completions.
    pub fn run_iteration(&mut self) -> Result<Vec<Response>> {
        self.admit()?;
        if self.active_slots() == 0 {
            return Ok(Vec::new());
        }
        let b = self.slots.len();
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        for (s, slot) in self.slots.iter().enumerate() {
            if let Some(sl) = slot {
                tokens[s] = sl.next_input;
                positions[s] = sl.pos;
                active[s] = true;
            }
        }
        let next = self.engine.step(&tokens, &positions, &active)?;
        self.iterations += 1;

        let mut done = Vec::new();
        let max_ctx = self.engine.max_context() as i32;
        for (s, slot) in self.slots.iter_mut().enumerate() {
            let Some(sl) = slot.as_mut() else { continue };
            sl.pos += 1;
            if sl.prompt_idx < sl.req.prompt.len() {
                // Still prefilling: feed the next prompt token, discard
                // the model's prediction.
                sl.next_input = sl.req.prompt[sl.prompt_idx];
                sl.prompt_idx += 1;
            } else {
                // Generating.
                let tok = next[s];
                if sl.first_token_at.is_none() {
                    sl.first_token_at = Some(Instant::now());
                }
                sl.generated.push(tok);
                sl.next_input = tok;
                let eos_hit =
                    self.cfg.eos_enabled && sl.req.eos.map(|e| e == tok).unwrap_or(false);
                let budget_hit = sl.generated.len() >= sl.req.max_new_tokens;
                let ctx_hit = sl.pos >= max_ctx;
                if eos_hit || budget_hit || ctx_hit {
                    let sl = slot.take().unwrap();
                    let now = Instant::now();
                    done.push(Response {
                        id: sl.req.id,
                        tokens: sl.generated,
                        ttft: sl
                            .first_token_at
                            .map(|t| t - sl.req.arrival)
                            .unwrap_or_default(),
                        latency: now - sl.req.arrival,
                        finish: if eos_hit {
                            FinishReason::Eos
                        } else if budget_hit {
                            FinishReason::MaxTokens
                        } else {
                            FinishReason::ContextFull
                        },
                    });
                }
            }
        }
        Ok(done)
    }

    /// Drive iterations until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut guard = 0u64;
        while !self.is_idle() {
            out.extend(self.run_iteration()?);
            guard += 1;
            assert!(guard < 10_000_000, "batcher livelock");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::request::Request;
    use crate::util::{propcheck, Prng};

    fn mk_batcher(batch: usize) -> Batcher<MockEngine> {
        Batcher::new(MockEngine::new(batch, 97, 64), BatcherConfig::default())
    }

    fn mk_req(id: u64, prng: &mut Prng) -> Request {
        let plen = prng.usize_in(1, 6);
        let prompt = (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
        Request::new(id, prompt, prng.usize_in(1, 10))
    }

    #[test]
    fn single_request_generates_budgeted_tokens() {
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![5, 6], 4));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn all_requests_complete_no_starvation() {
        propcheck::check(
            "batcher-completion",
            propcheck::Config { cases: 40, seed: 77 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let n_req = p.usize_in(1, 20);
                let seed = p.next_u64();
                (batch, n_req, seed)
            },
            |&(batch, n_req, seed)| {
                let mut prng = Prng::new(seed);
                let mut b = mk_batcher(batch);
                for id in 0..n_req {
                    b.submit(mk_req(id as u64, &mut prng));
                }
                let done = b.run_to_completion().unwrap();
                if done.len() != n_req {
                    return Err(format!("{} of {n_req} completed", done.len()));
                }
                let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                if ids != (0..n_req as u64).collect::<Vec<_>>() {
                    return Err("duplicate or missing ids".into());
                }
                for r in &done {
                    if r.tokens.is_empty() {
                        return Err(format!("request {} got no tokens", r.id));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batched_output_matches_isolated_output() {
        // Isolation invariant: co-scheduling must not change any request's
        // tokens (the mock's state is per-slot, reset on admission — if
        // the batcher leaked state across admissions this would differ).
        let mut prng = Prng::new(123);
        let reqs: Vec<Request> = (0..10).map(|id| mk_req(id, &mut prng)).collect();

        // Isolated runs, batch=1.
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = mk_batcher(1);
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }

        // Co-scheduled run, batch=3.
        let mut b = mk_batcher(3);
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(
                &resp.tokens, &isolated[&resp.id],
                "request {} diverged under batching",
                resp.id
            );
        }
    }

    #[test]
    fn fifo_admission_order() {
        // With batch=1, completion order must equal submission order.
        let mut prng = Prng::new(5);
        let mut b = mk_batcher(1);
        for id in 0..6 {
            b.submit(mk_req(id, &mut prng));
        }
        let done = b.run_to_completion().unwrap();
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn eos_stops_generation() {
        let mut b = mk_batcher(1);
        // Find what the mock will emit, then use it as EOS.
        let mut probe = mk_batcher(1);
        probe.submit(Request::new(0, vec![5], 3));
        let toks = probe.run_to_completion().unwrap()[0].tokens.clone();
        let mut req = Request::new(1, vec![5], 3);
        req.eos = Some(toks[0]);
        b.submit(req);
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::Eos);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = Batcher::new(MockEngine::new(1, 97, 8), BatcherConfig::default());
        b.submit(Request::new(0, vec![1, 2, 3], 100));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        // Positions 0..7 hold 3 prompt + 5 generated inputs; the 6th
        // generated token is predicted from position 7 without needing a
        // KV slot of its own.
        assert_eq!(done[0].tokens.len(), 6);
    }

    #[test]
    fn sjf_policy_admits_short_jobs_first() {
        let cfg = BatcherConfig {
            policy: AdmissionPolicy::ShortestJobFirst { aging_step: 1000 },
            ..BatcherConfig::default()
        };
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        b.submit(Request::new(0, vec![1], 20));
        b.submit(Request::new(1, vec![1], 2));
        b.submit(Request::new(2, vec![1], 5));
        let done = b.run_to_completion().unwrap();
        // All three are queued before the first iteration, so SJF admits
        // (and with one slot, completes) them shortest-budget-first.
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        assert_eq!(done.iter().map(|r| r.tokens.len()).sum::<usize>(), 27);
    }

    #[test]
    fn iterations_count_tokens_at_a_time() {
        let mut b = mk_batcher(4);
        // 4 requests, 1-token prompts, 5 tokens each: perfect batching
        // needs exactly 1 prefill + 5 generation iterations.
        for id in 0..4 {
            b.submit(Request::new(id, vec![7], 5));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        assert_eq!(b.iterations(), 5);
    }
}
