//! Iteration-level batching over a fixed slot set, with chunked prefill.
//!
//! Every call to [`Batcher::run_iteration`] advances all active slots and
//! admits pending requests into free slots FIFO. Each active slot submits
//! one [`SlotRun`] per iteration: a **generating** slot feeds its last
//! sampled token (one row), a **prefilling** slot feeds up to
//! [`BatcherConfig::prefill_chunk`] prompt tokens at once — the chunked
//! prefill that amortizes one LUT build per weight chunk across the whole
//! `Σ rows` iteration batch (§III's high-data-reuse argument applied to
//! the sequence axis) instead of rebuilding it per token. Prefill chunks
//! and single-token decode rows co-schedule in the same iteration
//! (continuous batching); [`BatcherConfig::iteration_rows`] caps the
//! per-iteration row total so a burst of long prompts cannot starve
//! in-flight decodes of latency. Completed requests are returned with
//! latency metadata; TTFT is stamped at the first *sampled* token, which
//! arrives with the run that consumes the prompt's last token.
//!
//! Invariants (property-tested, at every chunk size):
//! - a slot is reset before every admission (no KV leakage),
//! - per-slot positions advance by exactly the rows the slot submitted,
//!   contiguously,
//! - no active position ever reaches `max_context` — over-long prompts
//!   finish with `ContextFull` *during prefill*, before an out-of-window
//!   KV write could happen,
//! - empty prompts are answered at admission (`EmptyPrompt`, zero tokens)
//!   instead of crashing the serving thread,
//! - FIFO admission: requests start in arrival order,
//! - every request eventually completes (no starvation — every active
//!   slot is guaranteed at least one row per iteration regardless of the
//!   row budget),
//! - outputs are identical to running each request alone (isolation), and
//!   **bit-identical across prefill chunk sizes** — `prefill_chunk: 1`
//!   reproduces the pre-chunking token-at-a-time batcher exactly,
//! - **fault isolation**: a failed batched forward is retried run-by-run;
//!   only requests that fail in isolation finish (typed,
//!   `FinishReason::EngineFault`, tokens-so-far), every other slot's
//!   stream is bit-identical to the fault-free run, and no engine error
//!   or panic escapes [`Batcher::run_iteration`],
//! - per-request TTFT/total-latency budgets finish expired requests with
//!   `DeadlineExceeded` (tokens-so-far). The deadline clock starts at
//!   [`Batcher::submit`] (not at `Request` construction), and *queued*
//!   requests are swept every iteration — an expiree parked behind busy
//!   slots finishes typed without ever consuming a slot, engine work, or
//!   bounded-queue capacity,
//! - the admission queue is bounded ([`BatcherConfig::queue_capacity`]):
//!   submissions past the bound are shed with a typed zero-token
//!   `Shed` response ([`Admission::Shed`]) instead of growing memory
//!   without limit,
//! - **preemption is invisible in the streams**: [`Batcher::preempt`]
//!   evicts a slot mid-flight and re-queues it for recompute-resume
//!   (feed = prompt ⊕ tokens generated so far, so the resumed prefill's
//!   final logits sample the *next* token); the preempted request's
//!   completed stream is bit-identical to an uninterrupted run, and the
//!   freed slot goes to a queued waiter before the victim is re-admitted.
//!
//! [`Batcher::run_iteration_events`] additionally reports each iteration's
//! sampled `(request, token)` pairs in slot order — the serving front-end
//! (`coordinator::serving`) forwards them over per-request stream channels
//! as they are produced.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::engine::{DecodeEngine, SlotRun};
use super::policy::{AdmissionPolicy, AdmissionQueue};
use super::request::{FinishReason, Request, RequestId, Response};

/// Strict parse of a `SAIL_PREFILL_CHUNK` value: an integer ≥ 1, or a
/// typed error naming what was wrong. Pure so the malformed forms are
/// testable without mutating the process environment.
pub fn parse_prefill_chunk(v: &str) -> Result<usize, String> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("invalid SAIL_PREFILL_CHUNK value '{v}' (want an integer ≥ 1)")),
    }
}

/// The `SAIL_PREFILL_CHUNK` environment override: the per-slot prefill
/// chunk [`BatcherConfig::default`] resolves (absent ⇒ 1, the
/// token-at-a-time regime). The CI matrix drives the whole test suite
/// through it, the same way `SAIL_POOL_THREADS`/`SAIL_NUMA` sweep pool
/// width and placement.
///
/// A malformed value is reported on stderr and ignored (⇒ the chunk-1
/// default) — one bad environment variable must not abort a serving
/// process. Strict callers use [`parse_prefill_chunk`] directly.
pub fn prefill_chunk_from_env() -> Option<usize> {
    let v = std::env::var("SAIL_PREFILL_CHUNK").ok()?;
    match parse_prefill_chunk(&v) {
        Ok(n) => Some(n),
        Err(e) => {
            eprintln!("sail: {e}; falling back to the default prefill chunk");
            None
        }
    }
}

/// Batcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Honour requests' `eos` stop token: when enabled, a generated token
    /// equal to `Request::eos` finishes the request with
    /// [`FinishReason::Eos`]; when disabled, generation runs to the token
    /// budget (or the context limit) even through stop tokens.
    pub eos_enabled: bool,
    /// Queue discipline for admissions.
    pub policy: AdmissionPolicy,
    /// Most prompt tokens one slot may consume per iteration. 1 is the
    /// pre-chunking prefill-as-decode regime; larger values amortize LUT
    /// builds across the chunk. Clamped to the engine's
    /// [`max_run`](DecodeEngine::max_run) capability at run time, so a
    /// single-token engine (PJRT) under a chunked config degrades to
    /// token-at-a-time instead of erroring. Token streams are identical
    /// at every value.
    pub prefill_chunk: usize,
    /// Per-iteration cap on total submitted rows across all slots.
    /// Every active slot is always granted one row (no slot can starve);
    /// the budget trims only the *extra* prefill rows stacked on top, so
    /// a burst of long prompts shares the iteration with in-flight
    /// decodes instead of monopolizing it. `usize::MAX` = uncapped.
    pub iteration_rows: usize,
    /// Most requests the admission queue may hold. A submission past the
    /// bound is *shed*: [`Batcher::submit`] returns a zero-token
    /// [`FinishReason::Shed`] response instead of growing the queue
    /// without bound. `usize::MAX` = unbounded (the historical default).
    pub queue_capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            eos_enabled: true,
            policy: AdmissionPolicy::Fifo,
            prefill_chunk: prefill_chunk_from_env().unwrap_or(1),
            iteration_rows: usize::MAX,
            queue_capacity: usize::MAX,
        }
    }
}

/// Outcome of [`Batcher::submit`]: either the request entered the
/// admission queue, or it was answered synchronously (backpressure shed,
/// today) and will never produce further events.
///
/// Pre-PR `submit` returned `Option<Response>`, conflating "queued"
/// (`None`) with "rejected right now" in a way callers routinely read
/// backwards — `serve_multiuser` silently dropped sheds because the
/// `Some` arm looked like a completion.
#[derive(Debug)]
pub enum Admission {
    /// The request is queued; its response arrives from a later
    /// [`Batcher::run_iteration`].
    Queued,
    /// The request was answered immediately (zero tokens,
    /// [`FinishReason::Shed`]); the caller may retry later.
    Shed(Response),
}

impl Admission {
    pub fn is_queued(&self) -> bool {
        matches!(self, Admission::Queued)
    }

    /// The synchronous rejection, if any.
    pub fn shed(self) -> Option<Response> {
        match self {
            Admission::Queued => None,
            Admission::Shed(r) => Some(r),
        }
    }
}

/// What one [`Batcher::run_iteration_events`] call did — the serving
/// front-end's window into the iteration loop.
#[derive(Debug, Default)]
pub struct IterationEvents {
    /// Engine rows submitted this iteration (0 when no slot was active).
    pub rows: usize,
    /// Tokens sampled this iteration, in slot order. Includes the final
    /// token of a request that completed this same iteration — streams
    /// carry every token exactly once.
    pub tokens: Vec<(RequestId, i32)>,
    /// Requests that finished this iteration (including queued expirees
    /// and admission rejections).
    pub done: Vec<Response>,
}

/// Read-only view of an active slot for scheduling decisions (the
/// serving front-end's preemption-victim policy).
#[derive(Debug, Clone)]
pub struct SlotSummary {
    pub slot: usize,
    pub id: RequestId,
    /// Still consuming prefill feed (no KV-complete state worth keeping).
    pub prefilling: bool,
    /// Tokens generated so far.
    pub generated: usize,
    /// Generation budget left (`max_new_tokens - generated`).
    pub remaining_budget: usize,
    /// The request carries its own TTFT or total-latency budget.
    pub has_deadline: bool,
}

#[derive(Debug)]
struct Slot {
    req: Request,
    /// Tokens to prefill *instead of* `req.prompt` when non-empty: set on
    /// recompute-resume to prompt ⊕ previously generated tokens, so the
    /// re-prefill rebuilds the evicted KV state and its final logits
    /// sample the next new token.
    resume_feed: Vec<i32>,
    /// Feed tokens already consumed by the engine (prefill cursor).
    fed: usize,
    /// Position of the *next* token to be written to the KV cache.
    pos: i32,
    /// Generation input: the token sampled last iteration (meaningful
    /// once the feed is fully consumed).
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
}

impl Slot {
    /// The prefill feed: the prompt, or the recompute-resume feed after a
    /// preemption.
    fn feed(&self) -> &[i32] {
        if self.resume_feed.is_empty() {
            &self.req.prompt
        } else {
            &self.resume_feed
        }
    }
}

/// A request evicted mid-flight by [`Batcher::preempt`], waiting to be
/// re-admitted and recomputed.
#[derive(Debug)]
struct Preempted {
    req: Request,
    /// prompt ⊕ generated — the full recompute-resume feed.
    feed: Vec<i32>,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    /// Earliest iteration at which re-admission is allowed. Set to the
    /// iteration *after* the eviction so the freed slot goes to a queued
    /// waiter first — re-admitting the victim immediately would make
    /// preemption a no-op.
    not_before: u64,
}

/// True when `req`'s total-latency budget — or, while no token has been
/// produced yet, its TTFT budget — has expired.
fn deadline_expired(req: &Request, has_first_token: bool) -> bool {
    let elapsed = req.arrival.elapsed();
    if req.deadline.is_some_and(|d| elapsed >= d) {
        return true;
    }
    !has_first_token && req.ttft_deadline.is_some_and(|d| elapsed >= d)
}

/// The iteration-level batcher.
pub struct Batcher<E: DecodeEngine> {
    engine: E,
    slots: Vec<Option<Slot>>,
    queue: AdmissionQueue,
    /// Preempted requests awaiting recompute-resume, FIFO.
    resume: VecDeque<Preempted>,
    cfg: BatcherConfig,
    iterations: u64,
    admitted: u64,
}

impl<E: DecodeEngine> Batcher<E> {
    /// Wrap `engine` with `engine.batch()` serving slots. The batcher owns
    /// the engine; drive it with [`run_iteration`](Batcher::run_iteration)
    /// or [`run_to_completion`](Batcher::run_to_completion).
    pub fn new(engine: E, cfg: BatcherConfig) -> Self {
        let b = engine.batch();
        Batcher {
            engine,
            slots: (0..b).map(|_| None).collect(),
            queue: AdmissionQueue::new(cfg.policy),
            resume: VecDeque::new(),
            cfg,
            iterations: 0,
            admitted: 0,
        }
    }

    /// The wrapped decode engine (read-only; tests and metrics use it to
    /// inspect per-projection kernel stats).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Mutable access to the wrapped engine — the serving loop drives
    /// live weight swaps ([`DecodeEngine::swap_weights`]) through this
    /// between iterations, never mid-iteration.
    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    /// Enqueue a request (admitted into a free slot, FIFO by default, at
    /// the start of a later iteration).
    ///
    /// The request's `arrival` is re-stamped here: deadline budgets
    /// measure from the moment the serving system accepts the request,
    /// not from `Request` construction (pre-PR, a request built early —
    /// e.g. a whole workload generated up front — burned its budget
    /// before it was ever submitted).
    ///
    /// Returns [`Admission::Queued`] when the request entered the queue.
    /// When the bounded admission queue
    /// ([`BatcherConfig::queue_capacity`]) is full the request is
    /// **shed** instead: [`Admission::Shed`] carries the zero-token
    /// [`FinishReason::Shed`] response answering it immediately, and the
    /// queue is left untouched.
    pub fn submit(&mut self, mut req: Request) -> Admission {
        req.arrival = Instant::now();
        match self.queue.push_bounded(req, self.iterations, self.cfg.queue_capacity) {
            Ok(()) => Admission::Queued,
            Err(req) => Admission::Shed(Response {
                id: req.id,
                tokens: Vec::new(),
                ttft: Duration::default(),
                latency: Instant::now() - req.arrival,
                finish: FinishReason::Shed,
            }),
        }
    }

    /// Requests waiting to run: queued plus preempted-awaiting-resume.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.resume.len()
    }

    /// Requests waiting in the admission queue (excluding preempted
    /// requests awaiting resume).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Preempted requests awaiting recompute-resume.
    pub fn resumable(&self) -> usize {
        self.resume.len()
    }

    /// Slots currently serving a request.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Free slots (admission capacity this iteration).
    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// True when nothing is queued, nothing awaits resume, and no slot is
    /// active.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.resume.is_empty() && self.active_slots() == 0
    }

    /// Replace the per-iteration row budget
    /// ([`BatcherConfig::iteration_rows`]). The serving front-end's
    /// SLO scheduler retunes this between iterations to trade prefill
    /// throughput (TTFT) against decode cadence (TPOT); the budget never
    /// changes *what* tokens are produced, only how iterations pack rows.
    pub fn set_iteration_rows(&mut self, rows: usize) {
        self.cfg.iteration_rows = rows.max(1);
    }

    /// Current per-iteration row budget.
    pub fn iteration_rows(&self) -> usize {
        self.cfg.iteration_rows
    }

    /// Smallest remaining TTFT budget over the *queued* requests — how
    /// close the most urgent waiter is to busting its first-token
    /// deadline. `None` when no queued request carries a TTFT budget.
    pub fn min_queued_ttft_headroom(&self) -> Option<Duration> {
        self.queue
            .iter()
            .filter_map(|r| r.ttft_deadline.map(|d| d.saturating_sub(r.arrival.elapsed())))
            .min()
    }

    /// Summaries of the active slots, for scheduling decisions.
    pub fn slot_summaries(&self) -> Vec<SlotSummary> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(s, slot)| {
                slot.as_ref().map(|sl| SlotSummary {
                    slot: s,
                    id: sl.req.id,
                    prefilling: sl.fed < sl.feed().len(),
                    generated: sl.generated.len(),
                    remaining_budget: sl.req.max_new_tokens.saturating_sub(sl.generated.len()),
                    has_deadline: sl.req.deadline.is_some() || sl.req.ttft_deadline.is_some(),
                })
            })
            .collect()
    }

    /// Evict the request on `slot` mid-flight and queue it for
    /// recompute-resume; returns false when the slot is empty. The
    /// victim's KV state is discarded — on re-admission it re-prefills
    /// prompt ⊕ generated-so-far (so the resumed run's first sample is
    /// the *next* new token) and its completed stream is bit-identical
    /// to an uninterrupted run. Resume is deferred by one iteration so
    /// the freed slot goes to a queued waiter first.
    pub fn preempt(&mut self, slot: usize) -> bool {
        let Some(sl) = self.slots.get_mut(slot).and_then(Option::take) else {
            return false;
        };
        let mut feed = sl.req.prompt.clone();
        feed.extend_from_slice(&sl.generated);
        self.resume.push_back(Preempted {
            req: sl.req,
            feed,
            generated: sl.generated,
            first_token_at: sl.first_token_at,
            not_before: self.iterations + 1,
        });
        true
    }

    /// Pop the next resumable preempted request. Preempted requests
    /// outrank the main queue (they are the oldest work in the system)
    /// *except* during the eviction iteration itself, where the queued
    /// waiters the preemption was for go first — once the queue is
    /// drained (or the deferral iteration has passed) the victim takes
    /// any free slot.
    fn pop_resume(&mut self) -> Option<Preempted> {
        let ready = self
            .resume
            .front()
            .is_some_and(|p| p.not_before <= self.iterations || self.queue.is_empty());
        if ready {
            self.resume.pop_front()
        } else {
            None
        }
    }

    /// Ask the engine's prefix cache to map the longest cached KV prefix
    /// of `feed` into the freshly reset `slot`, returning the number of
    /// feed tokens whose KV is already resident — prefill starts there.
    ///
    /// The split is clamped to `max_context - 1`: a cached prefix exactly
    /// filling the window (possible when a full-window prompt was
    /// inserted) must still leave one feedable position, so an over-long
    /// prompt sharing it walks into the usual `ContextFull`-during-prefill
    /// path instead of submitting a run at position `max_context`.
    /// Engines without a prefix cache report 0 (cold start) and the
    /// admission below is byte-for-byte the pre-paging behaviour.
    fn attach_prefix(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        let split = self.engine.prefix_attach(slot, feed)?;
        Ok(split.min(self.engine.max_context().saturating_sub(1)))
    }

    /// Admit pending requests into free slots (resume queue first, then
    /// the admission queue), resetting slot KV.
    ///
    /// Admission hardening: a request with an empty prompt cannot be
    /// prefilled (there is no first token to feed) — it is answered
    /// immediately with a zero-token [`FinishReason::EmptyPrompt`]
    /// response pushed onto `done` instead of crashing the serving thread,
    /// and the slot stays free for the next queued request.
    fn admit(&mut self, done: &mut Vec<Response>) -> Result<()> {
        for s in 0..self.slots.len() {
            while self.slots[s].is_none() {
                if let Some(p) = self.pop_resume() {
                    if deadline_expired(&p.req, p.first_token_at.is_some()) {
                        done.push(Response {
                            id: p.req.id,
                            tokens: p.generated,
                            ttft: p
                                .first_token_at
                                .map(|t| t - p.req.arrival)
                                .unwrap_or_default(),
                            latency: Instant::now() - p.req.arrival,
                            finish: FinishReason::DeadlineExceeded,
                        });
                        continue;
                    }
                    self.engine.reset_slot(s)?;
                    self.admitted += 1;
                    let split = self.attach_prefix(s, &p.feed)?;
                    self.slots[s] = Some(Slot {
                        req: p.req,
                        resume_feed: p.feed,
                        fed: split,
                        pos: split as i32,
                        next_input: 0,
                        generated: p.generated,
                        first_token_at: p.first_token_at,
                    });
                    continue;
                }
                let Some(req) = self.queue.pop(self.iterations) else {
                    return Ok(());
                };
                if deadline_expired(&req, false) {
                    // The budget ran out while the request was queued: it
                    // finishes here, before consuming a slot or any
                    // engine work.
                    done.push(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        ttft: Duration::default(),
                        latency: Instant::now() - req.arrival,
                        finish: FinishReason::DeadlineExceeded,
                    });
                    continue;
                }
                if req.prompt.is_empty() {
                    done.push(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        ttft: std::time::Duration::default(),
                        latency: Instant::now() - req.arrival,
                        finish: FinishReason::EmptyPrompt,
                    });
                    continue;
                }
                self.engine.reset_slot(s)?;
                self.admitted += 1;
                let split = self.attach_prefix(s, &req.prompt)?;
                self.slots[s] = Some(Slot {
                    req,
                    resume_feed: Vec::new(),
                    fed: split,
                    pos: split as i32,
                    next_input: 0,
                    generated: Vec::new(),
                    first_token_at: None,
                });
            }
        }
        Ok(())
    }

    /// One iteration: admit, submit one [`SlotRun`] per active slot
    /// (prefill chunks alongside single-token decode rows), harvest
    /// completions. Thin wrapper over
    /// [`run_iteration_events`](Batcher::run_iteration_events) for
    /// callers that only want completions.
    pub fn run_iteration(&mut self) -> Result<Vec<Response>> {
        Ok(self.run_iteration_events()?.done)
    }

    /// One iteration, reporting everything that happened: rows submitted,
    /// tokens sampled (in slot order — the serving front-end forwards
    /// these over per-request streams), and completed responses.
    pub fn run_iteration_events(&mut self) -> Result<IterationEvents> {
        let mut ev = IterationEvents::default();
        // Queued-expiree sweep: a request whose budget ran out *while
        // waiting in the queue* finishes now — typed, without consuming a
        // slot, engine work, or bounded-queue capacity. Pre-PR the queue
        // was only checked at pop time, so behind busy slots an expiree
        // could wait forever (and hold a queue seat that shed live
        // requests).
        if !self.queue.is_empty() {
            for req in self.queue.drain_matching(|r| deadline_expired(r, false)) {
                ev.done.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft: Duration::default(),
                    latency: Instant::now() - req.arrival,
                    finish: FinishReason::DeadlineExceeded,
                });
            }
        }
        self.admit(&mut ev.done)?;
        // Deadline sweep: an active request whose TTFT or total-latency
        // budget expired finishes now, with the tokens it generated so
        // far, before any further engine work is spent on it.
        for slot in &mut self.slots {
            if slot.as_ref().is_some_and(|sl| {
                deadline_expired(&sl.req, sl.first_token_at.is_some())
            }) {
                let sl = slot.take().unwrap();
                ev.done.push(Response {
                    id: sl.req.id,
                    tokens: sl.generated,
                    ttft: sl.first_token_at.map(|t| t - sl.req.arrival).unwrap_or_default(),
                    latency: Instant::now() - sl.req.arrival,
                    finish: FinishReason::DeadlineExceeded,
                });
            }
        }
        let active = self.active_slots();
        if active == 0 {
            return Ok(ev);
        }
        let max_ctx = self.engine.max_context();
        // The per-slot chunk: config clamped to the engine's capability.
        let chunk = self.cfg.prefill_chunk.max(1).min(self.engine.max_run().max(1));
        // Every active slot is guaranteed one row; the row budget caps
        // only the extra prefill rows, so no slot can stall.
        let mut extra_budget = self.cfg.iteration_rows.max(active) - active;

        let mut runs: Vec<SlotRun> = Vec::with_capacity(active);
        for (s, slot) in self.slots.iter().enumerate() {
            let Some(sl) = slot else { continue };
            let feed = sl.feed();
            if sl.fed < feed.len() {
                // Prefilling: up to `chunk` feed tokens, clamped so the
                // run never reaches position `max_context` (ContextFull is
                // raised below, before an out-of-window KV write could
                // happen) and never overdraws the iteration row budget.
                let remaining = feed.len() - sl.fed;
                let avail = max_ctx.saturating_sub(sl.pos as usize);
                debug_assert!(avail > 0, "prefilling slot left with a full window");
                let extra =
                    (chunk - 1).min(remaining - 1).min(avail.saturating_sub(1)).min(extra_budget);
                extra_budget -= extra;
                runs.push(SlotRun {
                    slot: s,
                    tokens: &feed[sl.fed..sl.fed + 1 + extra],
                    start_pos: sl.pos,
                });
            } else {
                // Generating: one row, feeding the last sampled token.
                runs.push(SlotRun {
                    slot: s,
                    tokens: std::slice::from_ref(&sl.next_input),
                    start_pos: sl.pos,
                });
            }
        }
        // Whatever the prefill rows left of the iteration budget is the
        // speculation grant: a speculative engine spends it on draft +
        // verify rows (2 per drafted token), plain engines ignore it.
        // Granting zero never stalls a slot — every run above already
        // holds its guaranteed row, speculation just degrades to plain
        // decode (same tokens, fewer of them per iteration).
        self.engine.spec_grant(extra_budget);
        // Fault isolation: a failed batched forward must not take down
        // the batch. Each run is retried alone — solo re-execution is
        // bit-identical by the engine's isolation contract, so healthy
        // slots' token streams are exactly what the fault-free batch
        // would have produced. Only runs that fail *in isolation* finish
        // with [`FinishReason::EngineFault`]; no engine error (or panic)
        // escapes this method through the forward path.
        let (next, faulted) = match self.engine.step_runs(&runs) {
            Ok(next) => (next, Vec::new()),
            Err(_) => {
                let mut next = Vec::with_capacity(runs.len());
                let mut faulted: Vec<usize> = Vec::new();
                for r in &runs {
                    match self.engine.step_runs(std::slice::from_ref(r)) {
                        Ok(one) if !one.is_empty() => next.push(one[0]),
                        _ => {
                            next.push(0); // placeholder; the slot is finished below
                            faulted.push(r.slot);
                        }
                    }
                }
                (next, faulted)
            }
        };
        let consumed: Vec<(usize, usize)> = runs.iter().map(|r| (r.slot, r.tokens.len())).collect();
        drop(runs);
        self.iterations += 1;
        ev.rows = consumed.iter().map(|(_, len)| len).sum();

        let max_ctx = max_ctx as i32;
        for ((s, len), tok) in consumed.into_iter().zip(next) {
            if faulted.contains(&s) {
                // This run's forward failed even in isolation: finish the
                // request with the tokens generated before the fault. Its
                // slot is reset (KV pane and any latched injected fault)
                // on the next admission.
                if let Some(sl) = self.slots[s].take() {
                    ev.done.push(Response {
                        id: sl.req.id,
                        tokens: sl.generated,
                        ttft: sl.first_token_at.map(|t| t - sl.req.arrival).unwrap_or_default(),
                        latency: Instant::now() - sl.req.arrival,
                        finish: FinishReason::EngineFault,
                    });
                }
                continue;
            }
            let slot = &mut self.slots[s];
            let Some(sl) = slot.as_mut() else { continue };
            sl.pos += len as i32;
            if sl.fed < sl.feed().len() {
                sl.fed += len;
                if sl.fed < sl.feed().len() {
                    if sl.pos >= max_ctx {
                        // The KV window is exhausted with feed tokens
                        // still unfed: feeding another would write KV
                        // position `max_context` out of bounds. Only an
                        // over-long *prompt* can get here (a resume feed
                        // fits by construction — its positions were all
                        // valid before the eviction), so no logits were
                        // ever sampled and the response carries zero
                        // tokens — identical at every chunk size, because
                        // runs are clamped to the window above.
                        let sl = slot.take().unwrap();
                        ev.done.push(Response {
                            id: sl.req.id,
                            tokens: sl.generated,
                            ttft: sl
                                .first_token_at
                                .map(|t| t - sl.req.arrival)
                                .unwrap_or_default(),
                            latency: Instant::now() - sl.req.arrival,
                            finish: FinishReason::ContextFull,
                        });
                    }
                    // Still prefilling: the run's prediction is discarded.
                    // For a resume feed that includes re-computing
                    // previously generated tokens — they were already
                    // streamed before the eviction.
                    continue;
                }
                // This run consumed the feed's last token: `tok`,
                // predicted from that final position, is the request's
                // next sampled token — the *first* for a fresh prompt
                // (TTFT stamps below), the first *new* one after a
                // recompute-resume — fall through to generation handling.
                //
                // The slot's KV now covers the whole feed: publish its
                // full pages into the prefix cache so later requests
                // sharing the prefix attach instead of re-prefilling (a
                // no-op on engines without a prefix cache).
                self.engine.prefix_insert(s, sl.feed())?;
            }
            if sl.first_token_at.is_none() {
                sl.first_token_at = Some(Instant::now());
            }
            sl.generated.push(tok);
            sl.next_input = tok;
            ev.tokens.push((sl.req.id, tok));
            let eos_hit = self.cfg.eos_enabled && sl.req.eos.map(|e| e == tok).unwrap_or(false);
            let budget_hit = sl.generated.len() >= sl.req.max_new_tokens;
            let ctx_hit = sl.pos >= max_ctx;
            if eos_hit || budget_hit || ctx_hit {
                let sl = slot.take().unwrap();
                let now = Instant::now();
                ev.done.push(Response {
                    id: sl.req.id,
                    tokens: sl.generated,
                    ttft: sl.first_token_at.map(|t| t - sl.req.arrival).unwrap_or_default(),
                    latency: now - sl.req.arrival,
                    finish: if eos_hit {
                        FinishReason::Eos
                    } else if budget_hit {
                        FinishReason::MaxTokens
                    } else {
                        FinishReason::ContextFull
                    },
                });
            }
        }
        Ok(ev)
    }

    /// Drive iterations until every submitted request completes.
    ///
    /// Stall handling: an iteration that stepped no engine, completed no
    /// request, and admitted nothing while requests are still queued can
    /// never make progress (the one way to build such a batcher is an
    /// engine with zero slots) — that used to trip a 10M-iteration
    /// `assert!` and abort the process; both the fast no-progress check
    /// and the deep safety-net guard now surface as `Err` so a serving
    /// thread degrades instead of panicking.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut guard = 0u64;
        while !self.is_idle() {
            let before = out.len();
            out.extend(self.run_iteration()?);
            if self.active_slots() == 0 && !self.queue.is_empty() && out.len() == before {
                bail!(
                    "batcher stalled: {} request(s) queued but the engine has {} slot(s) \
                     and nothing was admitted or completed",
                    self.queue.len(),
                    self.slots.len()
                );
            }
            guard += 1;
            if guard >= 10_000_000 {
                bail!(
                    "batcher livelock: {guard} iterations without draining \
                     ({} active, {} queued)",
                    self.active_slots(),
                    self.queue.len()
                );
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::request::Request;
    use crate::util::{propcheck, Prng};

    fn mk_batcher(batch: usize) -> Batcher<MockEngine> {
        Batcher::new(MockEngine::new(batch, 97, 64), BatcherConfig::default())
    }

    fn mk_req(id: u64, prng: &mut Prng) -> Request {
        let plen = prng.usize_in(1, 6);
        let prompt = (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
        Request::new(id, prompt, prng.usize_in(1, 10))
    }

    #[test]
    fn single_request_generates_budgeted_tokens() {
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![5, 6], 4));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn all_requests_complete_no_starvation() {
        propcheck::check(
            "batcher-completion",
            propcheck::Config { cases: 40, seed: 77 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let n_req = p.usize_in(1, 20);
                let seed = p.next_u64();
                (batch, n_req, seed)
            },
            |&(batch, n_req, seed)| {
                let mut prng = Prng::new(seed);
                let mut b = mk_batcher(batch);
                for id in 0..n_req {
                    b.submit(mk_req(id as u64, &mut prng));
                }
                let done = b.run_to_completion().unwrap();
                if done.len() != n_req {
                    return Err(format!("{} of {n_req} completed", done.len()));
                }
                let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                if ids != (0..n_req as u64).collect::<Vec<_>>() {
                    return Err("duplicate or missing ids".into());
                }
                for r in &done {
                    if r.tokens.is_empty() {
                        return Err(format!("request {} got no tokens", r.id));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batched_output_matches_isolated_output() {
        // Isolation invariant: co-scheduling must not change any request's
        // tokens (the mock's state is per-slot, reset on admission — if
        // the batcher leaked state across admissions this would differ).
        let mut prng = Prng::new(123);
        let reqs: Vec<Request> = (0..10).map(|id| mk_req(id, &mut prng)).collect();

        // Isolated runs, batch=1.
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = mk_batcher(1);
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }

        // Co-scheduled run, batch=3.
        let mut b = mk_batcher(3);
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(
                &resp.tokens, &isolated[&resp.id],
                "request {} diverged under batching",
                resp.id
            );
        }
    }

    #[test]
    fn fifo_admission_order() {
        // With batch=1, completion order must equal submission order.
        let mut prng = Prng::new(5);
        let mut b = mk_batcher(1);
        for id in 0..6 {
            b.submit(mk_req(id, &mut prng));
        }
        let done = b.run_to_completion().unwrap();
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn eos_stops_generation() {
        let mut b = mk_batcher(1);
        // Find what the mock will emit, then use it as EOS.
        let mut probe = mk_batcher(1);
        probe.submit(Request::new(0, vec![5], 3));
        let toks = probe.run_to_completion().unwrap()[0].tokens.clone();
        let mut req = Request::new(1, vec![5], 3);
        req.eos = Some(toks[0]);
        b.submit(req);
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::Eos);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = Batcher::new(MockEngine::new(1, 97, 8), BatcherConfig::default());
        b.submit(Request::new(0, vec![1, 2, 3], 100));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        // Positions 0..7 hold 3 prompt + 5 generated inputs; the 6th
        // generated token is predicted from position 7 without needing a
        // KV slot of its own.
        assert_eq!(done[0].tokens.len(), 6);
    }

    #[test]
    fn sjf_policy_admits_short_jobs_first() {
        let cfg = BatcherConfig {
            policy: AdmissionPolicy::ShortestJobFirst { aging_step: 1000 },
            ..BatcherConfig::default()
        };
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        b.submit(Request::new(0, vec![1], 20));
        b.submit(Request::new(1, vec![1], 2));
        b.submit(Request::new(2, vec![1], 5));
        let done = b.run_to_completion().unwrap();
        // All three are queued before the first iteration, so SJF admits
        // (and with one slot, completes) them shortest-budget-first.
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        assert_eq!(done.iter().map(|r| r.tokens.len()).sum::<usize>(), 27);
    }

    /// MockEngine wrapper recording the largest position ever fed to the
    /// engine on an active slot — the "no KV write outside the window"
    /// observability the admission-hardening tests assert on — plus the
    /// row count of every `step_runs` call (the iteration-budget tests).
    struct TrackingEngine {
        inner: MockEngine,
        max_pos_fed: i32,
        rows_per_iteration: Vec<usize>,
    }

    impl TrackingEngine {
        fn new(inner: MockEngine) -> Self {
            TrackingEngine { inner, max_pos_fed: -1, rows_per_iteration: Vec::new() }
        }
    }

    impl DecodeEngine for TrackingEngine {
        fn batch(&self) -> usize {
            self.inner.batch()
        }

        fn vocab(&self) -> usize {
            self.inner.vocab()
        }

        fn max_context(&self) -> usize {
            self.inner.max_context()
        }

        fn max_run(&self) -> usize {
            self.inner.max_run()
        }

        fn step(
            &mut self,
            tokens: &[i32],
            positions: &[i32],
            active: &[bool],
        ) -> Result<Vec<i32>> {
            for (s, &p) in positions.iter().enumerate() {
                if active[s] {
                    self.max_pos_fed = self.max_pos_fed.max(p);
                }
            }
            self.inner.step(tokens, positions, active)
        }

        fn step_runs(&mut self, runs: &[crate::coordinator::engine::SlotRun]) -> Result<Vec<i32>> {
            for r in runs {
                self.max_pos_fed = self.max_pos_fed.max(r.start_pos + r.tokens.len() as i32 - 1);
            }
            self.rows_per_iteration.push(runs.iter().map(|r| r.tokens.len()).sum());
            self.inner.step_runs(runs)
        }

        fn reset_slot(&mut self, slot: usize) -> Result<()> {
            self.inner.reset_slot(slot)
        }
    }

    #[test]
    fn empty_prompt_rejected_with_response_not_panic() {
        // Regression: pre-PR `admit` indexed `req.prompt[0]` and panicked,
        // taking the serving thread down with it.
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![], 4));
        b.submit(Request::new(1, vec![5], 2));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let empty = done.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(empty.finish, FinishReason::EmptyPrompt);
        assert!(empty.tokens.is_empty());
        let ok = done.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(ok.finish, FinishReason::MaxTokens);
        assert_eq!(ok.tokens.len(), 2);
    }

    #[test]
    fn empty_prompt_alone_resolves_without_engine_work() {
        let mut b = mk_batcher(1);
        b.submit(Request::new(0, vec![], 4));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::EmptyPrompt);
        assert_eq!(b.iterations(), 0, "a rejected request must not step the engine");
        assert!(b.is_idle());
    }

    #[test]
    fn prompt_longer_than_context_finishes_context_full_during_prefill() {
        // Regression: pre-PR the ctx check ran only in the generating
        // branch, so a 12-token prompt prefilled positions 8..11 into an
        // 8-token KV window (out-of-bounds writes once the cache is real).
        let mut b = Batcher::new(
            TrackingEngine::new(MockEngine::new(1, 97, 8)),
            BatcherConfig::default(),
        );
        b.submit(Request::new(0, (1..=12).collect(), 5));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        assert!(done[0].tokens.is_empty(), "no logits were ever sampled");
        assert!(
            b.engine().max_pos_fed < 8,
            "position {} fed beyond the KV window",
            b.engine().max_pos_fed
        );
    }

    #[test]
    fn prompt_exactly_context_still_gets_one_token() {
        // The last prompt token sits at position max_context-1; its logits
        // are the one token this request can legally produce.
        let mut b = Batcher::new(
            TrackingEngine::new(MockEngine::new(1, 97, 8)),
            BatcherConfig::default(),
        );
        b.submit(Request::new(0, (1..=8).collect(), 5));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        assert_eq!(done[0].tokens.len(), 1);
        assert_eq!(b.engine().max_pos_fed, 7);
    }

    #[test]
    fn admission_hardening_property() {
        // Random mixes of empty, short, exact-fit, and over-long prompts:
        // every request completes with the right finish reason and token
        // count, and no active position ever reaches max_context.
        propcheck::check(
            "batcher-admission-hardening",
            propcheck::Config { cases: 60, seed: 99 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let max_ctx = p.usize_in(2, 11);
                let n_req = p.usize_in(1, 13);
                let seed = p.next_u64();
                (batch, max_ctx, n_req, seed)
            },
            |&(batch, max_ctx, n_req, seed)| {
                let mut prng = Prng::new(seed);
                let mut b = Batcher::new(
                    TrackingEngine::new(MockEngine::new(batch, 97, max_ctx)),
                    BatcherConfig::default(),
                );
                let mut expect = std::collections::HashMap::new();
                for id in 0..n_req as u64 {
                    let plen = prng.usize_in(0, max_ctx + 4);
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
                    let max_new = prng.usize_in(1, 8);
                    expect.insert(id, (plen, max_new));
                    b.submit(Request::new(id, prompt, max_new));
                }
                let done = b.run_to_completion().map_err(|e| e.to_string())?;
                if done.len() != n_req {
                    return Err(format!("{} of {n_req} completed", done.len()));
                }
                for r in &done {
                    let (plen, max_new) = expect[&r.id];
                    let (want_finish, want_tokens) = if plen == 0 {
                        (FinishReason::EmptyPrompt, 0)
                    } else if plen > max_ctx {
                        (FinishReason::ContextFull, 0)
                    } else {
                        let avail = max_ctx - plen + 1;
                        if max_new <= avail {
                            (FinishReason::MaxTokens, max_new)
                        } else {
                            (FinishReason::ContextFull, avail)
                        }
                    };
                    if r.finish != want_finish {
                        return Err(format!(
                            "req {} (plen {plen}): finish {:?}, want {want_finish:?}",
                            r.id, r.finish
                        ));
                    }
                    if r.tokens.len() != want_tokens {
                        return Err(format!(
                            "req {} (plen {plen}): {} tokens, want {want_tokens}",
                            r.id,
                            r.tokens.len()
                        ));
                    }
                }
                if b.engine().max_pos_fed >= max_ctx as i32 {
                    return Err(format!(
                        "position {} fed beyond max_context {max_ctx}",
                        b.engine().max_pos_fed
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn iterations_count_tokens_at_a_time() {
        let mut b = mk_batcher(4);
        // 4 requests, 1-token prompts, 5 tokens each: perfect batching
        // needs exactly 1 prefill + 5 generation iterations.
        for id in 0..4 {
            b.submit(Request::new(id, vec![7], 5));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        assert_eq!(b.iterations(), 5);
    }

    fn chunked_batcher(batch: usize, chunk: usize, rows: usize) -> Batcher<TrackingEngine> {
        Batcher::new(
            TrackingEngine::new(MockEngine::new(batch, 97, 64)),
            BatcherConfig {
                prefill_chunk: chunk,
                iteration_rows: rows,
                ..BatcherConfig::default()
            },
        )
    }

    #[test]
    fn chunked_prefill_matches_token_at_a_time_property() {
        // The tentpole invariant at the scheduling layer: for random mixes
        // of prompt lengths, budgets, chunk sizes, and row budgets, the
        // responses (tokens, finish reasons) are bit-identical to the
        // chunk-1 prefill-as-decode batcher, and no position ever reaches
        // the window.
        propcheck::check(
            "batcher-chunked-prefill-equivalence",
            propcheck::Config { cases: 60, seed: 2024 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let chunk = p.usize_in(2, 10);
                let rows = p.usize_in(1, 14);
                let n_req = p.usize_in(1, 14);
                let seed = p.next_u64();
                (batch, chunk, rows, n_req, seed)
            },
            |&(batch, chunk, rows, n_req, seed)| {
                type Outcome = Vec<(u64, Vec<i32>, FinishReason)>;
                fn run_case(
                    batch: usize,
                    chunk: usize,
                    rows: usize,
                    n_req: usize,
                    seed: u64,
                ) -> Result<Outcome, String> {
                    let mut prng = Prng::new(seed);
                    let mut b = chunked_batcher(batch, chunk, rows);
                    for id in 0..n_req as u64 {
                        let plen = prng.usize_in(1, 30);
                        let prompt = (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
                        b.submit(Request::new(id, prompt, prng.usize_in(1, 8)));
                    }
                    let mut done = b.run_to_completion().map_err(|e| e.to_string())?;
                    if b.engine().max_pos_fed >= 64 {
                        return Err(format!(
                            "position {} fed beyond the window",
                            b.engine().max_pos_fed
                        ));
                    }
                    done.sort_by_key(|r| r.id);
                    Ok(done.into_iter().map(|r| (r.id, r.tokens, r.finish)).collect())
                }
                let base = run_case(batch, 1, usize::MAX, n_req, seed)?;
                let got = run_case(batch, chunk, rows, n_req, seed)?;
                if got != base {
                    return Err(format!("chunk {chunk} rows {rows} diverged from chunk 1"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn iteration_row_budget_caps_rows_without_starving_decode() {
        // Slot 0 prefills a 24-token prompt while slot 1 decodes; with
        // chunk 8 and a 5-row budget every iteration must stay ≤ 5 rows,
        // both requests complete, and the stream matches chunk 1.
        let run = |chunk: usize, rows: usize| {
            let mut b = chunked_batcher(2, chunk, rows);
            b.submit(Request::new(0, (1..=24).collect(), 3));
            b.submit(Request::new(1, vec![5], 6));
            let mut done = b.run_to_completion().unwrap();
            done.sort_by_key(|r| r.id);
            let max_rows = b.engine().rows_per_iteration.iter().copied().max().unwrap_or(0);
            (done.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>(), max_rows)
        };
        let (base, _) = run(1, usize::MAX);
        let (got, max_rows) = run(8, 5);
        assert_eq!(got, base, "row budget changed the token streams");
        assert!(max_rows <= 5, "an iteration submitted {max_rows} rows past the 5-row budget");
        // Uncapped, the same workload does stack full chunks.
        let (got_wide, max_rows_wide) = run(8, usize::MAX);
        assert_eq!(got_wide, base);
        assert!(max_rows_wide > 5, "chunk 8 never stacked more than 5 rows: {max_rows_wide}");
    }

    #[test]
    fn ttft_improves_with_chunked_prefill_in_iterations() {
        // With a 40-token prompt and a 1-token budget, the request's
        // whole life is prefill: iterations-to-completion is exactly
        // ceil(40 / chunk) and therefore monotone non-increasing in the
        // chunk size (the iteration-count proxy for TTFT, which a wall
        // clock would measure too noisily).
        let mut prev = u64::MAX;
        for chunk in [1usize, 4, 16, 64] {
            let mut b = chunked_batcher(1, chunk, usize::MAX);
            b.submit(Request::new(0, (1..=40).collect(), 1));
            let done = b.run_to_completion().unwrap();
            assert_eq!(done[0].tokens.len(), 1);
            assert_eq!(b.iterations(), 40u64.div_ceil(chunk.min(40) as u64), "chunk {chunk}");
            assert!(b.iterations() <= prev, "chunk {chunk} regressed TTFT iterations");
            prev = b.iterations();
        }
    }

    #[test]
    fn prefill_chunk_parse_rejects_malformed_forms_typed() {
        for bad in ["", "x", "0", "-2", "1.5", "8 tokens", "0x10"] {
            let err = parse_prefill_chunk(bad).unwrap_err();
            assert!(err.contains("SAIL_PREFILL_CHUNK"), "'{bad}': {err}");
        }
        assert_eq!(parse_prefill_chunk(" 16 "), Ok(16), "whitespace is tolerated");
        assert_eq!(parse_prefill_chunk("1"), Ok(1));
    }

    #[test]
    fn full_queue_sheds_typed_zero_token_responses() {
        let cfg = BatcherConfig { queue_capacity: 2, ..BatcherConfig::default() };
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        assert!(b.submit(Request::new(0, vec![1], 2)).is_queued());
        assert!(b.submit(Request::new(1, vec![1], 2)).is_queued());
        let shed =
            b.submit(Request::new(2, vec![1], 2)).shed().expect("third submit must shed");
        assert_eq!(shed.id, 2);
        assert_eq!(shed.finish, FinishReason::Shed);
        assert!(shed.tokens.is_empty());
        // The queued requests are unaffected by the shed one.
        let done = b.run_to_completion().unwrap();
        let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
        assert!(done.iter().all(|r| r.finish == FinishReason::MaxTokens));
        // Draining re-opens admission.
        assert!(b.submit(Request::new(3, vec![1], 2)).is_queued());
        assert_eq!(b.run_to_completion().unwrap().len(), 1);
    }

    #[test]
    fn expired_deadlines_finish_typed_with_tokens_so_far() {
        // Zero total budget, checked while queued: finishes at admission
        // with zero tokens and no engine work.
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![5], 4).with_deadline(Duration::ZERO));
        b.submit(Request::new(1, vec![5], 2));
        let done = b.run_to_completion().unwrap();
        let dead = done.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(dead.finish, FinishReason::DeadlineExceeded);
        assert!(dead.tokens.is_empty());
        let ok = done.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(ok.finish, FinishReason::MaxTokens);
        assert_eq!(ok.tokens.len(), 2);

        // Zero TTFT budget behaves the same (no first token yet ⇒ expired).
        let mut b = mk_batcher(1);
        b.submit(Request::new(2, vec![5], 4).with_ttft_deadline(Duration::ZERO));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);

        // A generous budget changes nothing — the deadline path is
        // dormant on the happy path.
        let mut b = mk_batcher(1);
        b.submit(
            Request::new(3, vec![5], 4)
                .with_deadline(Duration::from_secs(3600))
                .with_ttft_deadline(Duration::from_secs(3600)),
        );
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
        assert_eq!(done[0].tokens.len(), 4);
    }

    /// Engine whose batched forward fails whenever `fail_slot` is in the
    /// batch — including when retried solo — until `fail_budget` errors
    /// have been served. The inner mock's per-slot state is only advanced
    /// on success, mirroring a real engine whose failed iteration commits
    /// nothing.
    struct FaultyEngine {
        inner: MockEngine,
        fail_slot: usize,
        fail_budget: usize,
    }

    impl DecodeEngine for FaultyEngine {
        fn batch(&self) -> usize {
            self.inner.batch()
        }

        fn vocab(&self) -> usize {
            self.inner.vocab()
        }

        fn max_context(&self) -> usize {
            self.inner.max_context()
        }

        fn max_run(&self) -> usize {
            self.inner.max_run()
        }

        fn step(
            &mut self,
            tokens: &[i32],
            positions: &[i32],
            active: &[bool],
        ) -> Result<Vec<i32>> {
            self.inner.step(tokens, positions, active)
        }

        fn step_runs(&mut self, runs: &[crate::coordinator::engine::SlotRun]) -> Result<Vec<i32>> {
            if self.fail_budget > 0 && runs.iter().any(|r| r.slot == self.fail_slot) {
                self.fail_budget -= 1;
                bail!("injected engine fault on slot {}", self.fail_slot);
            }
            self.inner.step_runs(runs)
        }

        fn reset_slot(&mut self, slot: usize) -> Result<()> {
            self.inner.reset_slot(slot)
        }
    }

    #[test]
    fn engine_fault_isolates_to_its_request_and_survivors_match_fault_free() {
        // Fault-free oracle for the whole workload.
        let reqs: Vec<Request> =
            (0..6).map(|id| Request::new(id, vec![5 + id as i32], 4)).collect();
        let mut oracle = mk_batcher(3);
        for r in &reqs {
            oracle.submit(r.clone());
        }
        let mut want: Vec<_> = oracle
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens, r.finish))
            .collect();
        want.sort_by_key(|(id, ..)| *id);

        // Same workload, but every forward containing slot 1 keeps
        // failing (a latched fault, like an injected KV-write failure).
        let mut b = Batcher::new(
            FaultyEngine {
                inner: MockEngine::new(3, 97, 64),
                fail_slot: 1,
                fail_budget: usize::MAX,
            },
            BatcherConfig::default(),
        );
        for r in &reqs {
            b.submit(r.clone());
        }
        let mut done: Vec<_> = b
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens, r.finish))
            .collect();
        done.sort_by_key(|(id, ..)| *id);
        assert_eq!(done.len(), want.len(), "every request must still finish");
        let mut faulted = 0usize;
        for ((id, tokens, finish), (wid, wtokens, wfinish)) in done.iter().zip(&want) {
            assert_eq!(id, wid);
            if *finish == FinishReason::EngineFault {
                faulted += 1;
                assert!(tokens.is_empty(), "slot 1 faults before its first token");
            } else {
                assert_eq!(finish, wfinish, "request {id}");
                assert_eq!(tokens, wtokens, "survivor {id} diverged from the fault-free run");
            }
        }
        // Slot 1 is re-admitted after each fault, so every request that
        // landed on it faults — but at least one did, and the batcher
        // never panicked or stalled.
        assert!(faulted >= 1, "no request ever exercised the faulty slot");

        // A transient fault (one failed batch, one failed solo retry)
        // costs *no* request: the next iteration retries cleanly.
        let mut b = Batcher::new(
            FaultyEngine { inner: MockEngine::new(3, 97, 64), fail_slot: 1, fail_budget: 1 },
            BatcherConfig::default(),
        );
        for r in &reqs {
            b.submit(r.clone());
        }
        let mut done: Vec<_> = b
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens, r.finish))
            .collect();
        done.sort_by_key(|(id, ..)| *id);
        assert_eq!(done, want, "a transient fault must cost nothing after the solo retry");
    }

    #[test]
    fn zero_slot_engine_is_an_error_not_a_livelock() {
        // Regression: a request submitted to a batcher whose engine has
        // zero slots can never be admitted; `run_to_completion` used to
        // spin 10M iterations and then `assert!`-abort the process. It
        // must return a proper Err (the server worker reports it and
        // degrades instead of panicking).
        let mut b = Batcher::new(MockEngine::new(0, 97, 64), BatcherConfig::default());
        b.submit(Request::new(0, vec![1, 2], 4));
        let err = b.run_to_completion();
        assert!(err.is_err(), "zero-slot batcher must error, not livelock");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("stalled"), "unexpected error: {msg}");
    }

    #[test]
    fn deadline_clock_starts_at_submit_not_construction() {
        // Regression (pre-fix failing): `arrival` was stamped at
        // `Request::new`, so a request built early — e.g. a workload
        // schedule generated up front — burned its deadline budget before
        // the serving system ever saw it. `submit` must restart the
        // clock.
        let mut b = mk_batcher(1);
        let req = Request::new(0, vec![5], 3)
            .with_deadline(Duration::from_millis(200))
            .with_ttft_deadline(Duration::from_millis(200));
        std::thread::sleep(Duration::from_millis(250));
        b.submit(req);
        let done = b.run_to_completion().unwrap();
        assert_eq!(
            done[0].finish,
            FinishReason::MaxTokens,
            "the deadline budget must start ticking at submit, not at construction"
        );
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn queued_expiree_finishes_typed_without_consuming_slot_or_capacity() {
        // Regression (pre-fix failing): deadlines were only checked when a
        // request was *popped* for admission, so behind a busy slot an
        // expired request sat in the queue indefinitely — eventually
        // running to completion anyway, and meanwhile holding a seat in
        // the bounded queue that shed live requests.
        let cfg = BatcherConfig {
            queue_capacity: 1,
            prefill_chunk: 1,
            ..BatcherConfig::default()
        };
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        // A, a long prefill, occupies the only slot for many iterations.
        assert!(b.submit(Request::new(0, (1..=40).collect(), 1)).is_queued());
        assert!(b.run_iteration().unwrap().is_empty());
        assert_eq!(b.active_slots(), 1);
        // B's budget is already gone the moment it is queued.
        assert!(b
            .submit(Request::new(1, vec![5], 4).with_deadline(Duration::ZERO))
            .is_queued());
        let done = b.run_iteration().unwrap();
        assert_eq!(done.len(), 1, "the queued expiree must finish on the next iteration");
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
        assert!(done[0].tokens.is_empty());
        assert_eq!(b.active_slots(), 1, "the expiree must not evict or occupy a slot");
        // Its bounded-queue seat is free again for a live request.
        assert!(
            b.submit(Request::new(2, vec![5], 2)).is_queued(),
            "the swept expiree must release its queue-capacity seat"
        );
        let done = b.run_to_completion().unwrap();
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2]);
        assert!(done.iter().all(|r| r.finish == FinishReason::MaxTokens));
    }

    #[test]
    fn iteration_events_stream_every_token_exactly_once() {
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![5, 6], 4));
        b.submit(Request::new(1, vec![7], 2));
        let mut streamed: std::collections::HashMap<u64, Vec<i32>> =
            std::collections::HashMap::new();
        let mut done = Vec::new();
        while !b.is_idle() {
            let ev = b.run_iteration_events().unwrap();
            for (id, tok) in ev.tokens {
                streamed.entry(id).or_default().push(tok);
            }
            assert!(ev.rows >= 1, "an iteration with active slots must submit rows");
            done.extend(ev.done);
        }
        assert_eq!(done.len(), 2);
        for r in &done {
            assert_eq!(
                streamed.get(&r.id),
                Some(&r.tokens),
                "request {}: streamed tokens must equal the response tokens",
                r.id
            );
        }
    }

    #[test]
    fn preempted_request_resumes_bit_identically_with_streams_intact() {
        // Oracle: the same request, never interrupted.
        let cfg = BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() };
        let mk = || Batcher::new(MockEngine::new(1, 97, 64), cfg);
        let mut o = mk();
        o.submit(Request::new(0, vec![5, 6, 7], 6));
        let want = o.run_to_completion().unwrap().remove(0);
        assert_eq!(want.tokens.len(), 6);

        // Preempting on an empty slot is a typed no-op.
        assert!(!mk().preempt(0));

        // Evict after 1..=6 iterations (mid-prefill and mid-generation):
        // the recompute-resume stream must be bit-identical, and the
        // events must carry each token exactly once — re-prefilled
        // positions are never re-streamed.
        for preempt_after in 1..=6usize {
            let mut b = mk();
            b.submit(Request::new(0, vec![5, 6, 7], 6));
            let mut streamed = Vec::new();
            for _ in 0..preempt_after {
                let ev = b.run_iteration_events().unwrap();
                streamed.extend(ev.tokens.iter().map(|&(_, t)| t));
                assert!(ev.done.is_empty(), "completed before the planned eviction");
            }
            assert!(b.preempt(0), "slot 0 must be active after {preempt_after} iterations");
            assert_eq!(b.active_slots(), 0);
            assert_eq!(b.resumable(), 1);
            let mut resp = None;
            while resp.is_none() {
                let mut ev = b.run_iteration_events().unwrap();
                streamed.extend(ev.tokens.iter().map(|&(_, t)| t));
                if !ev.done.is_empty() {
                    resp = Some(ev.done.remove(0));
                }
            }
            let resp = resp.unwrap();
            assert_eq!(
                resp.tokens, want.tokens,
                "eviction after {preempt_after} iterations changed the stream"
            );
            assert_eq!(resp.finish, want.finish);
            assert_eq!(
                streamed, want.tokens,
                "eviction after {preempt_after} iterations duplicated or dropped stream events"
            );
        }
    }

    #[test]
    fn preemption_yields_slot_to_waiter_then_resumes_victim() {
        let cfg = BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() };
        // Oracle for the victim, uninterrupted and alone.
        let mut o = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        o.submit(Request::new(0, vec![5], 8));
        let want = o.run_to_completion().unwrap().remove(0);

        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        b.submit(Request::new(0, vec![5], 8));
        for _ in 0..3 {
            assert!(b.run_iteration().unwrap().is_empty());
        }
        b.submit(Request::new(1, vec![9], 2));
        assert!(b.preempt(0));
        // The freed slot must go to the queued waiter, not back to the
        // victim — otherwise preemption never makes room.
        let ev = b.run_iteration_events().unwrap();
        assert!(
            ev.tokens.iter().all(|&(id, _)| id == 1),
            "the eviction iteration must serve the waiter, got {:?}",
            ev.tokens
        );
        let mut done = ev.done;
        done.extend(b.run_to_completion().unwrap());
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 0], "waiter finishes first, then the resumed victim");
        let victim = done.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(victim.tokens, want.tokens, "the resumed victim's stream drifted");
        assert_eq!(victim.finish, want.finish);
    }

    #[test]
    fn set_iteration_rows_retunes_budget_without_changing_streams() {
        let run = |retune: bool| {
            let mut b = chunked_batcher(2, 8, usize::MAX);
            b.submit(Request::new(0, (1..=24).collect(), 3));
            b.submit(Request::new(1, vec![5], 6));
            let mut done = Vec::new();
            let mut flip = false;
            while !b.is_idle() {
                if retune {
                    // Oscillate the budget mid-flight, as the serving
                    // scheduler does between iterations.
                    b.set_iteration_rows(if flip { 2 } else { 64 });
                    flip = !flip;
                }
                done.extend(b.run_iteration().unwrap());
            }
            done.sort_by_key(|r| r.id);
            done.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "retuning iteration_rows changed the streams");
    }
}
