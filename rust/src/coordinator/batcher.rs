//! Iteration-level batching over a fixed slot set.
//!
//! Every call to [`Batcher::run_iteration`] advances all active slots by
//! one token (prompt tokens are consumed first — prefill-as-decode, the
//! token-at-a-time regime of the paper's generation-stage evaluation) and
//! admits pending requests into free slots FIFO. Completed requests are
//! returned with latency metadata.
//!
//! Invariants (property-tested):
//! - a slot is reset before every admission (no KV leakage),
//! - per-slot positions increase by exactly 1 per active iteration,
//! - no active position ever reaches `max_context` — over-long prompts
//!   finish with `ContextFull` *during prefill*, before an out-of-window
//!   KV write could happen,
//! - empty prompts are answered at admission (`EmptyPrompt`, zero tokens)
//!   instead of crashing the serving thread,
//! - FIFO admission: requests start in arrival order,
//! - every request eventually completes (no starvation),
//! - outputs are identical to running each request alone (isolation).

use std::time::Instant;

use anyhow::Result;

use super::engine::DecodeEngine;
use super::policy::{AdmissionPolicy, AdmissionQueue};
use super::request::{FinishReason, Request, Response};

/// Batcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Honour requests' `eos` stop token: when enabled, a generated token
    /// equal to `Request::eos` finishes the request with
    /// [`FinishReason::Eos`]; when disabled, generation runs to the token
    /// budget (or the context limit) even through stop tokens.
    pub eos_enabled: bool,
    /// Queue discipline for admissions.
    pub policy: AdmissionPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { eos_enabled: true, policy: AdmissionPolicy::Fifo }
    }
}

#[derive(Debug)]
struct Slot {
    req: Request,
    /// Next prompt token to feed (prefill cursor).
    prompt_idx: usize,
    /// Position of the *next* token to be written to the KV cache.
    pos: i32,
    /// Token to feed this iteration.
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
}

/// The iteration-level batcher.
pub struct Batcher<E: DecodeEngine> {
    engine: E,
    slots: Vec<Option<Slot>>,
    queue: AdmissionQueue,
    cfg: BatcherConfig,
    iterations: u64,
    admitted: u64,
}

impl<E: DecodeEngine> Batcher<E> {
    /// Wrap `engine` with `engine.batch()` serving slots. The batcher owns
    /// the engine; drive it with [`run_iteration`](Batcher::run_iteration)
    /// or [`run_to_completion`](Batcher::run_to_completion).
    pub fn new(engine: E, cfg: BatcherConfig) -> Self {
        let b = engine.batch();
        Batcher {
            engine,
            slots: (0..b).map(|_| None).collect(),
            queue: AdmissionQueue::new(cfg.policy),
            cfg,
            iterations: 0,
            admitted: 0,
        }
    }

    /// The wrapped decode engine (read-only; tests and metrics use it to
    /// inspect per-projection kernel stats).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Enqueue a request (admitted into a free slot, FIFO by default, at
    /// the start of a later iteration).
    pub fn submit(&mut self, req: Request) {
        self.queue.push(req, self.iterations);
    }

    /// Requests waiting in the admission queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Slots currently serving a request.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// True when nothing is queued and no slot is active.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active_slots() == 0
    }

    /// Admit queued requests into free slots (FIFO), resetting slot KV.
    ///
    /// Admission hardening: a request with an empty prompt cannot be
    /// prefilled (there is no first token to feed) — it is answered
    /// immediately with a zero-token [`FinishReason::EmptyPrompt`]
    /// response pushed onto `done` instead of crashing the serving thread,
    /// and the slot stays free for the next queued request.
    fn admit(&mut self, done: &mut Vec<Response>) -> Result<()> {
        for s in 0..self.slots.len() {
            while self.slots[s].is_none() {
                let Some(req) = self.queue.pop(self.iterations) else {
                    return Ok(());
                };
                if req.prompt.is_empty() {
                    done.push(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        ttft: std::time::Duration::default(),
                        latency: Instant::now() - req.arrival,
                        finish: FinishReason::EmptyPrompt,
                    });
                    continue;
                }
                self.engine.reset_slot(s)?;
                self.admitted += 1;
                let first = req.prompt[0];
                self.slots[s] = Some(Slot {
                    req,
                    prompt_idx: 1,
                    pos: 0,
                    next_input: first,
                    generated: Vec::new(),
                    first_token_at: None,
                });
            }
        }
        Ok(())
    }

    /// One iteration: admit, step the engine once, harvest completions.
    pub fn run_iteration(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        self.admit(&mut done)?;
        if self.active_slots() == 0 {
            return Ok(done);
        }
        let b = self.slots.len();
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        for (s, slot) in self.slots.iter().enumerate() {
            if let Some(sl) = slot {
                tokens[s] = sl.next_input;
                positions[s] = sl.pos;
                active[s] = true;
            }
        }
        let next = self.engine.step(&tokens, &positions, &active)?;
        self.iterations += 1;

        let max_ctx = self.engine.max_context() as i32;
        for (s, slot) in self.slots.iter_mut().enumerate() {
            let Some(sl) = slot.as_mut() else { continue };
            sl.pos += 1;
            if sl.prompt_idx < sl.req.prompt.len() {
                if sl.pos >= max_ctx {
                    // The KV window is exhausted with prompt tokens still
                    // unfed: feeding another one would write KV position
                    // `max_context` out of bounds (the check used to live
                    // only in the generating branch, so over-long prompts
                    // silently prefilled past the window). No logits were
                    // ever sampled, so the response carries zero tokens.
                    let sl = slot.take().unwrap();
                    done.push(Response {
                        id: sl.req.id,
                        tokens: Vec::new(),
                        ttft: std::time::Duration::default(),
                        latency: Instant::now() - sl.req.arrival,
                        finish: FinishReason::ContextFull,
                    });
                    continue;
                }
                // Still prefilling: feed the next prompt token, discard
                // the model's prediction.
                sl.next_input = sl.req.prompt[sl.prompt_idx];
                sl.prompt_idx += 1;
            } else {
                // Generating.
                let tok = next[s];
                if sl.first_token_at.is_none() {
                    sl.first_token_at = Some(Instant::now());
                }
                sl.generated.push(tok);
                sl.next_input = tok;
                let eos_hit =
                    self.cfg.eos_enabled && sl.req.eos.map(|e| e == tok).unwrap_or(false);
                let budget_hit = sl.generated.len() >= sl.req.max_new_tokens;
                let ctx_hit = sl.pos >= max_ctx;
                if eos_hit || budget_hit || ctx_hit {
                    let sl = slot.take().unwrap();
                    let now = Instant::now();
                    done.push(Response {
                        id: sl.req.id,
                        tokens: sl.generated,
                        ttft: sl
                            .first_token_at
                            .map(|t| t - sl.req.arrival)
                            .unwrap_or_default(),
                        latency: now - sl.req.arrival,
                        finish: if eos_hit {
                            FinishReason::Eos
                        } else if budget_hit {
                            FinishReason::MaxTokens
                        } else {
                            FinishReason::ContextFull
                        },
                    });
                }
            }
        }
        Ok(done)
    }

    /// Drive iterations until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut guard = 0u64;
        while !self.is_idle() {
            out.extend(self.run_iteration()?);
            guard += 1;
            assert!(guard < 10_000_000, "batcher livelock");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::request::Request;
    use crate::util::{propcheck, Prng};

    fn mk_batcher(batch: usize) -> Batcher<MockEngine> {
        Batcher::new(MockEngine::new(batch, 97, 64), BatcherConfig::default())
    }

    fn mk_req(id: u64, prng: &mut Prng) -> Request {
        let plen = prng.usize_in(1, 6);
        let prompt = (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
        Request::new(id, prompt, prng.usize_in(1, 10))
    }

    #[test]
    fn single_request_generates_budgeted_tokens() {
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![5, 6], 4));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn all_requests_complete_no_starvation() {
        propcheck::check(
            "batcher-completion",
            propcheck::Config { cases: 40, seed: 77 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let n_req = p.usize_in(1, 20);
                let seed = p.next_u64();
                (batch, n_req, seed)
            },
            |&(batch, n_req, seed)| {
                let mut prng = Prng::new(seed);
                let mut b = mk_batcher(batch);
                for id in 0..n_req {
                    b.submit(mk_req(id as u64, &mut prng));
                }
                let done = b.run_to_completion().unwrap();
                if done.len() != n_req {
                    return Err(format!("{} of {n_req} completed", done.len()));
                }
                let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                if ids != (0..n_req as u64).collect::<Vec<_>>() {
                    return Err("duplicate or missing ids".into());
                }
                for r in &done {
                    if r.tokens.is_empty() {
                        return Err(format!("request {} got no tokens", r.id));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batched_output_matches_isolated_output() {
        // Isolation invariant: co-scheduling must not change any request's
        // tokens (the mock's state is per-slot, reset on admission — if
        // the batcher leaked state across admissions this would differ).
        let mut prng = Prng::new(123);
        let reqs: Vec<Request> = (0..10).map(|id| mk_req(id, &mut prng)).collect();

        // Isolated runs, batch=1.
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = mk_batcher(1);
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }

        // Co-scheduled run, batch=3.
        let mut b = mk_batcher(3);
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(
                &resp.tokens, &isolated[&resp.id],
                "request {} diverged under batching",
                resp.id
            );
        }
    }

    #[test]
    fn fifo_admission_order() {
        // With batch=1, completion order must equal submission order.
        let mut prng = Prng::new(5);
        let mut b = mk_batcher(1);
        for id in 0..6 {
            b.submit(mk_req(id, &mut prng));
        }
        let done = b.run_to_completion().unwrap();
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn eos_stops_generation() {
        let mut b = mk_batcher(1);
        // Find what the mock will emit, then use it as EOS.
        let mut probe = mk_batcher(1);
        probe.submit(Request::new(0, vec![5], 3));
        let toks = probe.run_to_completion().unwrap()[0].tokens.clone();
        let mut req = Request::new(1, vec![5], 3);
        req.eos = Some(toks[0]);
        b.submit(req);
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::Eos);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = Batcher::new(MockEngine::new(1, 97, 8), BatcherConfig::default());
        b.submit(Request::new(0, vec![1, 2, 3], 100));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        // Positions 0..7 hold 3 prompt + 5 generated inputs; the 6th
        // generated token is predicted from position 7 without needing a
        // KV slot of its own.
        assert_eq!(done[0].tokens.len(), 6);
    }

    #[test]
    fn sjf_policy_admits_short_jobs_first() {
        let cfg = BatcherConfig {
            policy: AdmissionPolicy::ShortestJobFirst { aging_step: 1000 },
            ..BatcherConfig::default()
        };
        let mut b = Batcher::new(MockEngine::new(1, 97, 64), cfg);
        b.submit(Request::new(0, vec![1], 20));
        b.submit(Request::new(1, vec![1], 2));
        b.submit(Request::new(2, vec![1], 5));
        let done = b.run_to_completion().unwrap();
        // All three are queued before the first iteration, so SJF admits
        // (and with one slot, completes) them shortest-budget-first.
        let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        assert_eq!(done.iter().map(|r| r.tokens.len()).sum::<usize>(), 27);
    }

    /// MockEngine wrapper recording the largest position ever fed to the
    /// engine on an active slot — the "no KV write outside the window"
    /// observability the admission-hardening tests assert on.
    struct TrackingEngine {
        inner: MockEngine,
        max_pos_fed: i32,
    }

    impl TrackingEngine {
        fn new(inner: MockEngine) -> Self {
            TrackingEngine { inner, max_pos_fed: -1 }
        }
    }

    impl DecodeEngine for TrackingEngine {
        fn batch(&self) -> usize {
            self.inner.batch()
        }

        fn vocab(&self) -> usize {
            self.inner.vocab()
        }

        fn max_context(&self) -> usize {
            self.inner.max_context()
        }

        fn step(
            &mut self,
            tokens: &[i32],
            positions: &[i32],
            active: &[bool],
        ) -> Result<Vec<i32>> {
            for (s, &p) in positions.iter().enumerate() {
                if active[s] {
                    self.max_pos_fed = self.max_pos_fed.max(p);
                }
            }
            self.inner.step(tokens, positions, active)
        }

        fn reset_slot(&mut self, slot: usize) -> Result<()> {
            self.inner.reset_slot(slot)
        }
    }

    #[test]
    fn empty_prompt_rejected_with_response_not_panic() {
        // Regression: pre-PR `admit` indexed `req.prompt[0]` and panicked,
        // taking the serving thread down with it.
        let mut b = mk_batcher(2);
        b.submit(Request::new(0, vec![], 4));
        b.submit(Request::new(1, vec![5], 2));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let empty = done.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(empty.finish, FinishReason::EmptyPrompt);
        assert!(empty.tokens.is_empty());
        let ok = done.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(ok.finish, FinishReason::MaxTokens);
        assert_eq!(ok.tokens.len(), 2);
    }

    #[test]
    fn empty_prompt_alone_resolves_without_engine_work() {
        let mut b = mk_batcher(1);
        b.submit(Request::new(0, vec![], 4));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::EmptyPrompt);
        assert_eq!(b.iterations(), 0, "a rejected request must not step the engine");
        assert!(b.is_idle());
    }

    #[test]
    fn prompt_longer_than_context_finishes_context_full_during_prefill() {
        // Regression: pre-PR the ctx check ran only in the generating
        // branch, so a 12-token prompt prefilled positions 8..11 into an
        // 8-token KV window (out-of-bounds writes once the cache is real).
        let mut b = Batcher::new(
            TrackingEngine::new(MockEngine::new(1, 97, 8)),
            BatcherConfig::default(),
        );
        b.submit(Request::new(0, (1..=12).collect(), 5));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        assert!(done[0].tokens.is_empty(), "no logits were ever sampled");
        assert!(
            b.engine().max_pos_fed < 8,
            "position {} fed beyond the KV window",
            b.engine().max_pos_fed
        );
    }

    #[test]
    fn prompt_exactly_context_still_gets_one_token() {
        // The last prompt token sits at position max_context-1; its logits
        // are the one token this request can legally produce.
        let mut b = Batcher::new(
            TrackingEngine::new(MockEngine::new(1, 97, 8)),
            BatcherConfig::default(),
        );
        b.submit(Request::new(0, (1..=8).collect(), 5));
        let done = b.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::ContextFull);
        assert_eq!(done[0].tokens.len(), 1);
        assert_eq!(b.engine().max_pos_fed, 7);
    }

    #[test]
    fn admission_hardening_property() {
        // Random mixes of empty, short, exact-fit, and over-long prompts:
        // every request completes with the right finish reason and token
        // count, and no active position ever reaches max_context.
        propcheck::check(
            "batcher-admission-hardening",
            propcheck::Config { cases: 60, seed: 99 },
            |p, _| {
                let batch = p.usize_in(1, 5);
                let max_ctx = p.usize_in(2, 11);
                let n_req = p.usize_in(1, 13);
                let seed = p.next_u64();
                (batch, max_ctx, n_req, seed)
            },
            |&(batch, max_ctx, n_req, seed)| {
                let mut prng = Prng::new(seed);
                let mut b = Batcher::new(
                    TrackingEngine::new(MockEngine::new(batch, 97, max_ctx)),
                    BatcherConfig::default(),
                );
                let mut expect = std::collections::HashMap::new();
                for id in 0..n_req as u64 {
                    let plen = prng.usize_in(0, max_ctx + 4);
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| prng.usize_in(1, 97) as i32).collect();
                    let max_new = prng.usize_in(1, 8);
                    expect.insert(id, (plen, max_new));
                    b.submit(Request::new(id, prompt, max_new));
                }
                let done = b.run_to_completion().map_err(|e| e.to_string())?;
                if done.len() != n_req {
                    return Err(format!("{} of {n_req} completed", done.len()));
                }
                for r in &done {
                    let (plen, max_new) = expect[&r.id];
                    let (want_finish, want_tokens) = if plen == 0 {
                        (FinishReason::EmptyPrompt, 0)
                    } else if plen > max_ctx {
                        (FinishReason::ContextFull, 0)
                    } else {
                        let avail = max_ctx - plen + 1;
                        if max_new <= avail {
                            (FinishReason::MaxTokens, max_new)
                        } else {
                            (FinishReason::ContextFull, avail)
                        }
                    };
                    if r.finish != want_finish {
                        return Err(format!(
                            "req {} (plen {plen}): finish {:?}, want {want_finish:?}",
                            r.id, r.finish
                        ));
                    }
                    if r.tokens.len() != want_tokens {
                        return Err(format!(
                            "req {} (plen {plen}): {} tokens, want {want_tokens}",
                            r.id,
                            r.tokens.len()
                        ));
                    }
                }
                if b.engine().max_pos_fed >= max_ctx as i32 {
                    return Err(format!(
                        "position {} fed beyond max_context {max_ctx}",
                        b.engine().max_pos_fed
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn iterations_count_tokens_at_a_time() {
        let mut b = mk_batcher(4);
        // 4 requests, 1-token prompts, 5 tokens each: perfect batching
        // needs exactly 1 prefill + 5 generation iterations.
        for id in 0..4 {
            b.submit(Request::new(id, vec![7], 5));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        assert_eq!(b.iterations(), 5);
    }
}
