//! The serving coordinator — SAIL's system layer in Rust.
//!
//! Multi-user, iteration-level batched serving (paper §III-A: "inference
//! serving systems operate on an iteration-based principle when serving
//! multiple users"): a fixed set of batch slots advances one token per
//! iteration; free slots are refilled from the FIFO queue (continuous
//! batching at iteration granularity). Tensor-level scheduling happens
//! *inside* the engine: every iteration runs the whole model once for all
//! active slots, so each weight is read exactly once per iteration.
//!
//! - [`request`]: request/response types + the synthetic workload
//!   generator (Poisson arrivals, geometric lengths);
//! - [`engine`]: the `DecodeEngine` abstraction — the default LUT serving
//!   backend [`TransformerServeEngine`] (multi-layer KV-cached transformer
//!   decode, every projection on the paper's actual kernel), the
//!   PJRT-backed [`crate::runtime::DecodeModel`], the single-projection
//!   toy [`LutGemvServeEngine`] for micro-benches, a deterministic
//!   mock for coordinator tests, and the self-speculative wrapper
//!   [`SpeculativeEngine`] (draft k tokens at reduced precision, verify
//!   in one multi-row forward, streams bit-identical to plain decode);
//! - [`batcher`]: slot management and the iteration loop (chunked
//!   prefill, bounded admission, deadlines, preemption/resume, and the
//!   per-iteration event stream [`batcher::IterationEvents`]);
//! - [`metrics`]: latency/throughput accounting (TTFT/TPOT percentiles,
//!   shed rate, goodput);
//! - [`server`]: the whole-response threaded front-end (submission queue
//!   + worker, one shared completion channel);
//! - [`serving`]: the **streaming** front-end — per-request token stream
//!   channels, SLO-aware row-budget scheduling, deadline-driven
//!   preemption; scheduling is bit-invisible in the streams;
//! - [`workload`]: seeded arrival-driven workload schedules (Poisson /
//!   bursty, mixed lengths, session reuse, shared Zipf-popular system
//!   prompts) for the serving bench.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod server;
pub mod serving;
pub mod workload;

pub use batcher::{
    parse_prefill_chunk, prefill_chunk_from_env, Admission, Batcher, BatcherConfig,
    IterationEvents, SlotSummary,
};
pub use engine::{
    argmax_logits, parse_spec_config, spec_config_from_env, step_runs_via_step, validate_runs,
    DecodeEngine, LutGemvServeEngine, MockEngine, PjrtEngine, SlotRun, SpecConfig, SpecStats,
    SpeculativeEngine, TransformerServeEngine,
};
pub use metrics::ServingMetrics;
pub use policy::{AdmissionPolicy, AdmissionQueue};
pub use request::{FinishReason, Request, RequestId, Response, WorkloadGen};
pub use server::Server;
pub use serving::{
    choose_victim, plan_iteration_rows, ServingConfig, ServingFrontend, SloPolicy, StreamEvent,
    StreamHandle,
};
pub use workload::{generate, replay, ArrivalProcess, SharedPromptMix, TimedRequest, WorkloadSpec};
