//! The serving coordinator — SAIL's system layer in Rust.
//!
//! Multi-user, iteration-level batched serving (paper §III-A: "inference
//! serving systems operate on an iteration-based principle when serving
//! multiple users"): a fixed set of batch slots advances one token per
//! iteration; free slots are refilled from the FIFO queue (continuous
//! batching at iteration granularity). Tensor-level scheduling happens
//! *inside* the engine: every iteration runs the whole model once for all
//! active slots, so each weight is read exactly once per iteration.
//!
//! - [`request`]: request/response types + the synthetic workload
//!   generator (Poisson arrivals, geometric lengths);
//! - [`engine`]: the `DecodeEngine` abstraction — the default LUT serving
//!   backend [`TransformerServeEngine`] (multi-layer KV-cached transformer
//!   decode, every projection on the paper's actual kernel), the
//!   PJRT-backed [`crate::runtime::DecodeModel`], the single-projection
//!   toy [`LutGemvServeEngine`] for micro-benches, and a deterministic
//!   mock for coordinator tests;
//! - [`batcher`]: slot management and the iteration loop;
//! - [`metrics`]: latency/throughput accounting;
//! - [`server`]: the threaded front-end (submission queue + worker).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod server;

pub use batcher::{parse_prefill_chunk, prefill_chunk_from_env, Batcher, BatcherConfig};
pub use engine::{
    argmax_logits, step_runs_via_step, DecodeEngine, LutGemvServeEngine, MockEngine, PjrtEngine,
    SlotRun, TransformerServeEngine,
};
pub use metrics::ServingMetrics;
pub use policy::{AdmissionPolicy, AdmissionQueue};
pub use request::{FinishReason, Request, RequestId, Response, WorkloadGen};
pub use server::Server;
