//! Arrival-driven continuous-batching serving front-end.
//!
//! [`Server`](super::Server) answers whole responses on one shared
//! channel; this module is the streaming front-end above the same
//! [`Batcher`] machinery: each submission gets its **own** token stream
//! (a `std::sync::mpsc` channel of [`StreamEvent`]s) fed from
//! [`Batcher::run_iteration_events`] as tokens are sampled, plus an
//! SLO-aware scheduler that retunes the PR-5 iteration row budget every
//! iteration and may preempt a deadline-free decode to give its slot to
//! a TTFT-critical waiter.
//!
//! **The determinism contract** (property-tested in
//! `tests/serving_frontend.rs`): every scheduling decision this module
//! makes — row-budget retuning, preemption, admission order under load —
//! is *invisible in the token streams*. A request's stream depends only
//! on its own prompt (engine isolation + per-slot KV + recompute-resume),
//! so for any fixed arrival schedule the online streams are bit-identical
//! to offline [`Batcher::run_to_completion`], across pool widths, NUMA
//! placements, prefill chunks, and healing fault plans. What the
//! scheduler *does* change is latency: TTFT/TPOT under load, measured by
//! [`ServingMetrics`] and persisted by `benches/serving_load.rs`.
//!
//! The scheduler itself is two pure functions — [`plan_iteration_rows`]
//! (split the row budget between prefill throughput and decode cadence)
//! and [`choose_victim`] (which slot to evict for an urgent waiter) — so
//! the policy is unit-testable without threads or clocks.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::{Admission, Batcher, BatcherConfig, SlotSummary};
use super::engine::DecodeEngine;
use super::metrics::ServingMetrics;
use super::request::{Request, RequestId, Response};

/// One event on a per-request token stream.
#[derive(Debug)]
pub enum StreamEvent {
    /// A token was sampled for this request. Tokens arrive in order and
    /// exactly once — including the final token of the iteration that
    /// completes the request.
    Token(i32),
    /// The request finished; the response's `tokens` equals everything
    /// streamed. No further events follow.
    Done(Response),
}

/// The client half of one request's token stream.
pub struct StreamHandle {
    pub id: RequestId,
    rx: Receiver<StreamEvent>,
}

impl StreamHandle {
    /// Next event, blocking. `Err` only if the serving worker died before
    /// completing this request (engine failure) — a shed or expired
    /// request still gets a normal [`StreamEvent::Done`].
    pub fn recv(&self) -> Result<StreamEvent> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serving worker terminated mid-stream"))
    }

    /// Drain the stream to completion: all tokens in order plus the final
    /// response. The invariant `streamed == response.tokens` is part of
    /// the front-end contract (asserted by the conformance tests).
    pub fn wait(self) -> Result<(Vec<i32>, Response)> {
        let mut streamed = Vec::new();
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token(t)) => streamed.push(t),
                Ok(StreamEvent::Done(r)) => return Ok((streamed, r)),
                Err(_) => bail!(
                    "serving worker terminated before request {} completed",
                    self.id
                ),
            }
        }
    }
}

/// Latency targets the scheduler steers toward. Targets shape *when*
/// work runs, never *what* is computed — streams are SLO-invariant.
#[derive(Debug, Clone, Copy)]
pub struct SloPolicy {
    /// Time-to-first-token target: when the most urgent queued request's
    /// TTFT headroom shrinks below a quarter of this, the scheduler opens
    /// the row budget wide (and may preempt) to get its prefill through.
    pub ttft: Duration,
    /// Time-per-output-token target: the per-iteration wall-time budget.
    /// Iterations are sized to `tpot / measured-row-cost` rows so decode
    /// cadence holds while prefill chunks ride along.
    pub tpot: Duration,
    /// Hard per-iteration row ceiling (the PR-5 budget's upper bound).
    pub max_rows: usize,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            ttft: Duration::from_millis(200),
            tpot: Duration::from_millis(50),
            max_rows: 256,
        }
    }
}

/// Serving front-end configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServingConfig {
    pub batcher: BatcherConfig,
    /// SLO steering; `None` leaves the batcher's static row budget alone.
    pub slo: Option<SloPolicy>,
    /// Allow evicting deadline-free decodes for TTFT-critical waiters
    /// (recompute-resume keeps the victim's stream bit-identical).
    pub preemption: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig { batcher: BatcherConfig::default(), slo: None, preemption: false }
    }
}

/// Split the iteration row budget for the next iteration.
///
/// Pure policy: `active` slots each get their guaranteed row; the return
/// value decides how many *extra* prefill rows may stack on top.
/// - Normally the budget is what the TPOT target affords at the measured
///   per-row cost (`tpot / row_cost` rows), so decode cadence holds.
/// - When the most urgent queued waiter's TTFT headroom is inside a
///   quarter of the TTFT target, the budget opens to `max_rows`: finishing
///   that prefill now is worth a slow iteration for everyone.
/// - Always ≥ `active` (no slot starves — the batcher guarantees each
///   active slot one row regardless) and ≤ `max_rows` (but never below
///   `active`, so a batch wider than `max_rows` still steps).
pub fn plan_iteration_rows(
    slo: &SloPolicy,
    active: usize,
    row_cost: Duration,
    ttft_headroom: Option<Duration>,
) -> usize {
    let lo = active.max(1);
    let hi = slo.max_rows.max(lo);
    if ttft_headroom.is_some_and(|h| h <= slo.ttft / 4) {
        return hi;
    }
    let cost = row_cost.as_secs_f64();
    let afford = if cost > 0.0 {
        (slo.tpot.as_secs_f64() / cost) as usize
    } else {
        hi
    };
    afford.clamp(lo, hi)
}

/// Pick the slot to evict for an urgent waiter: among slots that carry no
/// deadline of their own and are past prefill (evicting mid-prefill
/// throws away work without freeing anything sooner), the one with the
/// most generation budget left — it would hold the slot longest, and its
/// recompute-resume cost is paid furthest in the future. Ties break to
/// the highest slot index. `None` when every slot is protected.
pub fn choose_victim(slots: &[SlotSummary]) -> Option<usize> {
    slots
        .iter()
        .filter(|s| !s.has_deadline && !s.prefilling)
        .max_by_key(|s| (s.remaining_budget, s.slot))
        .map(|s| s.slot)
}

/// One scheduling step before an iteration: retune the row budget from
/// the SLO targets and, when a TTFT-critical request is stuck behind a
/// full slot set, preempt one deadline-free decode for it.
fn schedule_slo<E: DecodeEngine>(
    b: &mut Batcher<E>,
    slo: &SloPolicy,
    row_cost: Duration,
    preemption: bool,
) {
    let headroom = b.min_queued_ttft_headroom();
    b.set_iteration_rows(plan_iteration_rows(slo, b.active_slots(), row_cost, headroom));
    if preemption
        && b.queued() > 0
        && b.free_slots() == 0
        && headroom.is_some_and(|h| h <= slo.ttft / 4)
    {
        if let Some(victim) = choose_victim(&b.slot_summaries()) {
            b.preempt(victim);
        }
    }
}

enum Msg {
    Submit(Request, Sender<StreamEvent>),
    /// Live weight hot-swap: rebuild the engine's weights from the seed
    /// **between** iterations (never mid-iteration, so every in-flight
    /// request's stream stays bit-identical); the ack reports the
    /// engine's verdict back to the caller.
    Swap(u64, Sender<Result<()>>),
    Drain,
}

/// The streaming continuous-batching front-end: a worker thread drives
/// the batcher iteration loop; [`submit`](ServingFrontend::submit)
/// returns a per-request [`StreamHandle`] immediately (admission —
/// including sheds — is reported *on the stream*, so submission never
/// blocks on the iteration loop).
pub struct ServingFrontend {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<ServingMetrics>>,
}

impl ServingFrontend {
    /// Spawn the serving worker around an engine.
    pub fn spawn<E: DecodeEngine + Send + 'static>(engine: E, cfg: ServingConfig) -> Self {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || serve_loop(engine, cfg, rx));
        ServingFrontend { tx, worker: Some(worker) }
    }

    /// Submit a request, returning its token stream. The request's
    /// deadline clock starts when the worker accepts it
    /// ([`Batcher::submit`] re-stamps `arrival`), not here and not at
    /// construction. A shed arrives as a zero-token
    /// [`StreamEvent::Done`] on the returned stream.
    pub fn submit(&self, req: Request) -> Result<StreamHandle> {
        let id = req.id;
        let (tx_ev, rx_ev) = channel();
        self.tx
            .send(Msg::Submit(req, tx_ev))
            .map_err(|_| anyhow::anyhow!("serving worker terminated"))?;
        Ok(StreamHandle { id, rx: rx_ev })
    }

    /// Live weight hot-swap: ask the worker to rebuild the engine's
    /// weights from `seed` between iterations and wait for the verdict.
    /// On success, requests admitted afterwards decode on the new
    /// weights while every request already prefilled finishes its stream
    /// on the generation that admitted it (the engine keeps superseded
    /// generations alive until their last slot drains, then reclaims
    /// them — see [`DecodeEngine::swap_weights`]). On engines without a
    /// rebuildable weight source this returns their typed error and
    /// serving continues unchanged.
    pub fn swap_weights(&self, seed: u64) -> Result<()> {
        let (tx_ack, rx_ack) = channel();
        self.tx
            .send(Msg::Swap(seed, tx_ack))
            .map_err(|_| anyhow::anyhow!("serving worker terminated"))?;
        rx_ack
            .recv()
            .map_err(|_| anyhow::anyhow!("serving worker terminated before the swap ack"))?
    }

    /// Signal no-more-requests, drain every in-flight request, and join,
    /// returning the final metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let _ = self.tx.send(Msg::Drain);
        let worker = self.worker.take().expect("double shutdown");
        worker.join().expect("serving worker panicked")
    }
}

impl Drop for ServingFrontend {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Drain);
            let _ = w.join();
        }
    }
}

fn serve_loop<E: DecodeEngine>(
    engine: E,
    cfg: ServingConfig,
    rx: Receiver<Msg>,
) -> ServingMetrics {
    let mut batcher = Batcher::new(engine, cfg.batcher);
    let mut metrics = ServingMetrics::new();
    let mut streams: HashMap<RequestId, Sender<StreamEvent>> = HashMap::new();
    // EWMA of the measured per-row iteration cost, feeding
    // `plan_iteration_rows`. Seeded optimistically low so the first
    // budgets are wide; real measurements take over within a few
    // iterations (7/8 decay).
    let mut row_cost = Duration::from_micros(50);
    let mut draining = false;
    loop {
        // Pull everything available without blocking; block only when
        // fully idle (nothing to compute).
        loop {
            let msg = if batcher.is_idle() && !draining {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        // All senders gone: final KV-pool/prefix-cache
                        // snapshot, then out.
                        metrics.record_kv(batcher.engine().kv_metrics());
                        metrics.record_spec(batcher.engine().spec_stats());
                        metrics.record_pool(batcher.engine().pool_stats());
                        metrics.record_reclaim(batcher.engine().reclaim_stats());
                        return metrics;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Submit(r, tx_ev) => {
                    let id = r.id;
                    match batcher.submit(r) {
                        Admission::Queued => {
                            streams.insert(id, tx_ev);
                        }
                        Admission::Shed(shed) => {
                            metrics.record(&shed);
                            let _ = tx_ev.send(StreamEvent::Done(shed));
                        }
                    }
                }
                Msg::Swap(seed, ack) => {
                    // Between iterations by construction: the pump never
                    // runs while `run_iteration_events` is on the stack.
                    let _ = ack.send(batcher.engine_mut().swap_weights(seed));
                }
                Msg::Drain => draining = true,
            }
        }
        if batcher.is_idle() {
            if draining {
                metrics.record_kv(batcher.engine().kv_metrics());
                metrics.record_spec(batcher.engine().spec_stats());
                metrics.record_pool(batcher.engine().pool_stats());
                metrics.record_reclaim(batcher.engine().reclaim_stats());
                return metrics;
            }
            continue;
        }
        if let Some(slo) = &cfg.slo {
            schedule_slo(&mut batcher, slo, row_cost, cfg.preemption);
        }
        let t0 = Instant::now();
        // An engine error must not panic the worker: report it and stop —
        // open streams observe the hangup as a typed recv error.
        let ev = match batcher.run_iteration_events() {
            Ok(ev) => ev,
            Err(e) => {
                eprintln!("sail serving: engine failure, stopping worker: {e}");
                metrics.record_kv(batcher.engine().kv_metrics());
                metrics.record_spec(batcher.engine().spec_stats());
                metrics.record_pool(batcher.engine().pool_stats());
                metrics.record_reclaim(batcher.engine().reclaim_stats());
                return metrics;
            }
        };
        if ev.rows > 0 {
            let per_row = t0.elapsed() / ev.rows as u32;
            row_cost = (row_cost * 7 + per_row) / 8;
        }
        for (id, tok) in &ev.tokens {
            if let Some(tx) = streams.get(id) {
                // A receiver that hung up just stops consuming its
                // stream; the request still runs to completion.
                let _ = tx.send(StreamEvent::Token(*tok));
            }
        }
        for resp in ev.done {
            metrics.record(&resp);
            if let Some(tx) = streams.remove(&resp.id) {
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::request::FinishReason;

    fn summaries() -> Vec<SlotSummary> {
        vec![
            SlotSummary {
                slot: 0,
                id: 0,
                prefilling: false,
                generated: 2,
                remaining_budget: 10,
                has_deadline: true,
            },
            SlotSummary {
                slot: 1,
                id: 1,
                prefilling: true,
                generated: 0,
                remaining_budget: 30,
                has_deadline: false,
            },
            SlotSummary {
                slot: 2,
                id: 2,
                prefilling: false,
                generated: 5,
                remaining_budget: 20,
                has_deadline: false,
            },
            SlotSummary {
                slot: 3,
                id: 3,
                prefilling: false,
                generated: 1,
                remaining_budget: 4,
                has_deadline: false,
            },
        ]
    }

    #[test]
    fn victim_is_deadline_free_decoding_and_longest_remaining() {
        // Slot 0 is protected (deadline), slot 1 is mid-prefill; of the
        // eligible 2 and 3, slot 2 has the most budget left.
        assert_eq!(choose_victim(&summaries()), Some(2));
        // All protected ⇒ no victim.
        let protected: Vec<SlotSummary> = summaries()
            .into_iter()
            .map(|mut s| {
                s.has_deadline = true;
                s
            })
            .collect();
        assert_eq!(choose_victim(&protected), None);
        assert_eq!(choose_victim(&[]), None);
    }

    #[test]
    fn row_plan_holds_tpot_and_respects_bounds() {
        let slo = SloPolicy {
            ttft: Duration::from_millis(200),
            tpot: Duration::from_millis(10),
            max_rows: 64,
        };
        // 1 ms/row, 10 ms target ⇒ 10 rows.
        assert_eq!(plan_iteration_rows(&slo, 2, Duration::from_millis(1), None), 10);
        // Costlier rows shrink the budget, but never below the active set.
        assert_eq!(plan_iteration_rows(&slo, 4, Duration::from_millis(5), None), 4);
        // Cheap rows grow it, capped at max_rows.
        assert_eq!(plan_iteration_rows(&slo, 1, Duration::from_micros(10), None), 64);
        // More active slots than max_rows: the floor wins (every slot
        // still steps; the batcher guarantees one row each regardless).
        assert_eq!(plan_iteration_rows(&slo, 100, Duration::from_millis(1), None), 100);
        // Zero measured cost (first iteration): wide open.
        assert_eq!(plan_iteration_rows(&slo, 1, Duration::ZERO, None), 64);
    }

    #[test]
    fn ttft_urgency_opens_the_budget() {
        let slo = SloPolicy {
            ttft: Duration::from_millis(100),
            tpot: Duration::from_millis(1),
            max_rows: 128,
        };
        let costly = Duration::from_millis(1); // affords only 1 row
        // Ample headroom: TPOT rules.
        assert_eq!(
            plan_iteration_rows(&slo, 1, costly, Some(Duration::from_millis(90))),
            1
        );
        // Inside a quarter of the TTFT target: open wide.
        assert_eq!(
            plan_iteration_rows(&slo, 1, costly, Some(Duration::from_millis(25))),
            128
        );
        assert_eq!(plan_iteration_rows(&slo, 1, costly, Some(Duration::ZERO)), 128);
        // No queued TTFT deadline at all: not urgent.
        assert_eq!(plan_iteration_rows(&slo, 1, costly, None), 1);
    }

    #[test]
    fn burst_streams_every_token_and_completes() {
        let fe = ServingFrontend::spawn(MockEngine::new(2, 97, 64), ServingConfig::default());
        let handles: Vec<StreamHandle> = (0..6u64)
            .map(|id| {
                fe.submit(Request::new(id, vec![3 + id as i32, 7], 4 + id as usize % 3))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let id = h.id;
            let (streamed, resp) = h.wait().unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.finish, FinishReason::MaxTokens);
            assert_eq!(streamed, resp.tokens, "stream {id} lost or duplicated tokens");
            assert!(!streamed.is_empty());
        }
        let metrics = fe.shutdown();
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.shed, 0);
    }

    #[test]
    fn shed_arrives_as_done_event_on_the_stream() {
        let cfg = ServingConfig {
            batcher: BatcherConfig { queue_capacity: 0, ..BatcherConfig::default() },
            ..ServingConfig::default()
        };
        let fe = ServingFrontend::spawn(MockEngine::new(2, 97, 64), cfg);
        let h = fe.submit(Request::new(0, vec![5], 4)).unwrap();
        let (streamed, resp) = h.wait().unwrap();
        assert!(streamed.is_empty());
        assert_eq!(resp.finish, FinishReason::Shed);
        let metrics = fe.shutdown();
        assert_eq!(metrics.shed, 1);
        assert!((metrics.shed_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slo_scheduling_and_preemption_do_not_change_streams() {
        // Offline oracle: same requests through run_to_completion.
        let reqs = |with_ttft: bool| -> Vec<Request> {
            (0..8u64)
                .map(|id| {
                    let plen = 1 + id as usize % 4;
                    let prompt = (0..plen).map(|p| 2 + id as i32 + p as i32).collect();
                    let r = Request::new(id, prompt, 3 + id as usize % 5);
                    if with_ttft && id % 2 == 1 {
                        // Generous budget: urgency steering may trigger,
                        // expiry must not.
                        r.with_ttft_deadline(Duration::from_secs(3600))
                    } else {
                        r
                    }
                })
                .collect()
        };
        let mut oracle = Batcher::new(MockEngine::new(2, 97, 64), BatcherConfig::default());
        for r in reqs(false) {
            oracle.submit(r);
        }
        let want: HashMap<RequestId, Vec<i32>> = oracle
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();

        // Online, with an aggressive SLO (tiny TPOT target ⇒ constant
        // retuning; TTFT target 20000 s makes the odd requests' 3600 s
        // headroom look "urgent" — ≤ ttft/4 — so the urgency path and
        // preemption genuinely fire without any deadline ever expiring).
        let cfg = ServingConfig {
            batcher: BatcherConfig::default(),
            slo: Some(SloPolicy {
                ttft: Duration::from_secs(20_000),
                tpot: Duration::from_micros(1),
                max_rows: 64,
            }),
            preemption: true,
        };
        let fe = ServingFrontend::spawn(MockEngine::new(2, 97, 64), cfg);
        let handles: Vec<StreamHandle> =
            reqs(true).into_iter().map(|r| fe.submit(r).unwrap()).collect();
        for h in handles {
            let id = h.id;
            let (streamed, resp) = h.wait().unwrap();
            assert_eq!(resp.finish, FinishReason::MaxTokens, "request {id}");
            assert_eq!(streamed, want[&id], "SLO scheduling changed stream {id}");
            assert_eq!(streamed, resp.tokens);
        }
        fe.shutdown();
    }
}
