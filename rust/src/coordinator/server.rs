//! Threaded serving front-end.
//!
//! A `Server` owns the batcher on a worker thread; clients submit requests
//! through a channel and receive responses on another. Rust std threads +
//! mpsc (no async runtime offline) — the event loop is the iteration loop
//! itself, which is exactly the iteration-based serving principle the
//! paper assumes.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::Result;

use super::batcher::{Admission, Batcher, BatcherConfig};
use super::engine::DecodeEngine;
use super::metrics::ServingMetrics;
use super::request::{Request, Response};

enum Msg {
    Submit(Request),
    Drain,
}

/// A cloneable, thread-safe submission handle.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<Msg>,
}

impl Submitter {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(Msg::Submit(req))
            .map_err(|_| anyhow::anyhow!("server worker terminated"))
    }
}

/// Handle to a running serving worker.
pub struct Server {
    tx: Sender<Msg>,
    rx_done: Receiver<Response>,
    worker: Option<JoinHandle<ServingMetrics>>,
}

impl Server {
    /// Spawn the worker thread around an engine.
    pub fn spawn<E: DecodeEngine + Send + 'static>(engine: E, cfg: BatcherConfig) -> Server {
        let (tx, rx) = channel::<Msg>();
        let (tx_done, rx_done) = channel::<Response>();
        let worker = std::thread::spawn(move || {
            let mut batcher = Batcher::new(engine, cfg);
            let mut metrics = ServingMetrics::new();
            let mut draining = false;
            loop {
                // Pull everything available without blocking; block only
                // when fully idle (nothing to compute).
                loop {
                    let msg = if batcher.is_idle() && !draining {
                        match rx.recv() {
                            Ok(m) => m,
                            Err(_) => return metrics, // all senders gone
                        }
                    } else {
                        match rx.try_recv() {
                            Ok(m) => m,
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                draining = true;
                                break;
                            }
                        }
                    };
                    match msg {
                        Msg::Submit(r) => {
                            // A full bounded queue sheds the request with
                            // a typed zero-token response — answered like
                            // any completion, never silently dropped.
                            if let Admission::Shed(shed) = batcher.submit(r) {
                                metrics.record(&shed);
                                let _ = tx_done.send(shed);
                            }
                        }
                        Msg::Drain => draining = true,
                    }
                }
                if batcher.is_idle() {
                    if draining {
                        return metrics;
                    }
                    continue;
                }
                // An engine error must not panic the worker (engines
                // return `Err` for bad calls precisely so serving can
                // degrade instead of abort): report it, stop the loop,
                // and let clients observe "server worker terminated".
                match batcher.run_iteration() {
                    Ok(done) => {
                        for resp in done {
                            metrics.record(&resp);
                            // Receiver may have hung up during shutdown;
                            // ignore.
                            let _ = tx_done.send(resp);
                        }
                    }
                    Err(e) => {
                        eprintln!("sail server: engine failure, stopping worker: {e}");
                        return metrics;
                    }
                }
            }
        });
        Server { tx, rx_done, worker: Some(worker) }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(Msg::Submit(req))
            .map_err(|_| anyhow::anyhow!("server worker terminated"))
    }

    /// A cloneable, thread-safe submission handle for open-loop workload
    /// threads.
    pub fn submitter(&self) -> Submitter {
        Submitter { tx: self.tx.clone() }
    }

    /// Receive the next completed response, blocking.
    pub fn recv(&self) -> Result<Response> {
        self.rx_done
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker terminated"))
    }

    /// Signal no-more-requests and join, returning final metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let _ = self.tx.send(Msg::Drain);
        let worker = self.worker.take().expect("double shutdown");
        worker.join().expect("worker panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Drain);
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::request::WorkloadGen;

    #[test]
    fn serves_a_burst_end_to_end() {
        let server = Server::spawn(MockEngine::new(4, 97, 64), BatcherConfig::default());
        let mut gen = WorkloadGen::new(3, 97);
        let reqs = gen.burst(12);
        let n = reqs.len();
        for r in reqs {
            server.submit(r).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..n {
            got.push(server.recv().unwrap());
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.completed as usize, n);
        assert!(metrics.tokens_generated > 0);
        let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_with_no_requests_is_clean() {
        let server = Server::spawn(MockEngine::new(2, 97, 64), BatcherConfig::default());
        let metrics = server.shutdown();
        assert_eq!(metrics.completed, 0);
    }

    #[test]
    fn zero_capacity_queue_sheds_every_request_typed() {
        use crate::coordinator::request::FinishReason;
        // capacity 0 makes shedding deterministic regardless of how fast
        // the worker drains: every submission comes back `Shed`.
        let cfg = BatcherConfig { queue_capacity: 0, ..BatcherConfig::default() };
        let server = Server::spawn(MockEngine::new(2, 97, 64), cfg);
        let mut gen = WorkloadGen::new(5, 97);
        for r in gen.burst(4) {
            server.submit(r).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(server.recv().unwrap());
        }
        assert!(
            got.iter().all(|r| r.finish == FinishReason::Shed && r.tokens.is_empty()),
            "a shed request must be answered with a typed zero-token response"
        );
        let metrics = server.shutdown();
        assert_eq!(metrics.completed, 4, "shed responses are recorded like completions");
    }

    #[test]
    fn staggered_submission_all_complete() {
        let server = Server::spawn(MockEngine::new(2, 97, 64), BatcherConfig::default());
        let mut gen = WorkloadGen::new(8, 97);
        for _ in 0..3 {
            let (r, _) = gen.next_request();
            server.submit(r).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.completed, 3);
    }
}
