//! The decode-engine abstraction the batcher drives.
//!
//! Production uses [`PjrtEngine`] (the AOT-compiled model through PJRT);
//! coordinator tests use [`MockEngine`], a deterministic token automaton
//! with the same slot/KV semantics, so batching invariants can be property-
//! tested without artifacts.

use anyhow::Result;

/// One decode iteration over all batch slots.
///
/// `tokens[s]`/`positions[s]` are only meaningful where `active[s]`;
/// inactive slots still occupy compute (the fixed-batch artifact) but
/// their outputs are ignored. Implementations must keep per-slot KV state
/// keyed by slot index and clear it on `reset_slot`.
pub trait DecodeEngine {
    fn batch(&self) -> usize;
    fn vocab(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Returns the next token per slot (greedy).
    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>>;
    /// Clear slot state before admitting a new request.
    fn reset_slot(&mut self, slot: usize) -> Result<()>;
}

/// PJRT-backed engine over the AOT decode artifact.
pub struct PjrtEngine {
    model: crate::runtime::DecodeModel,
}

// SAFETY: the xla crate's client/executable/literal types hold raw C
// pointers and an `Rc` to the client, making them !Send. A `PjrtEngine`
// is constructed with its *own* client (`PjrtEngine::load`), holds the
// only references to it, and is then moved wholesale into a single worker
// thread (`Server::spawn`) — it is never aliased across threads, so
// transferring ownership is sound. Do not clone the inner client out.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(model: crate::runtime::DecodeModel) -> Self {
        PjrtEngine { model }
    }

    pub fn load(dir: &std::path::Path, batch: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine { model: crate::runtime::DecodeModel::load(&client, dir, batch)? })
    }

    pub fn steps_executed(&self) -> u64 {
        self.model.steps_executed()
    }
}

impl DecodeEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.model.batch
    }

    fn vocab(&self) -> usize {
        self.model.manifest.config.vocab
    }

    fn max_context(&self) -> usize {
        self.model.manifest.config.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], _active: &[bool]) -> Result<Vec<i32>> {
        let logits = self.model.step(tokens, positions)?;
        Ok(self.model.argmax(&logits))
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.model.reset_kv(Some(&[slot]))
    }
}

/// Deterministic mock: next token = hash(slot history) — context-sensitive
/// (like a real LM, the output depends on everything fed so far), which
/// lets tests detect KV-state leakage across requests.
pub struct MockEngine {
    batch: usize,
    vocab: usize,
    max_context: usize,
    /// Per-slot rolling history hash (the "KV cache").
    state: Vec<u64>,
    pub steps: u64,
}

impl MockEngine {
    pub fn new(batch: usize, vocab: usize, max_context: usize) -> Self {
        MockEngine { batch, vocab, max_context, state: vec![0; batch], steps: 0 }
    }
}

impl DecodeEngine for MockEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        assert_eq!(tokens.len(), self.batch);
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| {
                if !active[s] {
                    return 0;
                }
                let mix = self.state[s]
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(tokens[s] as u64)
                    .wrapping_add((positions[s] as u64) << 32);
                self.state[s] = mix;
                // Never emit token 0 (reserved as EOS in tests) unless the
                // hash lands there; tests pick eos handling explicitly.
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.state[slot] = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_context_sensitive() {
        let mut e1 = MockEngine::new(2, 100, 64);
        let mut e2 = MockEngine::new(2, 100, 64);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2);
        // Different history ⇒ different next token (with these inputs).
        let b1 = e1.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        e2.reset_slot(0).unwrap();
        let b2 = e2.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        assert_ne!(b1[0], b2[0], "reset must change slot-0 trajectory");
        assert_eq!(b1[1], b2[1], "slot 1 unaffected by slot-0 reset");
    }

    #[test]
    fn inactive_slots_are_inert() {
        let mut e = MockEngine::new(2, 100, 64);
        let out = e.step(&[1, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0);
        // Slot 1 state untouched.
        assert_eq!(e.state[1], 0);
    }
}
