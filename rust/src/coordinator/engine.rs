//! The decode-engine abstraction the batcher drives.
//!
//! Four execution engines implement it:
//! - [`TransformerServeEngine`] — the default LUT serving backend: a real
//!   multi-layer KV-cached transformer ([`LutTransformer`]) whose every
//!   projection (Q/K/V/O, both FFN matrices, the output head) is a
//!   LUT-GEMV on the shared worker pool, with per-token attention over a
//!   real fp16/q8 KV cache;
//! - [`PjrtEngine`] — the AOT-compiled model through PJRT (production when
//!   artifacts are present);
//! - [`LutGemvServeEngine`] — the single-projection recurrent toy, kept
//!   for micro-benches where one GEMV per step isolates kernel cost from
//!   model structure;
//! - [`MockEngine`] — a deterministic token automaton with the same
//!   slot/KV semantics, for property-testing batching invariants without
//!   any compute.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::lutgemv::engine::GemvStats;
use crate::lutgemv::{GemvOutput, LutGemvEngine};
use crate::model::{
    DecodeItem, DecodeRun, DecodeSpec, DecodeStats, KvMetrics, KvRuntimeConfig, LutTransformer,
};
use crate::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use crate::runtime::WorkerPool;

/// Greedy argmax over a logits row, NaN-safe.
///
/// Tie/edge rule (documented, pinned by tests): NaN entries are skipped;
/// among equal maxima the **lowest index** wins; an all-NaN or empty row
/// maps to token 0 — an explicit sentinel, not the artifact of a
/// failed `>` comparison (the pre-fix code returned index 0 for
/// `[NaN, …]` rows because every `v > NaN` is false, silently masking
/// poisoned logits).
pub fn argmax_logits(row: &[f32]) -> i32 {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i as i32).unwrap_or(0)
}

/// A run of consecutive tokens for one slot in one engine iteration:
/// `tokens[i]` is fed at KV position `start_pos + i`. A single-token run
/// is one decode step; a longer run is a prefill chunk. The engine
/// returns one next-token prediction per run, sampled (greedy) from the
/// run's **last** position — exactly the token the sequential
/// token-at-a-time regime would have produced there, because every
/// position in the run attends only to positions `≤` its own.
#[derive(Debug, Clone, Copy)]
pub struct SlotRun<'a> {
    pub slot: usize,
    pub tokens: &'a [i32],
    pub start_pos: i32,
}

/// Shared `step_runs` validation: slots in range and unique per
/// iteration, runs non-empty, positions non-negative and inside the
/// context window (the batcher raises `ContextFull` *before* a run could
/// ever touch position `max_context`).
fn validate_runs(batch: usize, max_context: usize, runs: &[SlotRun]) -> Result<()> {
    let mut seen = vec![false; batch];
    for r in runs {
        if r.slot >= batch {
            bail!("run slot {} outside batch {batch}", r.slot);
        }
        if seen[r.slot] {
            bail!("slot {} appears in more than one run this iteration", r.slot);
        }
        seen[r.slot] = true;
        if r.tokens.is_empty() {
            bail!("empty token run for slot {}", r.slot);
        }
        if r.start_pos < 0 {
            bail!("negative start position {} for slot {}", r.start_pos, r.slot);
        }
        if r.start_pos as usize + r.tokens.len() > max_context {
            bail!(
                "run {}..{} for slot {} outside the {max_context}-token context window \
                 (the batcher must finish the request with ContextFull first)",
                r.start_pos,
                r.start_pos as usize + r.tokens.len(),
                r.slot
            );
        }
    }
    Ok(())
}

/// Generic adapter: decompose variable-length runs into single-token
/// [`DecodeEngine::step`] calls (the `active` flags select the slots
/// whose run still has tokens at each inner step). Any engine whose
/// `step` honours `active` can implement `step_runs` with this; the
/// result is bit-identical to a native multi-row forward by the engines'
/// own determinism contracts — it just forgoes the batched-GEMV
/// amortization a native implementation gets. Tests also use it as the
/// sequential oracle the native paths are compared against.
pub fn step_runs_via_step<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    runs: &[SlotRun],
) -> Result<Vec<i32>> {
    validate_runs(engine.batch(), engine.max_context(), runs)?;
    let b = engine.batch();
    let max_len = runs.iter().map(|r| r.tokens.len()).max().unwrap_or(0);
    let mut out = vec![0i32; runs.len()];
    let mut tokens = vec![0i32; b];
    let mut positions = vec![0i32; b];
    for j in 0..max_len {
        let mut active = vec![false; b];
        for r in runs {
            if let Some(&t) = r.tokens.get(j) {
                tokens[r.slot] = t;
                positions[r.slot] = r.start_pos + j as i32;
                active[r.slot] = true;
            }
        }
        let next = engine.step(&tokens, &positions, &active)?;
        for (ri, r) in runs.iter().enumerate() {
            if j + 1 == r.tokens.len() {
                out[ri] = next[r.slot];
            }
        }
    }
    Ok(out)
}

/// One decode iteration over all batch slots.
///
/// Two entry points:
/// - [`step`](DecodeEngine::step): the fixed-arity token-at-a-time form —
///   `tokens[s]`/`positions[s]` are only meaningful where `active[s]`;
///   inactive slots may still occupy compute (the fixed-batch artifact)
///   but their outputs are ignored.
/// - [`step_runs`](DecodeEngine::step_runs): the variable-rows-per-slot
///   form the batcher drives — each active slot submits a [`SlotRun`] of
///   up to [`max_run`](DecodeEngine::max_run) consecutive tokens
///   (chunked prefill), and the engine returns one greedy next-token per
///   run, predicted from the run's last position. Engines with a
///   multi-row forward execute the whole iteration at effective batch
///   `Σ rows(run)`, amortizing every per-weight cost (LUT builds) across
///   all rows.
///
/// Implementations must keep per-slot KV state keyed by slot index and
/// clear it on `reset_slot`, and both entry points must produce
/// bit-identical token streams for the same fed (token, position)
/// sequence.
pub trait DecodeEngine {
    fn batch(&self) -> usize;
    fn vocab(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Largest number of tokens one slot may submit in a single
    /// [`step_runs`](DecodeEngine::step_runs) call (engine capability;
    /// the batcher clamps its configured prefill chunk to this). Engines
    /// without a multi-row forward return 1.
    fn max_run(&self) -> usize {
        1
    }
    /// Returns the next token per slot (greedy).
    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>>;
    /// Variable-rows-per-slot iteration: returns one next token per run,
    /// sampled from the run's last position.
    ///
    /// The provided body decomposes runs into single-token `step` calls
    /// ([`step_runs_via_step`]) — correct for any engine whose `step`
    /// honours `active`, with no multi-row amortization. Engines with a
    /// real multi-row forward override it; engines whose `step` ignores
    /// `active` (PJRT) must override it too, because the decomposition's
    /// filler rows would write their KV.
    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        step_runs_via_step(self, runs)
    }
    /// Clear slot state before admitting a new request.
    fn reset_slot(&mut self, slot: usize) -> Result<()>;
    /// Map the longest cached KV prefix of `feed` into `slot` (paged KV
    /// with a prefix cache only) and return the number of tokens covered —
    /// the batcher starts prefill at that split. Engines without a prefix
    /// cache report a cold start (0).
    fn prefix_attach(&mut self, _slot: usize, _feed: &[i32]) -> Result<usize> {
        Ok(0)
    }
    /// Publish `slot`'s prefilled KV pages for the token sequence `feed`
    /// into the prefix cache so later requests sharing the prefix can
    /// attach. A no-op on engines without a prefix cache.
    fn prefix_insert(&mut self, _slot: usize, _feed: &[i32]) -> Result<()> {
        Ok(())
    }
    /// KV pool/prefix-cache counters, if the engine runs a paged store.
    fn kv_metrics(&self) -> Option<KvMetrics> {
        None
    }
}

/// PJRT-backed engine over the AOT decode artifact.
pub struct PjrtEngine {
    model: crate::runtime::DecodeModel,
}

// SAFETY: the xla crate's client/executable/literal types hold raw C
// pointers and an `Rc` to the client, making them !Send. A `PjrtEngine`
// is constructed with its *own* client (`PjrtEngine::load`), holds the
// only references to it, and is then moved wholesale into a single worker
// thread (`Server::spawn`) — it is never aliased across threads, so
// transferring ownership is sound. Do not clone the inner client out.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(model: crate::runtime::DecodeModel) -> Self {
        PjrtEngine { model }
    }

    pub fn load(dir: &std::path::Path, batch: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine { model: crate::runtime::DecodeModel::load(&client, dir, batch)? })
    }

    pub fn steps_executed(&self) -> u64 {
        self.model.steps_executed()
    }
}

impl DecodeEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.model.batch
    }

    fn vocab(&self) -> usize {
        self.model.manifest.config.vocab
    }

    fn max_context(&self) -> usize {
        self.model.manifest.config.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], _active: &[bool]) -> Result<Vec<i32>> {
        let logits = self.model.step(tokens, positions)?;
        Ok(self.model.argmax(&logits))
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        // The AOT artifact's step signature is one token per slot; the
        // batcher sees `max_run() == 1` and never builds longer runs, so
        // a longer run here is a caller bug. The guard must come first:
        // the generic decomposition below would feed absent slots the
        // (token 0, position 0) filler on *every* inner step, and this
        // engine's `step` ignores `active` — fine once per iteration
        // (the dense path always did it), KV-corrupting if repeated.
        if let Some(r) = runs.iter().find(|r| r.tokens.len() > 1) {
            bail!(
                "{}-token run for slot {}: the PJRT decode artifact steps one token \
                 per slot per iteration (max_run = 1)",
                r.tokens.len(),
                r.slot
            );
        }
        step_runs_via_step(self, runs)
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.model.reset_kv(Some(&[slot]))
    }
}

/// The single-projection LUT-GEMV micro-bench backend (the *toy*; the
/// default serving backend is [`TransformerServeEngine`]).
///
/// The "model" is a deterministic single-layer recurrent LM built to put
/// all of its compute where SAIL's is — the quantized output projection:
/// each step mixes the incoming token into a per-slot f32 hidden state
/// (the engine-side KV analogue; reset on slot reuse), quantizes it to
/// int8, and computes logits for all slots with **one batched LUT-GEMV**
/// over the `[vocab, hidden]` weight matrix, exactly the iteration-level
/// tensor scheduling of §III-A. Greedy argmax picks the next token.
///
/// Because the tiled backend is bit-exact at every thread count, token
/// streams are reproducible across pool sizes — property-tested below.
///
/// The pool is `Arc`-shared: several engines (several models, or several
/// shards of one model) can serve concurrently off one process-wide set of
/// persistent workers instead of each spawning its own
/// (`tests/shared_pool_serving.rs` pins down isolation and determinism).
pub struct LutGemvServeEngine {
    gemv: LutGemvEngine,
    pool: Arc<WorkerPool>,
    /// Reused flat logits buffer (no allocation per iteration).
    logits: GemvOutput,
    /// Per-slot hidden state, `[batch * hidden]` (the slot-keyed state the
    /// `DecodeEngine` contract requires).
    hidden: Vec<f32>,
    batch: usize,
    max_context: usize,
    /// Accumulated kernel counters across all steps (observability).
    pub gemv_stats: GemvStats,
    pub steps: u64,
}

impl LutGemvServeEngine {
    /// Wrap a LUT-GEMV engine whose weights are `[vocab, hidden]`
    /// (transposed layout, as `LutGemvEngine` stores them). `pool` may be
    /// shared with other engines.
    pub fn new(
        gemv: LutGemvEngine,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        assert!(batch > 0);
        let hidden = vec![0.0f32; batch * gemv.k()];
        LutGemvServeEngine {
            gemv,
            pool,
            logits: GemvOutput::new(),
            hidden,
            batch,
            max_context,
            gemv_stats: GemvStats::default(),
            steps: 0,
        }
    }

    /// Convenience constructor with seeded random quantized weights —
    /// the same seed gives the same model at any batch size / pool width.
    #[allow(clippy::too_many_arguments)]
    pub fn random(
        seed: u64,
        vocab: usize,
        hidden: usize,
        level: QuantLevel,
        group: usize,
        nbw: u32,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        let mut prng = crate::util::Prng::new(seed);
        let w: Vec<f32> = (0..vocab * hidden).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, vocab, hidden, level, group);
        // Placed for the serving pool: on a multi-node host the head
        // weights are sharded per node (a no-op single shard otherwise).
        let gemv = LutGemvEngine::with_pool(wt, nbw, &pool);
        LutGemvServeEngine::new(gemv, batch, max_context, pool)
    }

    /// Deterministic token/position embedding component `i` in `[-1, 1)`:
    /// the shared [`crate::util::splitmix_embed`] hash (no PRNG state, so
    /// it is the same on every thread and at every batch size). Positions
    /// here are batcher positions, always ≥ 0.
    fn embed(token: i32, position: i32, i: usize) -> f32 {
        crate::util::splitmix_embed(token, position as u64, i)
    }

    /// The worker pool this engine dispatches on (shareable with other
    /// engines via `Arc::clone`).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }
}

impl DecodeEngine for LutGemvServeEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.gemv.n()
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    /// The recurrent state update is per-token but the expensive part —
    /// the output projection — only matters at the run's last position,
    /// so a run of any length costs **one** GEMV row.
    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        // A mis-sized call is a caller bug, but it must surface as an
        // error the server can report, not a panic that aborts the worker.
        let b = self.batch;
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        let k = self.gemv.k();
        // Recurrent state update for active slots, staged into copies:
        // committing only after a successful dispatch means a failed
        // forward leaves the slot states untouched, so the batcher's solo
        // retry re-applies the same fold exactly once (bit-identical
        // recovery). Inactive slots keep their state untouched — the
        // fixed-batch artifact still computes them, but their outputs are
        // ignored.
        let mut staged: Vec<(usize, Vec<f32>)> = Vec::new();
        for s in 0..self.batch {
            if !active[s] {
                continue;
            }
            let mut h = self.hidden[s * k..(s + 1) * k].to_vec();
            for (i, hi) in h.iter_mut().enumerate() {
                *hi = 0.5 * *hi + Self::embed(tokens[s], positions[s], i);
            }
            staged.push((s, h));
        }
        let xs: Vec<QuantizedVector> = (0..self.batch)
            .map(|s| {
                let h = staged
                    .iter()
                    .find(|(ss, _)| *ss == s)
                    .map(|(_, h)| h.as_slice())
                    .unwrap_or(&self.hidden[s * k..(s + 1) * k]);
                QuantizedVector::quantize(h)
            })
            .collect();
        let stats = self.gemv.gemv_batch_into(&xs, &self.pool, &mut self.logits)?;
        for (s, h) in staged {
            self.hidden[s * k..(s + 1) * k].copy_from_slice(&h);
        }
        self.gemv_stats += stats;
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| if active[s] { argmax_logits(self.logits.row(s)) } else { 0 })
            .collect())
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.batch, self.max_context, runs)?;
        let k = self.gemv.k();
        // Fold every run's tokens into a staged copy of its slot's hidden
        // state in feed order — the exact recurrence sequential
        // single-token steps apply (the discarded mid-prefill logits
        // never feed back into the state, so skipping them changes
        // nothing downstream). Commit happens only after a successful
        // dispatch: a failed forward leaves every slot's state untouched
        // for a bit-identical solo retry.
        let mut staged: Vec<(usize, Vec<f32>)> = Vec::with_capacity(runs.len());
        for r in runs {
            let mut h = self.hidden[r.slot * k..(r.slot + 1) * k].to_vec();
            for (j, &t) in r.tokens.iter().enumerate() {
                let pos = r.start_pos + j as i32;
                for (i, hi) in h.iter_mut().enumerate() {
                    *hi = 0.5 * *hi + Self::embed(t, pos, i);
                }
            }
            staged.push((r.slot, h));
        }
        // One batched GEMV at effective batch = number of runs (only the
        // last position of each run needs logits).
        let xs: Vec<QuantizedVector> =
            staged.iter().map(|(_, h)| QuantizedVector::quantize(h)).collect();
        let stats = self.gemv.gemv_batch_into(&xs, &self.pool, &mut self.logits)?;
        for (s, h) in staged {
            self.hidden[s * k..(s + 1) * k].copy_from_slice(&h);
        }
        self.gemv_stats += stats;
        self.steps += 1;
        Ok((0..runs.len()).map(|i| argmax_logits(self.logits.row(i))).collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        let k = self.gemv.k();
        self.hidden[slot * k..(slot + 1) * k].fill(0.0);
        Ok(())
    }
}

/// The default LUT serving backend: multi-layer KV-cached transformer
/// decode, every projection a LUT-GEMV on the shared pool.
///
/// This is the generation-stage workload of the paper served end-to-end:
/// the batcher's per-iteration `(token, position)` pairs become
/// [`DecodeItem`]s for the **active** slots only (inactive slots cost
/// nothing and are never touched — their KV panes are per-slot state), the
/// model runs all layers, and the next token per slot is the NaN-safe
/// argmax of its logits row.
///
/// Determinism: the model is bit-identical at every pool width and across
/// batch compositions (`tests/decode_serving.rs`), so the serving
/// invariants the mock pins down hold on the real multi-layer path too.
pub struct TransformerServeEngine {
    model: LutTransformer,
}

impl TransformerServeEngine {
    pub fn new(model: LutTransformer) -> Self {
        TransformerServeEngine { model }
    }

    /// Seeded-random model: the same `(spec, seed)` gives the same model
    /// at any batch size and pool width.
    pub fn random(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<Self> {
        Ok(TransformerServeEngine { model: LutTransformer::random(spec, seed, batch, pool)? })
    }

    /// [`random`](Self::random) with an explicit KV runtime configuration
    /// (store layout, prefix cache, page budget) instead of `SAIL_KV`.
    pub fn random_with_kv(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
        kv_cfg: KvRuntimeConfig,
    ) -> Result<Self> {
        Ok(TransformerServeEngine {
            model: LutTransformer::random_with_kv(spec, seed, batch, pool, kv_cfg)?,
        })
    }

    pub fn model(&self) -> &LutTransformer {
        &self.model
    }

    /// Per-layer, per-projection kernel counters (rolled up across steps).
    pub fn stats(&self) -> &DecodeStats {
        &self.model.stats
    }
}

impl DecodeEngine for TransformerServeEngine {
    fn batch(&self) -> usize {
        self.model.batch()
    }

    fn vocab(&self) -> usize {
        self.model.spec().vocab
    }

    fn max_context(&self) -> usize {
        self.model.spec().max_context
    }

    /// The transformer has a true multi-row forward
    /// ([`LutTransformer::step_runs`]): every projection runs once per
    /// iteration at effective batch `Σ rows`, so prefill chunks of any
    /// length (the window permitting) are welcome.
    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        let b = self.model.batch();
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        let mut items = Vec::with_capacity(b);
        for s in 0..b {
            if !active[s] {
                continue;
            }
            if positions[s] < 0 {
                bail!("negative position {} for slot {s}", positions[s]);
            }
            items.push(DecodeItem { slot: s, token: tokens[s], pos: positions[s] as usize });
        }
        self.model.step(&items)?;
        let mut next = vec![0i32; b];
        for (i, it) in items.iter().enumerate() {
            next[it.slot] = argmax_logits(self.model.logits().row(i));
        }
        Ok(next)
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.model.batch(), self.model.spec().max_context, runs)?;
        let model_runs: Vec<DecodeRun> = runs
            .iter()
            .map(|r| DecodeRun { slot: r.slot, tokens: r.tokens, start_pos: r.start_pos as usize })
            .collect();
        self.model.step_runs(&model_runs)?;
        Ok((0..runs.len()).map(|i| argmax_logits(self.model.logits().row(i))).collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.model.reset_slot(slot)
    }

    fn prefix_attach(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        self.model.prefix_attach(slot, feed)
    }

    fn prefix_insert(&mut self, slot: usize, feed: &[i32]) -> Result<()> {
        self.model.prefix_insert(slot, feed)
    }

    fn kv_metrics(&self) -> Option<KvMetrics> {
        self.model.kv_metrics()
    }
}

/// Deterministic mock: next token = hash(slot history) — context-sensitive
/// (like a real LM, the output depends on everything fed so far), which
/// lets tests detect KV-state leakage across requests.
pub struct MockEngine {
    batch: usize,
    vocab: usize,
    max_context: usize,
    /// Per-slot rolling history hash (the "KV cache").
    state: Vec<u64>,
    pub steps: u64,
}

impl MockEngine {
    pub fn new(batch: usize, vocab: usize, max_context: usize) -> Self {
        MockEngine { batch, vocab, max_context, state: vec![0; batch], steps: 0 }
    }
}

impl DecodeEngine for MockEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        assert_eq!(tokens.len(), self.batch);
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| {
                if !active[s] {
                    return 0;
                }
                let mix = self.state[s]
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(tokens[s] as u64)
                    .wrapping_add((positions[s] as u64) << 32);
                self.state[s] = mix;
                // Never emit token 0 (reserved as EOS in tests) unless the
                // hash lands there; tests pick eos handling explicitly.
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.batch, self.max_context, runs)?;
        self.steps += 1;
        Ok(runs
            .iter()
            .map(|r| {
                // The same per-token fold `step` applies, so chunked
                // feeding is bit-identical to token-at-a-time feeding.
                let mut mix = self.state[r.slot];
                for (j, &t) in r.tokens.iter().enumerate() {
                    let pos = r.start_pos + j as i32;
                    mix = mix
                        .wrapping_mul(0x100000001B3)
                        .wrapping_add(t as u64)
                        .wrapping_add((pos as u64) << 32);
                }
                self.state[r.slot] = mix;
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.state[slot] = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_context_sensitive() {
        let mut e1 = MockEngine::new(2, 100, 64);
        let mut e2 = MockEngine::new(2, 100, 64);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2);
        // Different history ⇒ different next token (with these inputs).
        let b1 = e1.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        e2.reset_slot(0).unwrap();
        let b2 = e2.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        assert_ne!(b1[0], b2[0], "reset must change slot-0 trajectory");
        assert_eq!(b1[1], b2[1], "slot 1 unaffected by slot-0 reset");
    }

    #[test]
    fn inactive_slots_are_inert() {
        let mut e = MockEngine::new(2, 100, 64);
        let out = e.step(&[1, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0);
        // Slot 1 state untouched.
        assert_eq!(e.state[1], 0);
    }

    fn lut_engine(batch: usize, threads: usize) -> LutGemvServeEngine {
        LutGemvServeEngine::random(
            7,
            64,               // vocab
            32,               // hidden
            QuantLevel::Q4,
            16,               // group
            4,                // nbw
            batch,
            64,               // max context
            WorkerPool::shared(threads),
        )
    }

    #[test]
    fn lut_serve_engine_token_streams_identical_across_thread_counts() {
        // The tiled backend is bit-exact at every pool width, so the decode
        // trajectory must be too.
        let mut streams = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut e = lut_engine(2, threads);
            let mut toks = vec![3, 11];
            let mut got = Vec::new();
            for pos in 0..12 {
                toks = e.step(&toks, &[pos, pos], &[true, true]).unwrap();
                got.push(toks.clone());
            }
            streams.push(got);
        }
        assert_eq!(streams[0], streams[1], "1 vs 2 threads diverged");
        assert_eq!(streams[0], streams[2], "1 vs 4 threads diverged");
    }

    #[test]
    fn lut_serve_engine_is_context_sensitive_and_resettable() {
        let mut e1 = lut_engine(2, 1);
        let mut e2 = lut_engine(2, 1);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2, "same seed must give the same model");
        // Diverge the histories: reset slot 0 on e2 only, then walk both
        // engines in lockstep. Slot 1 must stay bit-identical; slot 0's
        // trajectory must differ somewhere.
        e2.reset_slot(0).unwrap();
        let mut slot0_diverged = false;
        for pos in 1..8 {
            let b1 = e1.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            let b2 = e2.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            assert_eq!(b1[1], b2[1], "slot 1 affected by slot-0 reset at pos {pos}");
            slot0_diverged |= b1[0] != b2[0];
        }
        assert!(slot0_diverged, "reset did not change slot-0 trajectory");
        assert!(e1.gemv_stats.luts_built > 0, "decode did not run the LUT path");
    }

    #[test]
    fn batcher_serves_requests_on_the_lut_gemv_path() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let mut b = Batcher::new(lut_engine(3, 2), BatcherConfig::default());
        for id in 0..7u64 {
            b.submit(Request::new(id, vec![1 + id as i32, 2], 4));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 7);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            for &t in &r.tokens {
                assert!((0..64).contains(&t), "token {t} outside vocab");
            }
        }
        let engine = b.engine();
        assert!(engine.steps > 0);
        assert!(engine.gemv_stats.lut_reads > 0, "no LUT reads on the serving path");
    }

    #[test]
    fn argmax_is_nan_safe_with_documented_tie_rule() {
        // Regression: the pre-fix `v > row[best]` scan returned index 0
        // whenever row[0] was NaN (every comparison against NaN is false).
        assert_eq!(argmax_logits(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax_logits(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax_logits(&[2.0, f32::NAN, 1.0]), 0);
        // All-NaN and empty rows map to the token-0 sentinel.
        assert_eq!(argmax_logits(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_logits(&[]), 0);
        // Ties: lowest index wins.
        assert_eq!(argmax_logits(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax_logits(&[1.0, 3.0, 3.0]), 1);
        // -inf is an ordinary (very small) value, not a sentinel.
        assert_eq!(argmax_logits(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax_logits(&[f32::NEG_INFINITY, f32::NAN]), 0);
    }

    #[test]
    fn mis_sized_step_is_an_error_not_a_panic() {
        // Regression: pre-fix these were `assert_eq!`s — a bad caller
        // aborted the server worker instead of getting an Err back.
        let mut e = lut_engine(2, 1);
        assert!(e.step(&[1], &[0], &[true]).is_err());
        assert!(e.step(&[1, 2], &[0], &[true, true]).is_err());
        assert!(e.step(&[1, 2], &[0, 0], &[true]).is_err());
        // The engine still serves after a rejected call.
        assert!(e.step(&[1, 2], &[0, 0], &[true, true]).is_ok());

        let mut t = transformer_engine(2, 1);
        assert!(t.step(&[1], &[0], &[true]).is_err());
        assert!(t.step(&[1, 2], &[0, -1], &[true, true]).is_err(), "negative position");
        assert!(t.step(&[1, 2], &[0, 0], &[true, true]).is_ok());
    }

    fn transformer_engine(batch: usize, threads: usize) -> TransformerServeEngine {
        TransformerServeEngine::random(
            crate::model::DecodeSpec::tiny(2, crate::model::KvCacheSpec::fp16()),
            11,
            batch,
            WorkerPool::shared(threads),
        )
        .unwrap()
    }

    #[test]
    fn transformer_engine_serves_through_the_batcher() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let mut b = Batcher::new(transformer_engine(2, 2), BatcherConfig::default());
        for id in 0..5u64 {
            b.submit(Request::new(id, vec![1 + id as i32, 2], 3));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        let vocab = b.engine().vocab() as i32;
        for r in &done {
            assert_eq!(r.tokens.len(), 3);
            for &t in &r.tokens {
                assert!((0..vocab).contains(&t), "token {t} outside vocab");
            }
        }
        // Every projection of every layer ran on the LUT path.
        let stats = b.engine().stats();
        for (l, layer) in stats.layers.iter().enumerate() {
            for (name, s) in layer.projections() {
                assert!(s.luts_built > 0, "layer {l} {name}: no LUTs built");
                assert!(s.lut_reads > 0, "layer {l} {name}: no LUT reads");
            }
        }
        assert!(stats.head.lut_reads > 0, "head projection never ran");
        assert!(stats.tokens > 0 && stats.steps > 0);
    }

    #[test]
    fn transformer_engine_inactive_slots_are_inert() {
        let mut e = transformer_engine(2, 1);
        let out = e.step(&[3, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0, "inactive slot must report the 0 sentinel");
        // Slot 1's KV pane was never written: stepping it later from
        // position 0 matches a fresh engine exactly.
        let mut fresh = transformer_engine(2, 1);
        let a = e.step(&[5, 7], &[1, 0], &[true, true]).unwrap();
        fresh.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        let b = fresh.step(&[5, 7], &[1, 0], &[true, true]).unwrap();
        assert_eq!(a[1], b[1], "slot 1 was touched while inactive");
    }

    #[test]
    fn step_runs_native_paths_match_the_sequential_oracle() {
        // Twin engines, same seed: the native multi-row `step_runs` must
        // produce the same outputs AND leave the same slot state as the
        // generic decomposition into single-token `step` calls.
        fn runs<'a>(p0: &'a [i32], p1: &'a [i32]) -> Vec<SlotRun<'a>> {
            vec![
                SlotRun { slot: 0, tokens: p0, start_pos: 0 },
                SlotRun { slot: 1, tokens: p1, start_pos: 0 },
            ]
        }
        let p0 = [3, 7, 11, 2, 9];
        let p1 = [5i32];

        let mut m_native = MockEngine::new(2, 97, 64);
        let mut m_oracle = MockEngine::new(2, 97, 64);
        let a = m_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut m_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "mock native step_runs diverged from the oracle");
        assert_eq!(m_native.state, m_oracle.state, "mock slot state diverged");

        let mut l_native = lut_engine(2, 2);
        let mut l_oracle = lut_engine(2, 1);
        let a = l_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut l_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "lut-toy native step_runs diverged from the oracle");
        // Continue decoding from the post-run state: trajectories must
        // stay locked (the hidden states are bit-identical).
        let cont = |e: &mut LutGemvServeEngine, t0: i32, t1: i32| {
            let toks = [t0, t1];
            let r: Vec<SlotRun> = (0..2)
                .map(|s| SlotRun {
                    slot: s,
                    tokens: std::slice::from_ref(&toks[s]),
                    start_pos: [p0.len(), p1.len()][s] as i32,
                })
                .collect();
            e.step_runs(&r).unwrap()
        };
        assert_eq!(cont(&mut l_native, a[0], a[1]), cont(&mut l_oracle, b[0], b[1]));

        let mut t_native = transformer_engine(2, 2);
        let mut t_oracle = transformer_engine(2, 1);
        let a = t_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut t_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "transformer native step_runs diverged from the oracle");
    }

    #[test]
    fn step_runs_rejects_malformed_runs() {
        let mut e = MockEngine::new(2, 97, 8);
        let toks = [1i32, 2, 3];
        let ok = SlotRun { slot: 0, tokens: &toks, start_pos: 0 };
        assert!(e.step_runs(&[ok]).is_ok());
        // Slot outside the batch.
        assert!(e.step_runs(&[SlotRun { slot: 2, tokens: &toks, start_pos: 0 }]).is_err());
        // Duplicate slot in one iteration.
        assert!(e.step_runs(&[ok, ok]).is_err());
        // Empty run.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &[], start_pos: 0 }]).is_err());
        // Negative start position.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: -1 }]).is_err());
        // Run crossing the context window (positions 6..9, window 8).
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 6 }]).is_err());
        // The engine still serves after a rejected call.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 3 }]).is_ok());

        // The transformer path reports the same class of errors.
        let mut t = transformer_engine(2, 1);
        let ctx = t.max_context() as i32;
        assert!(t.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: ctx - 1 }]).is_err());
        assert!(t.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 0 }]).is_ok());
    }

    #[test]
    fn pjrt_shaped_engines_cap_runs_at_one_token() {
        // `max_run` defaults to 1 and `step_runs` to the generic
        // decomposition, so a minimal engine implements neither; the
        // batcher clamps its chunk to 1 and the default body serves it.
        struct OneTokenEngine(MockEngine);
        impl DecodeEngine for OneTokenEngine {
            fn batch(&self) -> usize {
                self.0.batch()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn max_context(&self) -> usize {
                self.0.max_context()
            }
            fn step(
                &mut self,
                tokens: &[i32],
                positions: &[i32],
                active: &[bool],
            ) -> Result<Vec<i32>> {
                self.0.step(tokens, positions, active)
            }
            fn reset_slot(&mut self, slot: usize) -> Result<()> {
                self.0.reset_slot(slot)
            }
        }
        assert_eq!(
            OneTokenEngine(MockEngine::new(1, 97, 64)).max_run(),
            1,
            "the default capability is one token per slot"
        );
        // Chunked serving through the batcher still works: the chunk is
        // clamped to 1 and the stream matches the mock's exactly.
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let toks = [4i32, 9, 2, 6];
        let want = {
            let mut m = Batcher::new(
                MockEngine::new(1, 97, 64),
                BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() },
            );
            m.submit(Request::new(0, toks.to_vec(), 3));
            m.run_to_completion().unwrap()[0].tokens.clone()
        };
        let mut b = Batcher::new(
            OneTokenEngine(MockEngine::new(1, 97, 64)),
            BatcherConfig { prefill_chunk: 16, ..BatcherConfig::default() },
        );
        b.submit(Request::new(0, toks.to_vec(), 3));
        let got = b.run_to_completion().unwrap()[0].tokens.clone();
        assert_eq!(got, want, "clamped chunking changed the token stream");
        assert_eq!(b.iterations(), 6, "4 prompt + 3 generated tokens, one per iteration");
    }

    #[test]
    fn batched_lut_decode_matches_isolated_decode() {
        // Same isolation invariant the mock pins down, now on the real
        // kernel: co-scheduling must not change any request's tokens.
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let reqs: Vec<Request> =
            (0..4).map(|id| Request::new(id, vec![2 + id as i32], 3)).collect();
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = Batcher::new(lut_engine(1, 1), BatcherConfig::default());
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }
        let mut b = Batcher::new(lut_engine(2, 2), BatcherConfig::default());
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(&resp.tokens, &isolated[&resp.id], "request {} diverged", resp.id);
        }
    }
}
