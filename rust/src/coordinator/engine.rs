//! The decode-engine abstraction the batcher drives.
//!
//! Four execution engines implement it:
//! - [`TransformerServeEngine`] — the default LUT serving backend: a real
//!   multi-layer KV-cached transformer ([`LutTransformer`]) whose every
//!   projection (Q/K/V/O, both FFN matrices, the output head) is a
//!   LUT-GEMV on the shared worker pool, with per-token attention over a
//!   real fp16/q8 KV cache;
//! - [`PjrtEngine`] — the AOT-compiled model through PJRT (production when
//!   artifacts are present);
//! - [`LutGemvServeEngine`] — the single-projection recurrent toy, kept
//!   for micro-benches where one GEMV per step isolates kernel cost from
//!   model structure;
//! - [`MockEngine`] — a deterministic token automaton with the same
//!   slot/KV semantics, for property-testing batching invariants without
//!   any compute.
//!
//! [`SpeculativeEngine`] is not a fifth backend but a wrapper: it drives
//! a [`TransformerServeEngine`] target plus a cheap same-weights draft
//! ([`DraftSpec`]) through self-speculative decoding, emitting token
//! streams bit-identical to the wrapped target alone.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::lutgemv::engine::GemvStats;
use crate::lutgemv::{GemvOutput, LutGemvEngine};
use crate::model::{
    DecodeItem, DecodeRun, DecodeSpec, DecodeStats, DraftSpec, FloatWeights, KvMetrics,
    KvRuntimeConfig, LutTransformer,
};
use crate::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use crate::runtime::reclaim::{ReclaimDomain, ReclaimStats};
use crate::runtime::{PoolStats, WorkerPool};

/// Greedy argmax over a logits row, NaN-safe.
///
/// Tie/edge rule (documented, pinned by tests): NaN entries are skipped;
/// among equal maxima the **lowest index** wins; an all-NaN or empty row
/// maps to token 0 — an explicit sentinel, not the artifact of a
/// failed `>` comparison (the pre-fix code returned index 0 for
/// `[NaN, …]` rows because every `v > NaN` is false, silently masking
/// poisoned logits).
pub fn argmax_logits(row: &[f32]) -> i32 {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i as i32).unwrap_or(0)
}

/// A run of consecutive tokens for one slot in one engine iteration:
/// `tokens[i]` is fed at KV position `start_pos + i`. A single-token run
/// is one decode step; a longer run is a prefill chunk. The engine
/// returns one next-token prediction per run, sampled (greedy) from the
/// run's **last** position — exactly the token the sequential
/// token-at-a-time regime would have produced there, because every
/// position in the run attends only to positions `≤` its own.
#[derive(Debug, Clone, Copy)]
pub struct SlotRun<'a> {
    pub slot: usize,
    pub tokens: &'a [i32],
    pub start_pos: i32,
}

/// Shared `step_runs` validation: slots in range and unique per
/// iteration, runs non-empty and no longer than the engine's `max_run`
/// capability, positions non-negative and inside the context window (the
/// batcher raises `ContextFull` *before* a run could ever touch position
/// `max_context`). An empty run *list* is valid and validates trivially —
/// `step_runs(&[])` is a no-op iteration, not an error.
///
/// Public so test harnesses and engine wrappers can hold their inputs to
/// the same contract the built-in engines enforce; every violation is a
/// typed `Err`, never a panic.
pub fn validate_runs(
    batch: usize,
    max_context: usize,
    max_run: usize,
    runs: &[SlotRun],
) -> Result<()> {
    let mut seen = vec![false; batch];
    for r in runs {
        if r.slot >= batch {
            bail!("run slot {} outside batch {batch}", r.slot);
        }
        if seen[r.slot] {
            bail!("slot {} appears in more than one run this iteration", r.slot);
        }
        seen[r.slot] = true;
        if r.tokens.is_empty() {
            bail!("empty token run for slot {}", r.slot);
        }
        if r.tokens.len() > max_run {
            bail!(
                "{}-token run for slot {} exceeds the engine's max_run {max_run}",
                r.tokens.len(),
                r.slot
            );
        }
        if r.start_pos < 0 {
            bail!("negative start position {} for slot {}", r.start_pos, r.slot);
        }
        if r.start_pos as usize + r.tokens.len() > max_context {
            bail!(
                "run {}..{} for slot {} outside the {max_context}-token context window \
                 (the batcher must finish the request with ContextFull first)",
                r.start_pos,
                r.start_pos as usize + r.tokens.len(),
                r.slot
            );
        }
    }
    Ok(())
}

/// Generic adapter: decompose variable-length runs into single-token
/// [`DecodeEngine::step`] calls (the `active` flags select the slots
/// whose run still has tokens at each inner step). Any engine whose
/// `step` honours `active` can implement `step_runs` with this; the
/// result is bit-identical to a native multi-row forward by the engines'
/// own determinism contracts — it just forgoes the batched-GEMV
/// amortization a native implementation gets. Tests also use it as the
/// sequential oracle the native paths are compared against.
pub fn step_runs_via_step<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    runs: &[SlotRun],
) -> Result<Vec<i32>> {
    validate_runs(engine.batch(), engine.max_context(), engine.max_run(), runs)?;
    let b = engine.batch();
    let max_len = runs.iter().map(|r| r.tokens.len()).max().unwrap_or(0);
    let mut out = vec![0i32; runs.len()];
    let mut tokens = vec![0i32; b];
    let mut positions = vec![0i32; b];
    for j in 0..max_len {
        let mut active = vec![false; b];
        for r in runs {
            if let Some(&t) = r.tokens.get(j) {
                tokens[r.slot] = t;
                positions[r.slot] = r.start_pos + j as i32;
                active[r.slot] = true;
            }
        }
        let next = engine.step(&tokens, &positions, &active)?;
        for (ri, r) in runs.iter().enumerate() {
            if j + 1 == r.tokens.len() {
                out[ri] = next[r.slot];
            }
        }
    }
    Ok(out)
}

/// One decode iteration over all batch slots.
///
/// Two entry points:
/// - [`step`](DecodeEngine::step): the fixed-arity token-at-a-time form —
///   `tokens[s]`/`positions[s]` are only meaningful where `active[s]`;
///   inactive slots may still occupy compute (the fixed-batch artifact)
///   but their outputs are ignored.
/// - [`step_runs`](DecodeEngine::step_runs): the variable-rows-per-slot
///   form the batcher drives — each active slot submits a [`SlotRun`] of
///   up to [`max_run`](DecodeEngine::max_run) consecutive tokens
///   (chunked prefill), and the engine returns one greedy next-token per
///   run, predicted from the run's last position. Engines with a
///   multi-row forward execute the whole iteration at effective batch
///   `Σ rows(run)`, amortizing every per-weight cost (LUT builds) across
///   all rows.
///
/// Implementations must keep per-slot KV state keyed by slot index and
/// clear it on `reset_slot`, and both entry points must produce
/// bit-identical token streams for the same fed (token, position)
/// sequence.
pub trait DecodeEngine {
    fn batch(&self) -> usize;
    fn vocab(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Largest number of tokens one slot may submit in a single
    /// [`step_runs`](DecodeEngine::step_runs) call (engine capability;
    /// the batcher clamps its configured prefill chunk to this). Engines
    /// without a multi-row forward return 1.
    fn max_run(&self) -> usize {
        1
    }
    /// Returns the next token per slot (greedy).
    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>>;
    /// Variable-rows-per-slot iteration: returns one next token per run,
    /// sampled from the run's last position.
    ///
    /// The provided body decomposes runs into single-token `step` calls
    /// ([`step_runs_via_step`]) — correct for any engine whose `step`
    /// honours `active`, with no multi-row amortization. Engines with a
    /// real multi-row forward override it; engines whose `step` ignores
    /// `active` (PJRT) must override it too, because the decomposition's
    /// filler rows would write their KV.
    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        step_runs_via_step(self, runs)
    }
    /// Clear slot state before admitting a new request.
    fn reset_slot(&mut self, slot: usize) -> Result<()>;
    /// Map the longest cached KV prefix of `feed` into `slot` (paged KV
    /// with a prefix cache only) and return the number of tokens covered —
    /// the batcher starts prefill at that split. Engines without a prefix
    /// cache report a cold start (0).
    fn prefix_attach(&mut self, _slot: usize, _feed: &[i32]) -> Result<usize> {
        Ok(0)
    }
    /// Publish `slot`'s prefilled KV pages for the token sequence `feed`
    /// into the prefix cache so later requests sharing the prefix can
    /// attach. A no-op on engines without a prefix cache.
    fn prefix_insert(&mut self, _slot: usize, _feed: &[i32]) -> Result<()> {
        Ok(())
    }
    /// KV pool/prefix-cache counters, if the engine runs a paged store.
    fn kv_metrics(&self) -> Option<KvMetrics> {
        None
    }
    /// Hand the engine this iteration's *unused* row budget: rows the
    /// batcher's scheduler had available under
    /// [`iteration_rows`](crate::coordinator::BatcherConfig::iteration_rows)
    /// but did not fill with decode or prefill rows. A speculative engine
    /// spends it on draft + verify rows (each drafted token costs two
    /// extra rows); plain engines ignore it. Throttling the grant to zero
    /// never stalls serving — speculation simply degrades to plain
    /// decode, with identical tokens.
    fn spec_grant(&mut self, _rows: usize) {}
    /// Speculative-decoding counters, if the engine drafts.
    fn spec_stats(&self) -> Option<SpecStats> {
        None
    }
    /// Live weight hot-swap: rebuild the model's weights from `seed`
    /// without stopping serving. In-flight slots finish their streams on
    /// the weights that prefilled them; slots admitted after the swap use
    /// the new weights; superseded weight generations are retired through
    /// a [`ReclaimDomain`] once no slot references them. The default is a
    /// typed error — most engines have no rebuildable weight source.
    fn swap_weights(&mut self, _seed: u64) -> Result<()> {
        bail!("this engine does not support live weight swapping")
    }
    /// Dispatch-pool observability counters (per-worker execute/steal
    /// tallies, dispatch latency percentiles), if the engine fans out on
    /// a [`WorkerPool`].
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }
    /// Weight-generation reclamation counters, if the engine supports
    /// [`swap_weights`](DecodeEngine::swap_weights).
    fn reclaim_stats(&self) -> Option<ReclaimStats> {
        None
    }
}

/// PJRT-backed engine over the AOT decode artifact.
pub struct PjrtEngine {
    model: crate::runtime::DecodeModel,
}

// SAFETY: the xla crate's client/executable/literal types hold raw C
// pointers and an `Rc` to the client, making them !Send. A `PjrtEngine`
// is constructed with its *own* client (`PjrtEngine::load`), holds the
// only references to it, and is then moved wholesale into a single worker
// thread (`Server::spawn`) — it is never aliased across threads, so
// transferring ownership is sound. Do not clone the inner client out.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(model: crate::runtime::DecodeModel) -> Self {
        PjrtEngine { model }
    }

    pub fn load(dir: &std::path::Path, batch: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine { model: crate::runtime::DecodeModel::load(&client, dir, batch)? })
    }

    pub fn steps_executed(&self) -> u64 {
        self.model.steps_executed()
    }
}

impl DecodeEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.model.batch
    }

    fn vocab(&self) -> usize {
        self.model.manifest.config.vocab
    }

    fn max_context(&self) -> usize {
        self.model.manifest.config.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], _active: &[bool]) -> Result<Vec<i32>> {
        let logits = self.model.step(tokens, positions)?;
        Ok(self.model.argmax(&logits))
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        // The AOT artifact's step signature is one token per slot; the
        // batcher sees `max_run() == 1` and never builds longer runs, so
        // a longer run here is a caller bug. The guard must come first:
        // the generic decomposition below would feed absent slots the
        // (token 0, position 0) filler on *every* inner step, and this
        // engine's `step` ignores `active` — fine once per iteration
        // (the dense path always did it), KV-corrupting if repeated.
        if let Some(r) = runs.iter().find(|r| r.tokens.len() > 1) {
            bail!(
                "{}-token run for slot {}: the PJRT decode artifact steps one token \
                 per slot per iteration (max_run = 1)",
                r.tokens.len(),
                r.slot
            );
        }
        step_runs_via_step(self, runs)
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.model.reset_kv(Some(&[slot]))
    }
}

/// The single-projection LUT-GEMV micro-bench backend (the *toy*; the
/// default serving backend is [`TransformerServeEngine`]).
///
/// The "model" is a deterministic single-layer recurrent LM built to put
/// all of its compute where SAIL's is — the quantized output projection:
/// each step mixes the incoming token into a per-slot f32 hidden state
/// (the engine-side KV analogue; reset on slot reuse), quantizes it to
/// int8, and computes logits for all slots with **one batched LUT-GEMV**
/// over the `[vocab, hidden]` weight matrix, exactly the iteration-level
/// tensor scheduling of §III-A. Greedy argmax picks the next token.
///
/// Because the tiled backend is bit-exact at every thread count, token
/// streams are reproducible across pool sizes — property-tested below.
///
/// The pool is `Arc`-shared: several engines (several models, or several
/// shards of one model) can serve concurrently off one process-wide set of
/// persistent workers instead of each spawning its own
/// (`tests/shared_pool_serving.rs` pins down isolation and determinism).
pub struct LutGemvServeEngine {
    gemv: LutGemvEngine,
    pool: Arc<WorkerPool>,
    /// Reused flat logits buffer (no allocation per iteration).
    logits: GemvOutput,
    /// Per-slot hidden state, `[batch * hidden]` (the slot-keyed state the
    /// `DecodeEngine` contract requires).
    hidden: Vec<f32>,
    batch: usize,
    max_context: usize,
    /// Accumulated kernel counters across all steps (observability).
    pub gemv_stats: GemvStats,
    pub steps: u64,
}

impl LutGemvServeEngine {
    /// Wrap a LUT-GEMV engine whose weights are `[vocab, hidden]`
    /// (transposed layout, as `LutGemvEngine` stores them). `pool` may be
    /// shared with other engines.
    pub fn new(
        gemv: LutGemvEngine,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        assert!(batch > 0);
        let hidden = vec![0.0f32; batch * gemv.k()];
        LutGemvServeEngine {
            gemv,
            pool,
            logits: GemvOutput::new(),
            hidden,
            batch,
            max_context,
            gemv_stats: GemvStats::default(),
            steps: 0,
        }
    }

    /// Convenience constructor with seeded random quantized weights —
    /// the same seed gives the same model at any batch size / pool width.
    #[allow(clippy::too_many_arguments)]
    pub fn random(
        seed: u64,
        vocab: usize,
        hidden: usize,
        level: QuantLevel,
        group: usize,
        nbw: u32,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        let mut prng = crate::util::Prng::new(seed);
        let w: Vec<f32> = (0..vocab * hidden).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, vocab, hidden, level, group);
        // Placed for the serving pool: on a multi-node host the head
        // weights are sharded per node (a no-op single shard otherwise).
        let gemv = LutGemvEngine::with_pool(wt, nbw, &pool);
        LutGemvServeEngine::new(gemv, batch, max_context, pool)
    }

    /// Deterministic token/position embedding component `i` in `[-1, 1)`:
    /// the shared [`crate::util::splitmix_embed`] hash (no PRNG state, so
    /// it is the same on every thread and at every batch size). Positions
    /// here are batcher positions, always ≥ 0.
    fn embed(token: i32, position: i32, i: usize) -> f32 {
        crate::util::splitmix_embed(token, position as u64, i)
    }

    /// The worker pool this engine dispatches on (shareable with other
    /// engines via `Arc::clone`).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }
}

impl DecodeEngine for LutGemvServeEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.gemv.n()
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    /// The recurrent state update is per-token but the expensive part —
    /// the output projection — only matters at the run's last position,
    /// so a run of any length costs **one** GEMV row.
    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        // A mis-sized call is a caller bug, but it must surface as an
        // error the server can report, not a panic that aborts the worker.
        let b = self.batch;
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        let k = self.gemv.k();
        // Recurrent state update for active slots, staged into copies:
        // committing only after a successful dispatch means a failed
        // forward leaves the slot states untouched, so the batcher's solo
        // retry re-applies the same fold exactly once (bit-identical
        // recovery). Inactive slots keep their state untouched — the
        // fixed-batch artifact still computes them, but their outputs are
        // ignored.
        let mut staged: Vec<(usize, Vec<f32>)> = Vec::new();
        for s in 0..self.batch {
            if !active[s] {
                continue;
            }
            let mut h = self.hidden[s * k..(s + 1) * k].to_vec();
            for (i, hi) in h.iter_mut().enumerate() {
                *hi = 0.5 * *hi + Self::embed(tokens[s], positions[s], i);
            }
            staged.push((s, h));
        }
        let xs: Vec<QuantizedVector> = (0..self.batch)
            .map(|s| {
                let h = staged
                    .iter()
                    .find(|(ss, _)| *ss == s)
                    .map(|(_, h)| h.as_slice())
                    .unwrap_or(&self.hidden[s * k..(s + 1) * k]);
                QuantizedVector::quantize(h)
            })
            .collect();
        let stats = self.gemv.gemv_batch_into(&xs, &self.pool, &mut self.logits)?;
        for (s, h) in staged {
            self.hidden[s * k..(s + 1) * k].copy_from_slice(&h);
        }
        self.gemv_stats += stats;
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| if active[s] { argmax_logits(self.logits.row(s)) } else { 0 })
            .collect())
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.batch, self.max_context, self.max_run(), runs)?;
        let k = self.gemv.k();
        // Fold every run's tokens into a staged copy of its slot's hidden
        // state in feed order — the exact recurrence sequential
        // single-token steps apply (the discarded mid-prefill logits
        // never feed back into the state, so skipping them changes
        // nothing downstream). Commit happens only after a successful
        // dispatch: a failed forward leaves every slot's state untouched
        // for a bit-identical solo retry.
        let mut staged: Vec<(usize, Vec<f32>)> = Vec::with_capacity(runs.len());
        for r in runs {
            let mut h = self.hidden[r.slot * k..(r.slot + 1) * k].to_vec();
            for (j, &t) in r.tokens.iter().enumerate() {
                let pos = r.start_pos + j as i32;
                for (i, hi) in h.iter_mut().enumerate() {
                    *hi = 0.5 * *hi + Self::embed(t, pos, i);
                }
            }
            staged.push((r.slot, h));
        }
        // One batched GEMV at effective batch = number of runs (only the
        // last position of each run needs logits).
        let xs: Vec<QuantizedVector> =
            staged.iter().map(|(_, h)| QuantizedVector::quantize(h)).collect();
        let stats = self.gemv.gemv_batch_into(&xs, &self.pool, &mut self.logits)?;
        for (s, h) in staged {
            self.hidden[s * k..(s + 1) * k].copy_from_slice(&h);
        }
        self.gemv_stats += stats;
        self.steps += 1;
        Ok((0..runs.len()).map(|i| argmax_logits(self.logits.row(i))).collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        let k = self.gemv.k();
        self.hidden[slot * k..(slot + 1) * k].fill(0.0);
        Ok(())
    }
}

/// The default LUT serving backend: multi-layer KV-cached transformer
/// decode, every projection a LUT-GEMV on the shared pool.
///
/// This is the generation-stage workload of the paper served end-to-end:
/// the batcher's per-iteration `(token, position)` pairs become
/// [`DecodeItem`]s for the **active** slots only (inactive slots cost
/// nothing and are never touched — their KV panes are per-slot state), the
/// model runs all layers, and the next token per slot is the NaN-safe
/// argmax of its logits row.
///
/// Determinism: the model is bit-identical at every pool width and across
/// batch compositions (`tests/decode_serving.rs`), so the serving
/// invariants the mock pins down hold on the real multi-layer path too.
///
/// Live weight hot-swap ([`DecodeEngine::swap_weights`]): the engine
/// tracks a monotone weight *generation* per slot. A swap rebuilds the
/// model (same spec/batch/pool/KV config, new seed) and makes it current;
/// slots mid-stream keep decoding on the generation whose KV holds their
/// history — bit-identical to a no-swap run — while every slot admitted
/// afterwards (`reset_slot`) migrates to the new generation. A superseded
/// generation is retired through the engine's [`ReclaimDomain`] the
/// moment its last slot migrates away, so the [`ReclaimStats`] counters
/// prove old weights are dropped, not leaked.
pub struct TransformerServeEngine {
    /// The current weight generation's model.
    model: LutTransformer,
    /// Generation counter of `model`; bumped by each successful swap.
    version: u64,
    /// The generation each slot's KV history lives in. Equal to `version`
    /// except for slots admitted before the last swap(s).
    slot_version: Vec<u64>,
    /// Superseded generations still referenced by at least one slot.
    old: Vec<(u64, LutTransformer)>,
    /// How to rebuild the model for a new seed; `None` when the engine
    /// wrapped an externally built model ([`new`](Self::new)) — such
    /// engines report a typed error on `swap_weights`.
    rebuild: Option<Rebuild>,
    /// Deferred reclamation of retired generations (observability: the
    /// serving layer surfaces these counters).
    domain: Arc<ReclaimDomain>,
}

/// The constructor arguments a seeded engine keeps so `swap_weights` can
/// rebuild the model for a new seed.
struct Rebuild {
    spec: DecodeSpec,
    batch: usize,
    pool: Arc<WorkerPool>,
    kv_cfg: KvRuntimeConfig,
}

impl TransformerServeEngine {
    pub fn new(model: LutTransformer) -> Self {
        let batch = model.batch();
        TransformerServeEngine {
            model,
            version: 0,
            slot_version: vec![0; batch],
            old: Vec::new(),
            rebuild: None,
            domain: Arc::new(ReclaimDomain::new()),
        }
    }

    /// Seeded-random model: the same `(spec, seed)` gives the same model
    /// at any batch size and pool width.
    pub fn random(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<Self> {
        Self::random_with_kv(spec, seed, batch, pool, KvRuntimeConfig::from_env())
    }

    /// [`random`](Self::random) with an explicit KV runtime configuration
    /// (store layout, prefix cache, page budget) instead of `SAIL_KV`.
    pub fn random_with_kv(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
        kv_cfg: KvRuntimeConfig,
    ) -> Result<Self> {
        let model = LutTransformer::random_with_kv(
            spec.clone(),
            seed,
            batch,
            Arc::clone(&pool),
            kv_cfg,
        )?;
        let mut eng = Self::new(model);
        eng.rebuild = Some(Rebuild { spec, batch, pool, kv_cfg });
        Ok(eng)
    }

    pub fn model(&self) -> &LutTransformer {
        &self.model
    }

    /// The current weight generation (0 at construction; +1 per swap).
    pub fn weights_version(&self) -> u64 {
        self.version
    }

    /// Weight generations currently alive: the serving one plus every
    /// superseded generation still finishing a pre-swap stream.
    pub fn live_generations(&self) -> usize {
        1 + self.old.len()
    }

    /// The model that owns generation `v`'s KV.
    fn model_for_version_mut(&mut self, v: u64) -> Result<&mut LutTransformer> {
        if v == self.version {
            return Ok(&mut self.model);
        }
        match self.old.iter_mut().find(|(g, _)| *g == v) {
            Some((_, m)) => Ok(m),
            None => bail!("weight generation {v} was retired while a slot still used it"),
        }
    }

    /// Retire every superseded generation no slot references anymore.
    fn retire_unreferenced(&mut self) {
        if self.old.iter().all(|(v, _)| self.slot_version.contains(v)) {
            return;
        }
        let mut kept = Vec::new();
        for (v, m) in self.old.drain(..) {
            if self.slot_version.contains(&v) {
                kept.push((v, m));
            } else {
                self.domain.retire(Box::new(m));
            }
        }
        self.old = kept;
        self.domain.collect();
    }

    /// Mutable access to the model — the speculative wrapper drives its
    /// verify forwards ([`LutTransformer::step_runs_all_logits`]) and KV
    /// rollback ([`LutTransformer::truncate_slot`]) through this.
    pub fn model_mut(&mut self) -> &mut LutTransformer {
        &mut self.model
    }

    /// Per-layer, per-projection kernel counters (rolled up across steps).
    pub fn stats(&self) -> &DecodeStats {
        &self.model.stats
    }
}

impl DecodeEngine for TransformerServeEngine {
    fn batch(&self) -> usize {
        self.model.batch()
    }

    fn vocab(&self) -> usize {
        self.model.spec().vocab
    }

    fn max_context(&self) -> usize {
        self.model.spec().max_context
    }

    /// The transformer has a true multi-row forward
    /// ([`LutTransformer::step_runs`]): every projection runs once per
    /// iteration at effective batch `Σ rows`, so prefill chunks of any
    /// length (the window permitting) are welcome.
    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        let b = self.model.batch();
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        // One item batch per live weight generation, in slot order within
        // each (with no swap in flight there is exactly one generation,
        // and this is byte-for-byte the single-model path).
        let mut by_gen: Vec<(u64, Vec<DecodeItem>)> = Vec::new();
        for s in 0..b {
            if !active[s] {
                continue;
            }
            if positions[s] < 0 {
                bail!("negative position {} for slot {s}", positions[s]);
            }
            let item = DecodeItem { slot: s, token: tokens[s], pos: positions[s] as usize };
            let v = self.slot_version[s];
            match by_gen.iter_mut().find(|(g, _)| *g == v) {
                Some((_, items)) => items.push(item),
                None => by_gen.push((v, vec![item])),
            }
        }
        let mut next = vec![0i32; b];
        for (v, items) in by_gen {
            let model = self.model_for_version_mut(v)?;
            model.step(&items)?;
            for (i, it) in items.iter().enumerate() {
                next[it.slot] = argmax_logits(model.logits().row(i));
            }
        }
        Ok(next)
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.model.batch(), self.model.spec().max_context, self.max_run(), runs)?;
        // Partition runs by their slot's weight generation, preserving
        // submission order within each partition; each generation's model
        // executes one multi-row forward over its own runs.
        let mut by_gen: Vec<(u64, Vec<usize>)> = Vec::new();
        for (ri, r) in runs.iter().enumerate() {
            let v = self.slot_version[r.slot];
            match by_gen.iter_mut().find(|(g, _)| *g == v) {
                Some((_, idxs)) => idxs.push(ri),
                None => by_gen.push((v, vec![ri])),
            }
        }
        let mut out = vec![0i32; runs.len()];
        for (v, idxs) in by_gen {
            let model_runs: Vec<DecodeRun> = idxs
                .iter()
                .map(|&ri| {
                    let r = &runs[ri];
                    DecodeRun { slot: r.slot, tokens: r.tokens, start_pos: r.start_pos as usize }
                })
                .collect();
            let model = self.model_for_version_mut(v)?;
            model.step_runs(&model_runs)?;
            for (j, &ri) in idxs.iter().enumerate() {
                out[ri] = argmax_logits(model.logits().row(j));
            }
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        // Admission hook: the slot migrates to the current generation.
        // Clear its pane in the generation that held it (releasing KV
        // pages there), then retire any generation left unreferenced.
        let stale = self.slot_version[slot];
        if stale != self.version {
            if let Some((_, m)) = self.old.iter_mut().find(|(v, _)| *v == stale) {
                m.reset_slot(slot)?;
            }
            self.slot_version[slot] = self.version;
            self.retire_unreferenced();
        }
        self.model.reset_slot(slot)
    }

    fn prefix_attach(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        // The batcher resets the slot before attaching, so the slot is on
        // the current generation here; route by version anyway so a
        // direct driver cannot cross KV between generations.
        let v = self.slot_version[slot];
        self.model_for_version_mut(v)?.prefix_attach(slot, feed)
    }

    fn prefix_insert(&mut self, slot: usize, feed: &[i32]) -> Result<()> {
        let v = self.slot_version[slot];
        self.model_for_version_mut(v)?.prefix_insert(slot, feed)
    }

    fn kv_metrics(&self) -> Option<KvMetrics> {
        self.model.kv_metrics()
    }

    fn swap_weights(&mut self, seed: u64) -> Result<()> {
        let Some(rb) = &self.rebuild else {
            bail!(
                "live weight swap needs a rebuildable engine \
                 (TransformerServeEngine::random / random_with_kv); this one wrapped \
                 an externally built model"
            );
        };
        let next = LutTransformer::random_with_kv(
            rb.spec.clone(),
            seed,
            rb.batch,
            Arc::clone(&rb.pool),
            rb.kv_cfg,
        )?;
        let prev = std::mem::replace(&mut self.model, next);
        let prev_version = self.version;
        self.version += 1;
        if self.slot_version.contains(&prev_version) {
            // Some slot's stream still lives in the old generation's KV:
            // keep the model until every such slot is re-admitted.
            self.old.push((prev_version, prev));
        } else {
            self.domain.retire(Box::new(prev));
            self.domain.collect();
        }
        Ok(())
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.model.pool().pool_stats())
    }

    fn reclaim_stats(&self) -> Option<ReclaimStats> {
        Some(self.domain.stats())
    }
}

/// Speculative-decoding configuration: the draft length and how the
/// draft model is derived from the target's weights ([`DraftSpec`]).
/// Parsed from `SAIL_SPEC` (`off`, or `k:<n>[,bits:<level>][,layers:<l>]`)
/// or built explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Tokens drafted per speculation round (`≥ 1`).
    pub k: usize,
    /// Draft derivation (default: the draft *is* the target — the
    /// 100%-acceptance calibration point).
    pub draft: DraftSpec,
    /// Test-only adversary: corrupt every draft token to
    /// `(argmax + 1) mod vocab`, forcing zero acceptance per round. Pins
    /// the claim that the emitted stream cannot depend on draft quality.
    pub sabotage: bool,
}

impl SpecConfig {
    /// Draft `k` tokens per round with an identical-weights draft.
    pub fn new(k: usize) -> Self {
        SpecConfig { k, draft: DraftSpec::default(), sabotage: false }
    }
}

/// Parse a `SAIL_SPEC` value. Grammar: `off` (speculation disabled —
/// `Ok(None)`) or a comma-separated field list `k:<n>[,bits:<level>]`
/// `[,layers:<l>]`: `k` is the draft length (required, ≥ 1), `bits` caps
/// every draft projection at a [`QuantLevel`], `layers` truncates the
/// draft's decoder stack. Strict: any malformed field is an `Err`; the
/// env path downgrades that to a warning ([`spec_config_from_env`]).
pub fn parse_spec_config(v: &str) -> Result<Option<SpecConfig>, String> {
    let t = v.trim();
    if t.eq_ignore_ascii_case("off") {
        return Ok(None);
    }
    let mut k = None;
    let mut draft = DraftSpec::default();
    for part in t.split(',') {
        let Some((key, val)) = part.split_once(':') else {
            return Err(format!(
                "invalid SAIL_SPEC field {part:?} \
                 (want off, or k:<n>[,bits:<level>][,layers:<l>])"
            ));
        };
        let val = val.trim();
        match key.trim() {
            "k" => match val.parse::<usize>() {
                Ok(n) if n >= 1 => k = Some(n),
                _ => {
                    return Err(format!("invalid SAIL_SPEC draft length {val:?} (want k ≥ 1)"));
                }
            },
            "bits" => match QuantLevel::parse(val) {
                Some(level) => draft.bits = Some(level),
                None => return Err(format!("invalid SAIL_SPEC draft quant level {val:?}")),
            },
            "layers" => match val.parse::<usize>() {
                Ok(n) if n >= 1 => draft.layers = Some(n),
                _ => {
                    return Err(format!(
                        "invalid SAIL_SPEC draft layer count {val:?} (want ≥ 1)"
                    ));
                }
            },
            other => {
                return Err(format!("unknown SAIL_SPEC field {other:?} (want k/bits/layers)"));
            }
        }
    }
    match k {
        Some(k) => Ok(Some(SpecConfig { k, draft, sabotage: false })),
        None => Err("SAIL_SPEC is missing the required k:<n> field".into()),
    }
}

/// Read `SAIL_SPEC` leniently: unset or empty means disabled; a malformed
/// value warns on stderr and disables speculation instead of failing the
/// serving process (same policy as the other `SAIL_*` env knobs).
pub fn spec_config_from_env() -> Option<SpecConfig> {
    let v = std::env::var("SAIL_SPEC").ok()?;
    if v.trim().is_empty() {
        return None;
    }
    match parse_spec_config(&v) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!("warning: {e}; speculation disabled");
            None
        }
    }
}

/// Speculation counters, cumulative across an engine's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Speculation rounds (one draft loop + one multi-row verify each).
    pub rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub drafted: u64,
    /// Draft tokens the target accepted (argmax-equal predictions).
    pub accepted: u64,
    /// Tokens served straight from the accepted buffer — feeds that ran
    /// **no** forward at all, the latency win speculation exists for.
    pub buffered: u64,
    /// Decode feeds served by a plain single-token target step instead
    /// of a round (no window room, no row grant, or an unhealthy draft).
    pub fallback_steps: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Self-speculative decoding on the LUT serving path.
///
/// Wraps a [`TransformerServeEngine`] *target* plus a cheap *draft*
/// [`LutTransformer`] quantized from the **same** float weights
/// ([`FloatWeights`]) at fewer effective bits and/or a truncated layer
/// stack ([`DraftSpec::from_target`]). On a decode feed the draft
/// proposes up to `k` tokens autoregressively; the target judges all of
/// them in **one** multi-row [`LutTransformer::step_runs_all_logits`]
/// forward — on the LUT path a k-row verify costs one LUT build per
/// weight chunk, nearly the price of a single decode step (the paper's
/// batched-GEMV amortization, PR-5). The longest draft prefix whose
/// tokens argmax-match the target's own predictions is accepted; the
/// rejected tail is rolled back off both KV caches
/// ([`LutTransformer::truncate_slot`], contiguous and paged stores
/// alike).
///
/// Determinism contract: **speculation changes latency, never tokens.**
/// Every emitted token is the target's own argmax computed over exactly
/// the cache prefix plain decode would have — acceptance only decides
/// how many of those tokens one round yields. Draft failures are
/// absorbed (the slot decodes plainly until reset); draft quality, bit
/// width, even an adversarial always-wrong draft ([`SpecConfig::sabotage`])
/// affect throughput only. Pinned by `tests/speculative_decode.rs`
/// across the full chunk × width × NUMA × KV-layout × faults matrix.
pub struct SpeculativeEngine {
    target: TransformerServeEngine,
    draft: LutTransformer,
    k: usize,
    sabotage: bool,
    /// Accepted-but-unserved target tokens per slot (front = next out).
    pending: Vec<VecDeque<i32>>,
    /// The feed `(token, position)` the buffer head is the answer to.
    expect: Vec<Option<(i32, usize)>>,
    /// Memo of the slot's last serviced decode feed
    /// `(token, position, output)` — replayed when the batcher's solo
    /// retry re-sends a feed that already succeeded inside a failed
    /// collective call.
    last: Vec<Option<(i32, usize, i32)>>,
    /// Exclusive upper bound of target-KV positions holding speculative
    /// or accepted writes per slot (the rollback watermark).
    hi: Vec<usize>,
    /// Same watermark for the draft's KV.
    draft_hi: Vec<usize>,
    /// Draft health: a failed draft forward leaves its KV suspect, so
    /// the slot decodes plainly until `reset_slot` clears it.
    draft_ok: Vec<bool>,
    /// Rows the current iteration may spend on drafting (each drafted
    /// token costs one draft row + one extra verify row). Engines driven
    /// outside a batcher never receive a grant and speculate freely.
    grant: usize,
    stats: SpecStats,
}

impl SpeculativeEngine {
    /// Wrap `target` with an explicit draft model. The draft must share
    /// the target's batch size, vocab, and context window; in the
    /// intended self-speculative setup both are quantized from the same
    /// [`FloatWeights`] so their predictions correlate, but correctness
    /// never depends on that — stream identity holds for *any* draft.
    pub fn new(
        target: TransformerServeEngine,
        draft: LutTransformer,
        cfg: SpecConfig,
    ) -> Result<Self> {
        if cfg.k == 0 {
            bail!("speculative draft length k must be ≥ 1");
        }
        let b = target.batch();
        if draft.batch() != b {
            bail!("draft batch {} != target batch {b}", draft.batch());
        }
        if draft.spec().vocab != target.vocab() {
            bail!("draft vocab {} != target vocab {}", draft.spec().vocab, target.vocab());
        }
        if draft.spec().max_context < target.max_context() {
            bail!(
                "draft context window {} shorter than the target's {}",
                draft.spec().max_context,
                target.max_context()
            );
        }
        Ok(SpeculativeEngine {
            target,
            draft,
            k: cfg.k,
            sabotage: cfg.sabotage,
            pending: (0..b).map(|_| VecDeque::new()).collect(),
            expect: vec![None; b],
            last: vec![None; b],
            hi: vec![0; b],
            draft_hi: vec![0; b],
            draft_ok: vec![true; b],
            grant: usize::MAX,
            stats: SpecStats::default(),
        })
    }

    /// Seeded self-speculative pair: target and draft quantized from the
    /// **same** [`FloatWeights::generate`] stream, the draft at the
    /// reduced precision / truncated depth `cfg.draft` asks for. The
    /// draft always runs the contiguous KV store — it is scratch state,
    /// rolled back wholesale every round, and must not compete for the
    /// target's page pool.
    pub fn random_with_kv(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
        kv_cfg: KvRuntimeConfig,
        cfg: SpecConfig,
    ) -> Result<Self> {
        let floats = FloatWeights::generate(&spec, seed);
        let draft_spec = cfg.draft.from_target(&spec)?;
        let target = TransformerServeEngine::new(LutTransformer::from_floats(
            spec,
            &floats,
            batch,
            Arc::clone(&pool),
            kv_cfg,
        )?);
        let draft = LutTransformer::from_floats(
            draft_spec,
            &floats,
            batch,
            pool,
            KvRuntimeConfig::contiguous(),
        )?;
        SpeculativeEngine::new(target, draft, cfg)
    }

    /// The wrapped target engine (its model owns the authoritative KV).
    pub fn target(&self) -> &TransformerServeEngine {
        &self.target
    }

    /// The reduced-precision draft model.
    pub fn draft_model(&self) -> &LutTransformer {
        &self.draft
    }

    /// Speculation counters so far.
    pub fn stats(&self) -> SpecStats {
        self.stats
    }

    /// A prefill chunk: mirror it into the draft (keeping the two caches
    /// in lockstep), forward it through the target, and drop any
    /// speculative state it supersedes.
    fn prefill_one(&mut self, r: &SlotRun) -> Result<i32> {
        let s = r.slot;
        let start = r.start_pos as usize;
        let end = start + r.tokens.len();
        self.pending[s].clear();
        self.expect[s] = None;
        self.last[s] = None;
        if self.hi[s] > start {
            self.target.model_mut().truncate_slot(s, start, self.hi[s])?;
            self.hi[s] = start;
        }
        if self.draft_ok[s] && self.draft_hi[s] > start {
            if self.draft.truncate_slot(s, start, self.draft_hi[s]).is_err() {
                self.draft_ok[s] = false;
            }
            self.draft_hi[s] = start;
        }
        if self.draft_ok[s] {
            let druns = [DecodeRun { slot: s, tokens: r.tokens, start_pos: start }];
            if self.draft.step_runs(&druns).is_err() {
                self.draft_ok[s] = false;
            } else {
                self.draft_hi[s] = end;
            }
        }
        let next = self.target.step_runs(std::slice::from_ref(r))?[0];
        self.hi[s] = end;
        Ok(next)
    }

    /// One decode feed `(tok @ pos)` for slot `s`: serve from the
    /// accepted buffer when the feed continues the speculated line,
    /// otherwise roll both caches back to `pos` and run a fresh round.
    fn decode_one(&mut self, s: usize, tok: i32, pos: usize) -> Result<i32> {
        if let Some((lt, lp, lo)) = self.last[s] {
            if (lt, lp) == (tok, pos) {
                // The batcher's solo retry replays feeds that already
                // succeeded inside a failed collective call; the answer
                // comes from the memo, not a second forward.
                return Ok(lo);
            }
        }
        if let Some((et, ep)) = self.expect[s] {
            if (et, ep) == (tok, pos) {
                if let Some(next) = self.pending[s].pop_front() {
                    self.stats.buffered += 1;
                    self.expect[s] = Some((next, pos + 1));
                    self.last[s] = Some((tok, pos, next));
                    return Ok(next);
                }
                // Buffer drained exactly at the speculation frontier
                // (`hi == pos`): fall through to a fresh round.
            } else {
                // The stream turned elsewhere (slot recompute without a
                // reset): the buffer is stale.
                self.pending[s].clear();
                self.expect[s] = None;
            }
        }
        // Re-anchor both caches at the fed position so the round below
        // starts from exactly the plain-decode state.
        if self.hi[s] > pos {
            self.target.model_mut().truncate_slot(s, pos, self.hi[s])?;
            self.hi[s] = pos;
        }
        if self.draft_ok[s] && self.draft_hi[s] > pos {
            if self.draft.truncate_slot(s, pos, self.draft_hi[s]).is_err() {
                self.draft_ok[s] = false;
            }
            self.draft_hi[s] = pos;
        }
        self.speculate(s, tok, pos)
    }

    /// One speculation round at `(tok @ pos)`: draft up to `k` tokens,
    /// verify them in one multi-row target forward, accept the longest
    /// argmax-matching prefix, roll the rejected tail back off both
    /// caches. Degrades to a plain target step when the window, the row
    /// grant, or the draft's health leaves no room to draft.
    fn speculate(&mut self, s: usize, tok: i32, pos: usize) -> Result<i32> {
        let ctx = self.target.max_context();
        debug_assert!(pos < ctx, "validated by the callers");
        let mut k_plan = self.k.min(ctx - pos - 1);
        if !self.draft_ok[s] {
            k_plan = 0;
        }
        k_plan = k_plan.min(self.grant / 2);
        // 1. Draft autoregressively at reduced precision. A draft-side
        //    failure must never surface on the serving path: stop
        //    drafting and decode plainly until the slot is reset.
        let mut drafts: Vec<i32> = Vec::with_capacity(k_plan);
        let mut cur = tok;
        for i in 0..k_plan {
            let item = [DecodeItem { slot: s, token: cur, pos: pos + i }];
            if self.draft.step(&item).is_err() {
                self.draft_ok[s] = false;
                break;
            }
            self.draft_hi[s] = pos + i + 1;
            let mut d = argmax_logits(self.draft.logits().row(0));
            if self.sabotage {
                d = (d + 1).rem_euclid(self.target.vocab() as i32);
            }
            drafts.push(d);
            cur = d;
        }
        let k_eff = drafts.len();
        if k_eff == 0 {
            // Nothing to verify: a plain single-token target step —
            // exactly what a non-speculative engine would run — with the
            // draft kept in lockstep for the next round.
            if self.draft_ok[s] {
                let item = [DecodeItem { slot: s, token: tok, pos }];
                if self.draft.step(&item).is_err() {
                    self.draft_ok[s] = false;
                } else {
                    self.draft_hi[s] = pos + 1;
                }
            }
            let toks = [tok];
            let run = SlotRun { slot: s, tokens: &toks, start_pos: pos as i32 };
            let next = self.target.step_runs(std::slice::from_ref(&run))?[0];
            self.hi[s] = pos + 1;
            self.stats.fallback_steps += 1;
            self.expect[s] = Some((next, pos + 1));
            self.last[s] = Some((tok, pos, next));
            return Ok(next);
        }
        self.grant = self.grant.saturating_sub(2 * k_eff);
        // 2. One multi-row verify forward of the target over the fed
        //    token plus the draft: row i's logits are bit-identical to
        //    what plain decode would compute after consuming the first
        //    i + 1 of those tokens.
        let mut vtokens = Vec::with_capacity(k_eff + 1);
        vtokens.push(tok);
        vtokens.extend_from_slice(&drafts);
        let vrun = [DecodeRun { slot: s, tokens: &vtokens, start_pos: pos }];
        if let Err(e) = self.target.model_mut().step_runs_all_logits(&vrun) {
            // Restore the pre-round cache (the forward may have written
            // any prefix of the verify positions) and surface the error
            // — the batcher's solo retry or EngineFault finish owns it.
            self.target.model_mut().truncate_slot(s, pos, pos + k_eff + 1)?;
            if self.draft_ok[s] && self.draft.truncate_slot(s, pos, self.draft_hi[s]).is_err() {
                self.draft_ok[s] = false;
            }
            self.draft_hi[s] = pos;
            self.pending[s].clear();
            self.expect[s] = None;
            self.last[s] = None;
            return Err(e);
        }
        // 3. Deterministic argmax acceptance. The emitted tokens are all
        //    target argmaxes by construction — the draft only decides how
        //    many of them this round yields.
        let targets: Vec<i32> =
            (0..=k_eff).map(|i| argmax_logits(self.target.model().logits().row(i))).collect();
        let mut j = 0;
        while j < k_eff && drafts[j] == targets[j] {
            j += 1;
        }
        self.stats.rounds += 1;
        self.stats.drafted += k_eff as u64;
        self.stats.accepted += j as u64;
        // 4. Roll the rejected tail back off both caches. Positions
        //    pos..=pos+j now hold exactly the tokens plain decode would
        //    have written there (the fed token, then j accepted tokens).
        self.target.model_mut().truncate_slot(s, pos + j + 1, pos + k_eff + 1)?;
        self.hi[s] = pos + j + 1;
        if self.draft_ok[s] {
            let keep = (pos + j + 1).min(self.draft_hi[s]);
            if self.draft.truncate_slot(s, keep, self.draft_hi[s]).is_err() {
                self.draft_ok[s] = false;
            } else {
                self.draft_hi[s] = keep;
                if j == k_eff {
                    // Full acceptance: the draft never consumed its own
                    // last proposal — feed it so the next round's draft
                    // history is gapless.
                    let item =
                        [DecodeItem { slot: s, token: drafts[k_eff - 1], pos: pos + k_eff }];
                    if self.draft.step(&item).is_err() {
                        self.draft_ok[s] = false;
                    } else {
                        self.draft_hi[s] = pos + k_eff + 1;
                    }
                }
            }
        }
        // 5. Emit the first target token now; the accepted tail is
        //    served from the buffer on the following feeds, no forwards
        //    needed.
        let out = targets[0];
        self.pending[s].clear();
        self.pending[s].extend(&targets[1..j + 1]);
        self.expect[s] = Some((out, pos + 1));
        self.last[s] = Some((tok, pos, out));
        Ok(out)
    }
}

impl DecodeEngine for SpeculativeEngine {
    fn batch(&self) -> usize {
        self.target.batch()
    }

    fn vocab(&self) -> usize {
        self.target.vocab()
    }

    fn max_context(&self) -> usize {
        self.target.max_context()
    }

    fn max_run(&self) -> usize {
        self.target.max_run()
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        let b = self.target.batch();
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        let ctx = self.target.max_context();
        let mut next = vec![0i32; b];
        for s in 0..b {
            if !active[s] {
                continue;
            }
            if positions[s] < 0 {
                bail!("negative position {} for slot {s}", positions[s]);
            }
            if positions[s] as usize >= ctx {
                bail!(
                    "position {} for slot {s} outside the {ctx}-token context window",
                    positions[s]
                );
            }
            next[s] = self.decode_one(s, tokens[s], positions[s] as usize)?;
        }
        Ok(next)
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.batch(), self.max_context(), self.max_run(), runs)?;
        let mut out = vec![0i32; runs.len()];
        for (ri, r) in runs.iter().enumerate() {
            out[ri] = if r.tokens.len() == 1 {
                self.decode_one(r.slot, r.tokens[0], r.start_pos as usize)?
            } else {
                self.prefill_one(r)?
            };
        }
        Ok(out)
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.target.reset_slot(slot)?;
        self.draft.reset_slot(slot)?;
        self.pending[slot].clear();
        self.expect[slot] = None;
        self.last[slot] = None;
        self.hi[slot] = 0;
        self.draft_hi[slot] = 0;
        self.draft_ok[slot] = true;
        Ok(())
    }

    fn prefix_attach(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        // The attach covers target-KV positions only; the draft starts
        // cold for the slot, so its early proposals may be poor — that
        // costs acceptance, never tokens.
        self.target.prefix_attach(slot, feed)
    }

    fn prefix_insert(&mut self, slot: usize, feed: &[i32]) -> Result<()> {
        self.target.prefix_insert(slot, feed)
    }

    fn kv_metrics(&self) -> Option<KvMetrics> {
        self.target.kv_metrics()
    }

    fn spec_grant(&mut self, rows: usize) {
        self.grant = rows;
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        Some(self.stats)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        self.target.pool_stats()
    }

    fn reclaim_stats(&self) -> Option<ReclaimStats> {
        self.target.reclaim_stats()
    }
}

/// Deterministic mock: next token = hash(slot history) — context-sensitive
/// (like a real LM, the output depends on everything fed so far), which
/// lets tests detect KV-state leakage across requests.
pub struct MockEngine {
    batch: usize,
    vocab: usize,
    max_context: usize,
    /// Per-slot rolling history hash (the "KV cache").
    state: Vec<u64>,
    pub steps: u64,
}

impl MockEngine {
    pub fn new(batch: usize, vocab: usize, max_context: usize) -> Self {
        MockEngine { batch, vocab, max_context, state: vec![0; batch], steps: 0 }
    }
}

impl DecodeEngine for MockEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn max_run(&self) -> usize {
        usize::MAX
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        // Same contract as the real engines: a mis-sized call is a typed
        // error, not a panic that aborts the caller (pre-fix this was an
        // `assert_eq!` on the token arity alone).
        let b = self.batch;
        if tokens.len() != b || positions.len() != b || active.len() != b {
            bail!(
                "step arity mismatch: tokens={} positions={} active={} batch={b}",
                tokens.len(),
                positions.len(),
                active.len()
            );
        }
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| {
                if !active[s] {
                    return 0;
                }
                let mix = self.state[s]
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(tokens[s] as u64)
                    .wrapping_add((positions[s] as u64) << 32);
                self.state[s] = mix;
                // Never emit token 0 (reserved as EOS in tests) unless the
                // hash lands there; tests pick eos handling explicitly.
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn step_runs(&mut self, runs: &[SlotRun]) -> Result<Vec<i32>> {
        validate_runs(self.batch, self.max_context, self.max_run(), runs)?;
        self.steps += 1;
        Ok(runs
            .iter()
            .map(|r| {
                // The same per-token fold `step` applies, so chunked
                // feeding is bit-identical to token-at-a-time feeding.
                let mut mix = self.state[r.slot];
                for (j, &t) in r.tokens.iter().enumerate() {
                    let pos = r.start_pos + j as i32;
                    mix = mix
                        .wrapping_mul(0x100000001B3)
                        .wrapping_add(t as u64)
                        .wrapping_add((pos as u64) << 32);
                }
                self.state[r.slot] = mix;
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.state[slot] = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_context_sensitive() {
        let mut e1 = MockEngine::new(2, 100, 64);
        let mut e2 = MockEngine::new(2, 100, 64);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2);
        // Different history ⇒ different next token (with these inputs).
        let b1 = e1.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        e2.reset_slot(0).unwrap();
        let b2 = e2.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        assert_ne!(b1[0], b2[0], "reset must change slot-0 trajectory");
        assert_eq!(b1[1], b2[1], "slot 1 unaffected by slot-0 reset");
    }

    #[test]
    fn inactive_slots_are_inert() {
        let mut e = MockEngine::new(2, 100, 64);
        let out = e.step(&[1, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0);
        // Slot 1 state untouched.
        assert_eq!(e.state[1], 0);
    }

    fn lut_engine(batch: usize, threads: usize) -> LutGemvServeEngine {
        LutGemvServeEngine::random(
            7,
            64,               // vocab
            32,               // hidden
            QuantLevel::Q4,
            16,               // group
            4,                // nbw
            batch,
            64,               // max context
            WorkerPool::shared(threads),
        )
    }

    #[test]
    fn lut_serve_engine_token_streams_identical_across_thread_counts() {
        // The tiled backend is bit-exact at every pool width, so the decode
        // trajectory must be too.
        let mut streams = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut e = lut_engine(2, threads);
            let mut toks = vec![3, 11];
            let mut got = Vec::new();
            for pos in 0..12 {
                toks = e.step(&toks, &[pos, pos], &[true, true]).unwrap();
                got.push(toks.clone());
            }
            streams.push(got);
        }
        assert_eq!(streams[0], streams[1], "1 vs 2 threads diverged");
        assert_eq!(streams[0], streams[2], "1 vs 4 threads diverged");
    }

    #[test]
    fn lut_serve_engine_is_context_sensitive_and_resettable() {
        let mut e1 = lut_engine(2, 1);
        let mut e2 = lut_engine(2, 1);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2, "same seed must give the same model");
        // Diverge the histories: reset slot 0 on e2 only, then walk both
        // engines in lockstep. Slot 1 must stay bit-identical; slot 0's
        // trajectory must differ somewhere.
        e2.reset_slot(0).unwrap();
        let mut slot0_diverged = false;
        for pos in 1..8 {
            let b1 = e1.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            let b2 = e2.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            assert_eq!(b1[1], b2[1], "slot 1 affected by slot-0 reset at pos {pos}");
            slot0_diverged |= b1[0] != b2[0];
        }
        assert!(slot0_diverged, "reset did not change slot-0 trajectory");
        assert!(e1.gemv_stats.luts_built > 0, "decode did not run the LUT path");
    }

    #[test]
    fn batcher_serves_requests_on_the_lut_gemv_path() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let mut b = Batcher::new(lut_engine(3, 2), BatcherConfig::default());
        for id in 0..7u64 {
            b.submit(Request::new(id, vec![1 + id as i32, 2], 4));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 7);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            for &t in &r.tokens {
                assert!((0..64).contains(&t), "token {t} outside vocab");
            }
        }
        let engine = b.engine();
        assert!(engine.steps > 0);
        assert!(engine.gemv_stats.lut_reads > 0, "no LUT reads on the serving path");
    }

    #[test]
    fn argmax_is_nan_safe_with_documented_tie_rule() {
        // Regression: the pre-fix `v > row[best]` scan returned index 0
        // whenever row[0] was NaN (every comparison against NaN is false).
        assert_eq!(argmax_logits(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax_logits(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax_logits(&[2.0, f32::NAN, 1.0]), 0);
        // All-NaN and empty rows map to the token-0 sentinel.
        assert_eq!(argmax_logits(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_logits(&[]), 0);
        // Ties: lowest index wins.
        assert_eq!(argmax_logits(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax_logits(&[1.0, 3.0, 3.0]), 1);
        // -inf is an ordinary (very small) value, not a sentinel.
        assert_eq!(argmax_logits(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax_logits(&[f32::NEG_INFINITY, f32::NAN]), 0);
    }

    #[test]
    fn mis_sized_step_is_an_error_not_a_panic() {
        // Regression: pre-fix these were `assert_eq!`s — a bad caller
        // aborted the server worker instead of getting an Err back.
        let mut e = lut_engine(2, 1);
        assert!(e.step(&[1], &[0], &[true]).is_err());
        assert!(e.step(&[1, 2], &[0], &[true, true]).is_err());
        assert!(e.step(&[1, 2], &[0, 0], &[true]).is_err());
        // The engine still serves after a rejected call.
        assert!(e.step(&[1, 2], &[0, 0], &[true, true]).is_ok());

        let mut t = transformer_engine(2, 1);
        assert!(t.step(&[1], &[0], &[true]).is_err());
        assert!(t.step(&[1, 2], &[0, -1], &[true, true]).is_err(), "negative position");
        assert!(t.step(&[1, 2], &[0, 0], &[true, true]).is_ok());

        // The mock holds the same contract (pre-fix: an `assert_eq!` on
        // the token arity alone — a panic, and only for one of the three
        // mis-sized inputs).
        let mut m = MockEngine::new(2, 97, 8);
        assert!(m.step(&[1], &[0], &[true]).is_err());
        assert!(m.step(&[1, 2], &[0, 0], &[true]).is_err());
        assert!(m.step(&[1, 2], &[0, 0], &[true, true]).is_ok());
    }

    fn transformer_engine(batch: usize, threads: usize) -> TransformerServeEngine {
        TransformerServeEngine::random(
            crate::model::DecodeSpec::tiny(2, crate::model::KvCacheSpec::fp16()),
            11,
            batch,
            WorkerPool::shared(threads),
        )
        .unwrap()
    }

    #[test]
    fn transformer_engine_serves_through_the_batcher() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let mut b = Batcher::new(transformer_engine(2, 2), BatcherConfig::default());
        for id in 0..5u64 {
            b.submit(Request::new(id, vec![1 + id as i32, 2], 3));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        let vocab = b.engine().vocab() as i32;
        for r in &done {
            assert_eq!(r.tokens.len(), 3);
            for &t in &r.tokens {
                assert!((0..vocab).contains(&t), "token {t} outside vocab");
            }
        }
        // Every projection of every layer ran on the LUT path.
        let stats = b.engine().stats();
        for (l, layer) in stats.layers.iter().enumerate() {
            for (name, s) in layer.projections() {
                assert!(s.luts_built > 0, "layer {l} {name}: no LUTs built");
                assert!(s.lut_reads > 0, "layer {l} {name}: no LUT reads");
            }
        }
        assert!(stats.head.lut_reads > 0, "head projection never ran");
        assert!(stats.tokens > 0 && stats.steps > 0);
    }

    #[test]
    fn transformer_engine_inactive_slots_are_inert() {
        let mut e = transformer_engine(2, 1);
        let out = e.step(&[3, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0, "inactive slot must report the 0 sentinel");
        // Slot 1's KV pane was never written: stepping it later from
        // position 0 matches a fresh engine exactly.
        let mut fresh = transformer_engine(2, 1);
        let a = e.step(&[5, 7], &[1, 0], &[true, true]).unwrap();
        fresh.step(&[3, 0], &[0, 0], &[true, false]).unwrap();
        let b = fresh.step(&[5, 7], &[1, 0], &[true, true]).unwrap();
        assert_eq!(a[1], b[1], "slot 1 was touched while inactive");
    }

    #[test]
    fn step_runs_native_paths_match_the_sequential_oracle() {
        // Twin engines, same seed: the native multi-row `step_runs` must
        // produce the same outputs AND leave the same slot state as the
        // generic decomposition into single-token `step` calls.
        fn runs<'a>(p0: &'a [i32], p1: &'a [i32]) -> Vec<SlotRun<'a>> {
            vec![
                SlotRun { slot: 0, tokens: p0, start_pos: 0 },
                SlotRun { slot: 1, tokens: p1, start_pos: 0 },
            ]
        }
        let p0 = [3, 7, 11, 2, 9];
        let p1 = [5i32];

        let mut m_native = MockEngine::new(2, 97, 64);
        let mut m_oracle = MockEngine::new(2, 97, 64);
        let a = m_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut m_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "mock native step_runs diverged from the oracle");
        assert_eq!(m_native.state, m_oracle.state, "mock slot state diverged");

        let mut l_native = lut_engine(2, 2);
        let mut l_oracle = lut_engine(2, 1);
        let a = l_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut l_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "lut-toy native step_runs diverged from the oracle");
        // Continue decoding from the post-run state: trajectories must
        // stay locked (the hidden states are bit-identical).
        let cont = |e: &mut LutGemvServeEngine, t0: i32, t1: i32| {
            let toks = [t0, t1];
            let r: Vec<SlotRun> = (0..2)
                .map(|s| SlotRun {
                    slot: s,
                    tokens: std::slice::from_ref(&toks[s]),
                    start_pos: [p0.len(), p1.len()][s] as i32,
                })
                .collect();
            e.step_runs(&r).unwrap()
        };
        assert_eq!(cont(&mut l_native, a[0], a[1]), cont(&mut l_oracle, b[0], b[1]));

        let mut t_native = transformer_engine(2, 2);
        let mut t_oracle = transformer_engine(2, 1);
        let a = t_native.step_runs(&runs(&p0, &p1)).unwrap();
        let b = step_runs_via_step(&mut t_oracle, &runs(&p0, &p1)).unwrap();
        assert_eq!(a, b, "transformer native step_runs diverged from the oracle");
    }

    #[test]
    fn step_runs_rejects_malformed_runs() {
        let mut e = MockEngine::new(2, 97, 8);
        let toks = [1i32, 2, 3];
        let ok = SlotRun { slot: 0, tokens: &toks, start_pos: 0 };
        assert!(e.step_runs(&[ok]).is_ok());
        // Slot outside the batch.
        assert!(e.step_runs(&[SlotRun { slot: 2, tokens: &toks, start_pos: 0 }]).is_err());
        // Duplicate slot in one iteration.
        assert!(e.step_runs(&[ok, ok]).is_err());
        // Empty run.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &[], start_pos: 0 }]).is_err());
        // Negative start position.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: -1 }]).is_err());
        // Run crossing the context window (positions 6..9, window 8).
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 6 }]).is_err());
        // The engine still serves after a rejected call.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 3 }]).is_ok());

        // The transformer path reports the same class of errors.
        let mut t = transformer_engine(2, 1);
        let ctx = t.max_context() as i32;
        assert!(t.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: ctx - 1 }]).is_err());
        assert!(t.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 0 }]).is_ok());
    }

    #[test]
    fn step_runs_rejects_runs_longer_than_max_run() {
        // A minimal engine (no step_runs override) advertises
        // max_run = 1; pre-fix the generic decomposition happily fed it
        // longer runs. Now that is a typed error like every other
        // contract violation, checked before any slot state mutates.
        struct OneToken(MockEngine);
        impl DecodeEngine for OneToken {
            fn batch(&self) -> usize {
                self.0.batch()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn max_context(&self) -> usize {
                self.0.max_context()
            }
            fn step(
                &mut self,
                tokens: &[i32],
                positions: &[i32],
                active: &[bool],
            ) -> Result<Vec<i32>> {
                self.0.step(tokens, positions, active)
            }
            fn reset_slot(&mut self, slot: usize) -> Result<()> {
                self.0.reset_slot(slot)
            }
        }
        let mut e = OneToken(MockEngine::new(2, 97, 64));
        let toks = [1i32, 2, 3];
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks, start_pos: 0 }]).is_err());
        assert_eq!(e.0.state, vec![0, 0], "rejected run mutated slot state");
        // Single-token runs still serve, and the empty run list is a
        // no-op iteration, not an error.
        assert!(e.step_runs(&[SlotRun { slot: 0, tokens: &toks[..1], start_pos: 0 }]).is_ok());
        assert_eq!(e.step_runs(&[]).unwrap(), Vec::<i32>::new());
        // Direct validation sees the same set of cases.
        assert!(validate_runs(2, 64, 1, &[SlotRun { slot: 0, tokens: &toks, start_pos: 0 }])
            .is_err());
        assert!(validate_runs(2, 64, 4, &[SlotRun { slot: 0, tokens: &toks, start_pos: 0 }])
            .is_ok());
        assert!(validate_runs(2, 64, 4, &[]).is_ok(), "empty run list is valid");
    }

    #[test]
    fn step_runs_leaves_absent_slots_inert() {
        // Slots with no run this iteration keep their state bit-exactly,
        // through the mock's native path and the generic decomposition
        // alike (the decomposition marks them inactive on every inner
        // step).
        let mut native = MockEngine::new(3, 97, 64);
        native.step(&[5, 7, 9], &[0, 0, 0], &[true, true, true]).unwrap();
        let before = native.state.clone();
        let toks = [4i32, 1];
        native.step_runs(&[SlotRun { slot: 1, tokens: &toks, start_pos: 1 }]).unwrap();
        assert_eq!(native.state[0], before[0], "slot 0 touched by a slot-1 run");
        assert_eq!(native.state[2], before[2], "slot 2 touched by a slot-1 run");
        assert_ne!(native.state[1], before[1], "slot 1's run did not advance its state");
        let mut generic = MockEngine::new(3, 97, 64);
        generic.step(&[5, 7, 9], &[0, 0, 0], &[true, true, true]).unwrap();
        step_runs_via_step(&mut generic, &[SlotRun { slot: 1, tokens: &toks, start_pos: 1 }])
            .unwrap();
        assert_eq!(generic.state, native.state, "generic decomposition diverged");
    }

    #[test]
    fn spec_config_grammar_round_trips() {
        assert_eq!(parse_spec_config("off").unwrap(), None);
        assert_eq!(parse_spec_config(" OFF ").unwrap(), None);
        assert_eq!(parse_spec_config("k:4").unwrap().unwrap(), SpecConfig::new(4));
        let c = parse_spec_config("k:2, bits:q2, layers:1").unwrap().unwrap();
        assert_eq!(c.k, 2);
        assert_eq!(c.draft.bits, Some(QuantLevel::Q2));
        assert_eq!(c.draft.layers, Some(1));
        for bad in ["", "k:0", "k:x", "bits:q4", "k:2,bits:7", "k:2,layers:0", "k:2,foo:1", "4"] {
            assert!(parse_spec_config(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    fn spec_engine(cfg: SpecConfig, batch: usize, threads: usize) -> SpeculativeEngine {
        SpeculativeEngine::random_with_kv(
            crate::model::DecodeSpec::tiny(2, crate::model::KvCacheSpec::fp16()),
            11,
            batch,
            WorkerPool::shared(threads),
            KvRuntimeConfig::contiguous(),
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn speculative_stream_matches_plain_decode() {
        // Same (spec, seed) as `transformer_engine`, so the wrapper's
        // target is that exact model: the emitted stream must reproduce
        // it token for token, from an identical draft (full acceptance)
        // and a sabotaged always-wrong draft (zero acceptance) alike.
        fn drive(e: &mut dyn DecodeEngine, prompt: &[i32], n: usize) -> Vec<i32> {
            let mut toks = Vec::new();
            let mut t =
                e.step_runs(&[SlotRun { slot: 0, tokens: prompt, start_pos: 0 }]).unwrap()[0];
            for i in 0..n {
                toks.push(t);
                let tt = [t];
                let pos = (prompt.len() + i) as i32;
                t = e.step_runs(&[SlotRun { slot: 0, tokens: &tt, start_pos: pos }]).unwrap()[0];
            }
            toks.push(t);
            toks
        }
        let prompt = [3i32, 7, 11];
        let want = drive(&mut transformer_engine(1, 1), &prompt, 10);

        let mut full = spec_engine(SpecConfig::new(4), 1, 1);
        assert_eq!(drive(&mut full, &prompt, 10), want, "identical-draft stream diverged");
        let st = full.stats();
        assert!(st.rounds > 0, "speculation never ran");
        assert_eq!(st.accepted, st.drafted, "an identical draft must be fully accepted");
        assert!(st.buffered > 0, "no tokens were served from the accepted buffer");

        let mut sab = spec_engine(SpecConfig { sabotage: true, ..SpecConfig::new(4) }, 1, 1);
        assert_eq!(drive(&mut sab, &prompt, 10), want, "sabotaged-draft stream diverged");
        let st = sab.stats();
        assert!(st.drafted > 0 && st.accepted == 0, "an always-wrong draft cannot be accepted");
    }

    #[test]
    fn pjrt_shaped_engines_cap_runs_at_one_token() {
        // `max_run` defaults to 1 and `step_runs` to the generic
        // decomposition, so a minimal engine implements neither; the
        // batcher clamps its chunk to 1 and the default body serves it.
        struct OneTokenEngine(MockEngine);
        impl DecodeEngine for OneTokenEngine {
            fn batch(&self) -> usize {
                self.0.batch()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn max_context(&self) -> usize {
                self.0.max_context()
            }
            fn step(
                &mut self,
                tokens: &[i32],
                positions: &[i32],
                active: &[bool],
            ) -> Result<Vec<i32>> {
                self.0.step(tokens, positions, active)
            }
            fn reset_slot(&mut self, slot: usize) -> Result<()> {
                self.0.reset_slot(slot)
            }
        }
        assert_eq!(
            OneTokenEngine(MockEngine::new(1, 97, 64)).max_run(),
            1,
            "the default capability is one token per slot"
        );
        // Chunked serving through the batcher still works: the chunk is
        // clamped to 1 and the stream matches the mock's exactly.
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let toks = [4i32, 9, 2, 6];
        let want = {
            let mut m = Batcher::new(
                MockEngine::new(1, 97, 64),
                BatcherConfig { prefill_chunk: 1, ..BatcherConfig::default() },
            );
            m.submit(Request::new(0, toks.to_vec(), 3));
            m.run_to_completion().unwrap()[0].tokens.clone()
        };
        let mut b = Batcher::new(
            OneTokenEngine(MockEngine::new(1, 97, 64)),
            BatcherConfig { prefill_chunk: 16, ..BatcherConfig::default() },
        );
        b.submit(Request::new(0, toks.to_vec(), 3));
        let got = b.run_to_completion().unwrap()[0].tokens.clone();
        assert_eq!(got, want, "clamped chunking changed the token stream");
        assert_eq!(b.iterations(), 6, "4 prompt + 3 generated tokens, one per iteration");
    }

    #[test]
    fn swap_weights_is_generation_exact_and_reclaims_old_weights() {
        // Three engines: the swapped one, a no-swap control with the same
        // seed (the oracle for the pre-swap stream), and a fresh engine
        // built directly at the swap seed (the oracle for post-swap
        // admissions).
        let spec = || crate::model::DecodeSpec::tiny(2, crate::model::KvCacheSpec::fp16());
        let mut e = transformer_engine(2, 2);
        let mut control = transformer_engine(2, 2);
        let mut fresh =
            TransformerServeEngine::random(spec(), 500, 2, WorkerPool::shared(2)).unwrap();

        // Slot 0 prefills and decodes a few tokens before the swap.
        let p0 = [3i32, 7, 11];
        let run0 = SlotRun { slot: 0, tokens: &p0, start_pos: 0 };
        let mut t0 = e.step_runs(std::slice::from_ref(&run0)).unwrap()[0];
        let mut t0_c = control.step_runs(std::slice::from_ref(&run0)).unwrap()[0];
        assert_eq!(t0, t0_c);
        for i in 0..3 {
            let pos = (p0.len() + i) as i32;
            t0 = e.step(&[t0, 0], &[pos, 0], &[true, false]).unwrap()[0];
            t0_c = control.step(&[t0_c, 0], &[pos, 0], &[true, false]).unwrap()[0];
            assert_eq!(t0, t0_c, "pre-swap decode diverged at step {i}");
        }

        assert_eq!(e.weights_version(), 0);
        e.swap_weights(500).unwrap();
        assert_eq!(e.weights_version(), 1);
        assert_eq!(e.live_generations(), 2, "slot 0 must pin generation 0");
        assert_eq!(e.reclaim_stats().unwrap().retired, 0, "generation 0 retired too early");

        // Slot 1 is admitted after the swap: its stream must match the
        // fresh seed-500 engine bit for bit.
        e.reset_slot(1).unwrap();
        fresh.reset_slot(1).unwrap();
        let p1 = [9i32, 2];
        let run1 = SlotRun { slot: 1, tokens: &p1, start_pos: 0 };
        let mut t1 = e.step_runs(std::slice::from_ref(&run1)).unwrap()[0];
        let mut t1_f = fresh.step_runs(std::slice::from_ref(&run1)).unwrap()[0];
        assert_eq!(t1, t1_f, "post-swap admission must serve the new weights");

        // Mixed-generation iterations: both slots active in ONE step call
        // on the swapped engine (the partitioned path), each generation's
        // oracle running its slot solo.
        for i in 0..4 {
            let pos0 = (p0.len() + 3 + i) as i32;
            let pos1 = (p1.len() + i) as i32;
            let both = e.step(&[t0, t1], &[pos0, pos1], &[true, true]).unwrap();
            t0_c = control.step(&[t0_c, 0], &[pos0, 0], &[true, false]).unwrap()[0];
            t1_f = fresh.step(&[0, t1_f], &[0, pos1], &[false, true]).unwrap()[1];
            assert_eq!(both[0], t0_c, "pre-swap stream drifted off the old weights at {i}");
            assert_eq!(both[1], t1_f, "post-swap stream drifted off the new weights at {i}");
            t0 = both[0];
            t1 = both[1];
        }

        // Slot 0 finishes and is re-admitted: generation 0 loses its last
        // reference and must be reclaimed through the domain.
        e.reset_slot(0).unwrap();
        assert_eq!(e.live_generations(), 1, "generation 0 must retire on migration");
        let rs = e.reclaim_stats().unwrap();
        assert_eq!((rs.retired, rs.reclaimed, rs.pending), (1, 1, 0), "{rs:?}");
        // The engine surfaces its dispatch-pool counters too.
        assert!(e.pool_stats().unwrap().dispatches > 0, "no dispatches counted");
    }

    #[test]
    fn swap_on_externally_built_model_is_a_typed_error() {
        let model = LutTransformer::random(
            crate::model::DecodeSpec::tiny(2, crate::model::KvCacheSpec::fp16()),
            11,
            1,
            WorkerPool::shared(1),
        )
        .unwrap();
        let mut e = TransformerServeEngine::new(model);
        let err = e.swap_weights(99).unwrap_err().to_string();
        assert!(err.contains("rebuildable"), "unexpected error text: {err}");
        assert_eq!(e.weights_version(), 0, "a failed swap must not bump the generation");
        // The engine still serves after the rejected swap.
        assert!(e.step(&[1], &[0], &[true]).is_ok());
    }

    #[test]
    fn batched_lut_decode_matches_isolated_decode() {
        // Same isolation invariant the mock pins down, now on the real
        // kernel: co-scheduling must not change any request's tokens.
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let reqs: Vec<Request> =
            (0..4).map(|id| Request::new(id, vec![2 + id as i32], 3)).collect();
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = Batcher::new(lut_engine(1, 1), BatcherConfig::default());
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }
        let mut b = Batcher::new(lut_engine(2, 2), BatcherConfig::default());
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(&resp.tokens, &isolated[&resp.id], "request {} diverged", resp.id);
        }
    }
}
