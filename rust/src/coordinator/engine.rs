//! The decode-engine abstraction the batcher drives.
//!
//! Three execution engines implement it:
//! - [`PjrtEngine`] — the AOT-compiled model through PJRT (production when
//!   artifacts are present);
//! - [`LutGemvServeEngine`] — the tiled multi-threaded LUT-GEMV backend on
//!   the decode hot path: every `step` quantizes per-slot hidden state and
//!   runs one batched LUT-GEMV over the tied output projection, so the
//!   batcher serves tokens through the paper's actual kernel;
//! - [`MockEngine`] — a deterministic token automaton with the same
//!   slot/KV semantics, for property-testing batching invariants without
//!   any compute.

use std::sync::Arc;

use anyhow::Result;

use crate::lutgemv::engine::GemvStats;
use crate::lutgemv::{GemvOutput, LutGemvEngine};
use crate::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use crate::runtime::WorkerPool;

/// One decode iteration over all batch slots.
///
/// `tokens[s]`/`positions[s]` are only meaningful where `active[s]`;
/// inactive slots still occupy compute (the fixed-batch artifact) but
/// their outputs are ignored. Implementations must keep per-slot KV state
/// keyed by slot index and clear it on `reset_slot`.
pub trait DecodeEngine {
    fn batch(&self) -> usize;
    fn vocab(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Returns the next token per slot (greedy).
    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>>;
    /// Clear slot state before admitting a new request.
    fn reset_slot(&mut self, slot: usize) -> Result<()>;
}

/// PJRT-backed engine over the AOT decode artifact.
pub struct PjrtEngine {
    model: crate::runtime::DecodeModel,
}

// SAFETY: the xla crate's client/executable/literal types hold raw C
// pointers and an `Rc` to the client, making them !Send. A `PjrtEngine`
// is constructed with its *own* client (`PjrtEngine::load`), holds the
// only references to it, and is then moved wholesale into a single worker
// thread (`Server::spawn`) — it is never aliased across threads, so
// transferring ownership is sound. Do not clone the inner client out.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(model: crate::runtime::DecodeModel) -> Self {
        PjrtEngine { model }
    }

    pub fn load(dir: &std::path::Path, batch: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine { model: crate::runtime::DecodeModel::load(&client, dir, batch)? })
    }

    pub fn steps_executed(&self) -> u64 {
        self.model.steps_executed()
    }
}

impl DecodeEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.model.batch
    }

    fn vocab(&self) -> usize {
        self.model.manifest.config.vocab
    }

    fn max_context(&self) -> usize {
        self.model.manifest.config.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], _active: &[bool]) -> Result<Vec<i32>> {
        let logits = self.model.step(tokens, positions)?;
        Ok(self.model.argmax(&logits))
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.model.reset_kv(Some(&[slot]))
    }
}

/// The LUT-GEMV serving backend: decode steps run on the real tiled,
/// thread-parallel LUT-GEMV path instead of a mock.
///
/// The "model" is a deterministic single-layer recurrent LM built to put
/// all of its compute where SAIL's is — the quantized output projection:
/// each step mixes the incoming token into a per-slot f32 hidden state
/// (the engine-side KV analogue; reset on slot reuse), quantizes it to
/// int8, and computes logits for all slots with **one batched LUT-GEMV**
/// over the `[vocab, hidden]` weight matrix, exactly the iteration-level
/// tensor scheduling of §III-A. Greedy argmax picks the next token.
///
/// Because the tiled backend is bit-exact at every thread count, token
/// streams are reproducible across pool sizes — property-tested below.
///
/// The pool is `Arc`-shared: several engines (several models, or several
/// shards of one model) can serve concurrently off one process-wide set of
/// persistent workers instead of each spawning its own
/// (`tests/shared_pool_serving.rs` pins down isolation and determinism).
pub struct LutGemvServeEngine {
    gemv: LutGemvEngine,
    pool: Arc<WorkerPool>,
    /// Reused flat logits buffer (no allocation per iteration).
    logits: GemvOutput,
    /// Per-slot hidden state, `[batch * hidden]` (the slot-keyed state the
    /// `DecodeEngine` contract requires).
    hidden: Vec<f32>,
    batch: usize,
    max_context: usize,
    /// Accumulated kernel counters across all steps (observability).
    pub gemv_stats: GemvStats,
    pub steps: u64,
}

impl LutGemvServeEngine {
    /// Wrap a LUT-GEMV engine whose weights are `[vocab, hidden]`
    /// (transposed layout, as `LutGemvEngine` stores them). `pool` may be
    /// shared with other engines.
    pub fn new(
        gemv: LutGemvEngine,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        assert!(batch > 0);
        let hidden = vec![0.0f32; batch * gemv.k()];
        LutGemvServeEngine {
            gemv,
            pool,
            logits: GemvOutput::new(),
            hidden,
            batch,
            max_context,
            gemv_stats: GemvStats::default(),
            steps: 0,
        }
    }

    /// Convenience constructor with seeded random quantized weights —
    /// the same seed gives the same model at any batch size / pool width.
    #[allow(clippy::too_many_arguments)]
    pub fn random(
        seed: u64,
        vocab: usize,
        hidden: usize,
        level: QuantLevel,
        group: usize,
        nbw: u32,
        batch: usize,
        max_context: usize,
        pool: Arc<WorkerPool>,
    ) -> Self {
        let mut prng = crate::util::Prng::new(seed);
        let w: Vec<f32> = (0..vocab * hidden).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, vocab, hidden, level, group);
        LutGemvServeEngine::new(LutGemvEngine::new(wt, nbw), batch, max_context, pool)
    }

    /// Deterministic token/position embedding component `i` in `[-1, 1)`
    /// (SplitMix64-style finalizer; no PRNG state, so it is the same on
    /// every thread and at every batch size).
    fn embed(token: i32, position: i32, i: usize) -> f32 {
        let mut z = (token as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((position as u64) << 32)
            .wrapping_add((i as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        ((z >> 40) as f32) / ((1u64 << 23) as f32) - 1.0
    }

    /// The worker pool this engine dispatches on (shareable with other
    /// engines via `Arc::clone`).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    fn argmax(row: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    }
}

impl DecodeEngine for LutGemvServeEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.gemv.n()
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        assert_eq!(tokens.len(), self.batch);
        assert_eq!(positions.len(), self.batch);
        let k = self.gemv.k();
        // Recurrent state update for active slots (inactive slots keep
        // their state untouched — the fixed-batch artifact still computes
        // them, but their outputs are ignored).
        for s in 0..self.batch {
            if !active[s] {
                continue;
            }
            let h = &mut self.hidden[s * k..(s + 1) * k];
            for (i, hi) in h.iter_mut().enumerate() {
                *hi = 0.5 * *hi + Self::embed(tokens[s], positions[s], i);
            }
        }
        let xs: Vec<QuantizedVector> = (0..self.batch)
            .map(|s| QuantizedVector::quantize(&self.hidden[s * k..(s + 1) * k]))
            .collect();
        let stats = self.gemv.gemv_batch_into(&xs, &self.pool, &mut self.logits);
        self.gemv_stats += stats;
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| if active[s] { Self::argmax(self.logits.row(s)) } else { 0 })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        let k = self.gemv.k();
        self.hidden[slot * k..(slot + 1) * k].fill(0.0);
        Ok(())
    }
}

/// Deterministic mock: next token = hash(slot history) — context-sensitive
/// (like a real LM, the output depends on everything fed so far), which
/// lets tests detect KV-state leakage across requests.
pub struct MockEngine {
    batch: usize,
    vocab: usize,
    max_context: usize,
    /// Per-slot rolling history hash (the "KV cache").
    state: Vec<u64>,
    pub steps: u64,
}

impl MockEngine {
    pub fn new(batch: usize, vocab: usize, max_context: usize) -> Self {
        MockEngine { batch, vocab, max_context, state: vec![0; batch], steps: 0 }
    }
}

impl DecodeEngine for MockEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn step(&mut self, tokens: &[i32], positions: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        assert_eq!(tokens.len(), self.batch);
        self.steps += 1;
        Ok((0..self.batch)
            .map(|s| {
                if !active[s] {
                    return 0;
                }
                let mix = self.state[s]
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(tokens[s] as u64)
                    .wrapping_add((positions[s] as u64) << 32);
                self.state[s] = mix;
                // Never emit token 0 (reserved as EOS in tests) unless the
                // hash lands there; tests pick eos handling explicitly.
                (mix % self.vocab as u64) as i32
            })
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) -> Result<()> {
        self.state[slot] = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic_and_context_sensitive() {
        let mut e1 = MockEngine::new(2, 100, 64);
        let mut e2 = MockEngine::new(2, 100, 64);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2);
        // Different history ⇒ different next token (with these inputs).
        let b1 = e1.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        e2.reset_slot(0).unwrap();
        let b2 = e2.step(&[5, 5], &[1, 1], &[true, true]).unwrap();
        assert_ne!(b1[0], b2[0], "reset must change slot-0 trajectory");
        assert_eq!(b1[1], b2[1], "slot 1 unaffected by slot-0 reset");
    }

    #[test]
    fn inactive_slots_are_inert() {
        let mut e = MockEngine::new(2, 100, 64);
        let out = e.step(&[1, 9], &[0, 0], &[true, false]).unwrap();
        assert_eq!(out[1], 0);
        // Slot 1 state untouched.
        assert_eq!(e.state[1], 0);
    }

    fn lut_engine(batch: usize, threads: usize) -> LutGemvServeEngine {
        LutGemvServeEngine::random(
            7,
            64,               // vocab
            32,               // hidden
            QuantLevel::Q4,
            16,               // group
            4,                // nbw
            batch,
            64,               // max context
            WorkerPool::shared(threads),
        )
    }

    #[test]
    fn lut_serve_engine_token_streams_identical_across_thread_counts() {
        // The tiled backend is bit-exact at every pool width, so the decode
        // trajectory must be too.
        let mut streams = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut e = lut_engine(2, threads);
            let mut toks = vec![3, 11];
            let mut got = Vec::new();
            for pos in 0..12 {
                toks = e.step(&toks, &[pos, pos], &[true, true]).unwrap();
                got.push(toks.clone());
            }
            streams.push(got);
        }
        assert_eq!(streams[0], streams[1], "1 vs 2 threads diverged");
        assert_eq!(streams[0], streams[2], "1 vs 4 threads diverged");
    }

    #[test]
    fn lut_serve_engine_is_context_sensitive_and_resettable() {
        let mut e1 = lut_engine(2, 1);
        let mut e2 = lut_engine(2, 1);
        let a1 = e1.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        let a2 = e2.step(&[3, 4], &[0, 0], &[true, true]).unwrap();
        assert_eq!(a1, a2, "same seed must give the same model");
        // Diverge the histories: reset slot 0 on e2 only, then walk both
        // engines in lockstep. Slot 1 must stay bit-identical; slot 0's
        // trajectory must differ somewhere.
        e2.reset_slot(0).unwrap();
        let mut slot0_diverged = false;
        for pos in 1..8 {
            let b1 = e1.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            let b2 = e2.step(&[5, 5], &[pos, pos], &[true, true]).unwrap();
            assert_eq!(b1[1], b2[1], "slot 1 affected by slot-0 reset at pos {pos}");
            slot0_diverged |= b1[0] != b2[0];
        }
        assert!(slot0_diverged, "reset did not change slot-0 trajectory");
        assert!(e1.gemv_stats.luts_built > 0, "decode did not run the LUT path");
    }

    #[test]
    fn batcher_serves_requests_on_the_lut_gemv_path() {
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let mut b = Batcher::new(lut_engine(3, 2), BatcherConfig::default());
        for id in 0..7u64 {
            b.submit(Request::new(id, vec![1 + id as i32, 2], 4));
        }
        let done = b.run_to_completion().unwrap();
        assert_eq!(done.len(), 7);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            for &t in &r.tokens {
                assert!((0..64).contains(&t), "token {t} outside vocab");
            }
        }
        let engine = b.engine();
        assert!(engine.steps > 0);
        assert!(engine.gemv_stats.lut_reads > 0, "no LUT reads on the serving path");
    }

    #[test]
    fn batched_lut_decode_matches_isolated_decode() {
        // Same isolation invariant the mock pins down, now on the real
        // kernel: co-scheduling must not change any request's tokens.
        use crate::coordinator::batcher::{Batcher, BatcherConfig};
        use crate::coordinator::request::Request;
        let reqs: Vec<Request> =
            (0..4).map(|id| Request::new(id, vec![2 + id as i32], 3)).collect();
        let mut isolated = std::collections::HashMap::new();
        for r in &reqs {
            let mut b = Batcher::new(lut_engine(1, 1), BatcherConfig::default());
            b.submit(r.clone());
            let done = b.run_to_completion().unwrap();
            isolated.insert(done[0].id, done[0].tokens.clone());
        }
        let mut b = Batcher::new(lut_engine(2, 2), BatcherConfig::default());
        for r in &reqs {
            b.submit(r.clone());
        }
        for resp in b.run_to_completion().unwrap() {
            assert_eq!(&resp.tokens, &isolated[&resp.id], "request {} diverged", resp.id);
        }
    }
}
