//! Deterministic arrival-driven workload synthesis for the serving bench.
//!
//! The serving front-end's load tests need *schedules*, not just request
//! batches: each request carries an arrival offset from t=0, drawn from a
//! seeded arrival process. Everything here is a pure function of the
//! [`WorkloadSpec`] — two calls with the same spec produce byte-identical
//! schedules on any host — which is what lets the bench's bit-exactness
//! assert compare online streams against an offline oracle: the *same*
//! request set replays through both.
//!
//! Supported mixes (the serving-paper workload axes):
//! - **Poisson** open-loop arrivals at a target rate, or **bursty**
//!   arrivals (same long-run rate, delivered in back-to-back clumps — the
//!   queueing-pressure worst case at equal load);
//! - mixed prompt/output length distributions (uniform ranges);
//! - **session reuse**: with probability `session_reuse` a request
//!   continues a previous session — its prompt is the session's prior
//!   prompt ⊕ that request's *answer-length placeholder* ⊕ a fresh turn,
//!   truncated to `max_prompt` from the front like a chat window. Reused
//!   sessions give the multi-turn prompt-length distribution real serving
//!   traces have (long shared prefixes, growing contexts).

use std::time::Duration;

use super::request::Request;
use super::serving::{ServingFrontend, StreamHandle};
use crate::util::Prng;

/// The inter-arrival process of a workload.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Independent exponential gaps at `rate_per_sec` (open-loop Poisson).
    Poisson { rate_per_sec: f64 },
    /// Same long-run rate, but arrivals land in back-to-back bursts of
    /// `burst_size`: one exponential gap (at `rate_per_sec / burst_size`)
    /// before each burst, zero gap inside it.
    Bursty { rate_per_sec: f64, burst_size: usize },
}

/// A seeded workload description; [`generate`] is a pure function of it.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub seed: u64,
    pub vocab: usize,
    /// Uniform prompt length range `[lo, hi]`, inclusive.
    pub prompt_len: (usize, usize),
    /// Uniform generation budget range `[lo, hi]`, inclusive.
    pub max_new: (usize, usize),
    pub arrivals: ArrivalProcess,
    /// Probability in `[0, 1]` that a request continues an existing
    /// session instead of opening a new one.
    pub session_reuse: f64,
    /// Chat-window cap: session prompts are truncated to this many
    /// trailing tokens. Also the hard cap on fresh prompts, so a spec
    /// tuned to an engine's `max_context` never emits `ContextFull` bait.
    pub max_prompt: usize,
}

impl WorkloadSpec {
    /// A small default mix compatible with the test engines (vocab 97,
    /// max_context 64).
    pub fn small(seed: u64, arrivals: ArrivalProcess) -> Self {
        WorkloadSpec {
            seed,
            vocab: 97,
            prompt_len: (2, 10),
            max_new: (4, 12),
            arrivals,
            session_reuse: 0.3,
            max_prompt: 24,
        }
    }
}

/// One scheduled arrival: submit `req` at `at` (offset from replay start).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at: Duration,
    pub req: Request,
}

/// Generate a deterministic `n`-request schedule from `spec`. Request ids
/// are `0..n` in arrival order; arrival offsets are non-decreasing.
pub fn generate(spec: &WorkloadSpec, n: usize) -> Vec<TimedRequest> {
    assert!(spec.prompt_len.0 >= 1, "prompts must be non-empty");
    assert!(spec.prompt_len.1 >= spec.prompt_len.0 && spec.max_new.1 >= spec.max_new.0);
    assert!(spec.max_prompt >= spec.prompt_len.1, "max_prompt below the fresh-prompt range");
    assert!((0.0..=1.0).contains(&spec.session_reuse));
    let mut prng = Prng::new(spec.seed);
    let mut sessions: Vec<Vec<i32>> = Vec::new();
    let mut t = Duration::ZERO;
    let mut out = Vec::with_capacity(n);
    for id in 0..n as u64 {
        // Arrival gap first, so the schedule shape is independent of the
        // per-request content draws below.
        let gap = match spec.arrivals {
            ArrivalProcess::Poisson { rate_per_sec } => prng.exp(rate_per_sec),
            ArrivalProcess::Bursty { rate_per_sec, burst_size } => {
                let b = burst_size.max(1);
                if id as usize % b == 0 {
                    prng.exp(rate_per_sec / b as f64)
                } else {
                    0.0
                }
            }
        };
        t += Duration::from_secs_f64(gap);

        let turn_len = prng.usize_in(spec.prompt_len.0, spec.prompt_len.1 + 1);
        let turn: Vec<i32> =
            (0..turn_len).map(|_| prng.usize_in(1, spec.vocab) as i32).collect();
        let reuse = !sessions.is_empty() && prng.f64() < spec.session_reuse;
        let prompt = if reuse {
            // Continue a session: prior context ⊕ fresh turn, truncated
            // to the window from the front (oldest context falls off).
            let s = prng.usize_in(0, sessions.len());
            let mut p = sessions[s].clone();
            p.extend_from_slice(&turn);
            if p.len() > spec.max_prompt {
                p.drain(..p.len() - spec.max_prompt);
            }
            sessions[s] = p.clone();
            p
        } else {
            sessions.push(turn.clone());
            turn
        };
        let max_new = prng.usize_in(spec.max_new.0, spec.max_new.1 + 1);
        out.push(TimedRequest { at: t, req: Request::new(id, prompt, max_new) });
    }
    out
}

/// Replay a schedule against a serving front-end in (scaled) real time:
/// sleep to each arrival's offset × `time_scale`, submit, collect the
/// stream handles. `time_scale` < 1 compresses the schedule (offered
/// load ÷ time_scale); 0 submits everything back-to-back.
pub fn replay(
    frontend: &ServingFrontend,
    schedule: &[TimedRequest],
    time_scale: f64,
) -> anyhow::Result<Vec<StreamHandle>> {
    let start = std::time::Instant::now();
    let mut handles = Vec::with_capacity(schedule.len());
    for tr in schedule {
        let due = tr.at.mul_f64(time_scale);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        handles.push(frontend.submit(tr.req.clone())?);
    }
    Ok(handles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson_spec(seed: u64) -> WorkloadSpec {
        WorkloadSpec::small(seed, ArrivalProcess::Poisson { rate_per_sec: 100.0 })
    }

    #[test]
    fn schedules_are_deterministic_per_spec() {
        let a = generate(&poisson_spec(7), 50);
        let b = generate(&poisson_spec(7), 50);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
        // A different seed gives a different schedule.
        let c = generate(&poisson_spec(8), 50);
        assert!(a.iter().zip(&c).any(|(x, y)| x.req.prompt != y.req.prompt || x.at != y.at));
    }

    #[test]
    fn requests_are_in_range_and_arrivals_monotone() {
        let spec = poisson_spec(11);
        let sched = generate(&spec, 200);
        let mut prev = Duration::ZERO;
        let mut saw_reuse_length = false;
        for (i, tr) in sched.iter().enumerate() {
            assert_eq!(tr.req.id, i as u64);
            assert!(tr.at >= prev, "arrival offsets must be non-decreasing");
            prev = tr.at;
            let plen = tr.req.prompt.len();
            assert!(plen >= spec.prompt_len.0 && plen <= spec.max_prompt, "plen {plen}");
            saw_reuse_length |= plen > spec.prompt_len.1;
            assert!(tr.req.prompt.iter().all(|&t| t >= 1 && (t as usize) < spec.vocab));
            assert!(
                tr.req.max_new_tokens >= spec.max_new.0
                    && tr.req.max_new_tokens <= spec.max_new.1
            );
        }
        // With 30% session reuse over 200 requests, multi-turn prompts
        // longer than a single fresh turn must appear.
        assert!(saw_reuse_length, "session reuse never grew a prompt");
    }

    #[test]
    fn bursty_arrivals_share_timestamps_within_a_burst() {
        let spec = WorkloadSpec::small(
            3,
            ArrivalProcess::Bursty { rate_per_sec: 100.0, burst_size: 4 },
        );
        let sched = generate(&spec, 40);
        for chunk in sched.chunks(4) {
            // Zero gap inside the burst: all 4 share the leader's offset.
            assert!(chunk.iter().all(|tr| tr.at == chunk[0].at), "burst not back-to-back");
        }
        // Bursts themselves are separated (exponential gaps at rate/4
        // essentially never draw an exact zero).
        let leaders: Vec<Duration> = sched.iter().step_by(4).map(|tr| tr.at).collect();
        assert!(leaders.windows(2).all(|w| w[1] > w[0]), "bursts share a timestamp");
    }

    #[test]
    fn session_reuse_extends_a_prior_prompt_as_prefix() {
        // With reuse certain after the first request, every later prompt
        // must extend some earlier session's context: its head (up to the
        // window truncation) re-appears from an earlier prompt.
        let spec = WorkloadSpec { session_reuse: 1.0, ..poisson_spec(5) };
        let sched = generate(&spec, 12);
        for later in &sched[1..] {
            let p = &later.req.prompt;
            let shares_context = sched.iter().any(|earlier| {
                earlier.req.id != later.req.id
                    && !earlier.req.prompt.is_empty()
                    && p.len() > earlier.req.prompt.len().min(spec.max_prompt - 1)
                    && {
                        // Untruncated case: earlier prompt is a strict prefix.
                        p.starts_with(&earlier.req.prompt)
                            // Truncated case: some suffix of the earlier
                            // prompt is the head of this one.
                            || (1..earlier.req.prompt.len()).any(|cut| {
                                p.starts_with(&earlier.req.prompt[cut..])
                            })
                    }
            });
            assert!(shares_context, "request {} shares no context with any session", later.req.id);
        }
    }

    #[test]
    fn specs_reject_malformed_ranges() {
        let ok = poisson_spec(1);
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { prompt_len: (0, 4), ..ok }, 1)
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { max_prompt: 3, ..ok }, 1)
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { session_reuse: 1.5, ..ok }, 1)
        })
        .is_err());
    }
}
