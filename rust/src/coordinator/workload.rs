//! Deterministic arrival-driven workload synthesis for the serving bench.
//!
//! The serving front-end's load tests need *schedules*, not just request
//! batches: each request carries an arrival offset from t=0, drawn from a
//! seeded arrival process. Everything here is a pure function of the
//! [`WorkloadSpec`] — two calls with the same spec produce byte-identical
//! schedules on any host — which is what lets the bench's bit-exactness
//! assert compare online streams against an offline oracle: the *same*
//! request set replays through both.
//!
//! Supported mixes (the serving-paper workload axes):
//! - **Poisson** open-loop arrivals at a target rate, or **bursty**
//!   arrivals (same long-run rate, delivered in back-to-back clumps — the
//!   queueing-pressure worst case at equal load);
//! - mixed prompt/output length distributions (uniform ranges);
//! - **session reuse**: with probability `session_reuse` a request
//!   continues a previous session — its prompt is the session's prior
//!   prompt ⊕ that request's *answer-length placeholder* ⊕ a fresh turn,
//!   truncated to `max_prompt` from the front like a chat window. Reused
//!   sessions give the multi-turn prompt-length distribution real serving
//!   traces have (long shared prefixes, growing contexts);
//! - **shared system prompts** ([`SharedPromptMix`]): fresh requests
//!   prepend one of `heads` fixed prompt heads, chosen by a Zipf draw —
//!   the many-users-few-system-prompts shape that prefix caching exists
//!   for. Head popularity follows `1/k^s`, so a paged KV store with a
//!   radix prefix cache sees a hit rate that rises with the skew.

use std::time::Duration;

use super::request::Request;
use super::serving::{ServingFrontend, StreamHandle};
use crate::util::Prng;

/// The inter-arrival process of a workload.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Independent exponential gaps at `rate_per_sec` (open-loop Poisson).
    Poisson { rate_per_sec: f64 },
    /// Same long-run rate, but arrivals land in back-to-back bursts of
    /// `burst_size`: one exponential gap (at `rate_per_sec / burst_size`)
    /// before each burst, zero gap inside it.
    Bursty { rate_per_sec: f64, burst_size: usize },
}

/// Shared-system-prompt mix: `heads` distinct fixed prompt heads of
/// `head_len` tokens each; every *fresh* request (not a session
/// continuation) prepends one, chosen by a Zipf(`zipf_s`) popularity draw
/// (head `k`'s probability ∝ `1/(k+1)^s`). The resulting schedule has the
/// long-shared-prefix structure real multi-tenant serving sees — N system
/// prompts reused across many users — which is the workload a radix
/// prefix cache converts from repeated prefill into page sharing.
#[derive(Debug, Clone, Copy)]
pub struct SharedPromptMix {
    /// Number of distinct prompt heads (≥ 1).
    pub heads: usize,
    /// Tokens per head (≥ 1).
    pub head_len: usize,
    /// Zipf skew `s` (> 0): larger ⇒ the top head dominates harder.
    pub zipf_s: f64,
}

/// A seeded workload description; [`generate`] is a pure function of it.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub seed: u64,
    pub vocab: usize,
    /// Uniform prompt length range `[lo, hi]`, inclusive.
    pub prompt_len: (usize, usize),
    /// Uniform generation budget range `[lo, hi]`, inclusive.
    pub max_new: (usize, usize),
    pub arrivals: ArrivalProcess,
    /// Probability in `[0, 1]` that a request continues an existing
    /// session instead of opening a new one.
    pub session_reuse: f64,
    /// Chat-window cap: session prompts are truncated to this many
    /// trailing tokens. Also the hard cap on fresh prompts, so a spec
    /// tuned to an engine's `max_context` never emits `ContextFull` bait.
    pub max_prompt: usize,
    /// Optional shared-system-prompt structure on fresh requests.
    pub shared_prompts: Option<SharedPromptMix>,
}

impl WorkloadSpec {
    /// A small default mix compatible with the test engines (vocab 97,
    /// max_context 64).
    pub fn small(seed: u64, arrivals: ArrivalProcess) -> Self {
        WorkloadSpec {
            seed,
            vocab: 97,
            prompt_len: (2, 10),
            max_new: (4, 12),
            arrivals,
            session_reuse: 0.3,
            max_prompt: 24,
            shared_prompts: None,
        }
    }
}

/// Inverse-CDF Zipf draw: head `k` (0-based) with probability
/// `(k+1)^-s / Σ_{j=1..n} j^-s`. Pure in `(u, n, s)`, so the schedule
/// stays a deterministic function of the spec.
fn zipf_index(u: f64, n: usize, s: f64) -> usize {
    let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
    let mut acc = 0.0;
    for k in 1..=n {
        acc += (k as f64).powf(-s) / total;
        if u < acc {
            return k - 1;
        }
    }
    n - 1
}

/// One scheduled arrival: submit `req` at `at` (offset from replay start).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at: Duration,
    pub req: Request,
}

/// Generate a deterministic `n`-request schedule from `spec`. Request ids
/// are `0..n` in arrival order; arrival offsets are non-decreasing.
pub fn generate(spec: &WorkloadSpec, n: usize) -> Vec<TimedRequest> {
    assert!(spec.prompt_len.0 >= 1, "prompts must be non-empty");
    assert!(spec.prompt_len.1 >= spec.prompt_len.0 && spec.max_new.1 >= spec.max_new.0);
    assert!(spec.max_prompt >= spec.prompt_len.1, "max_prompt below the fresh-prompt range");
    assert!((0.0..=1.0).contains(&spec.session_reuse));
    if let Some(mix) = spec.shared_prompts {
        assert!(mix.heads >= 1 && mix.head_len >= 1, "shared-prompt mix needs ≥1 head of ≥1 token");
        assert!(mix.zipf_s > 0.0, "Zipf skew must be positive");
        assert!(
            spec.max_prompt >= mix.head_len + spec.prompt_len.1,
            "max_prompt below head_len + the fresh-turn maximum"
        );
    }
    // Head token tables come from a seed-derived side stream so adding or
    // removing the mix perturbs only what it must: arrival gaps and turn
    // content draw from the main stream exactly as without it.
    let heads: Vec<Vec<i32>> = match spec.shared_prompts {
        Some(mix) => {
            let mut hp = Prng::new(spec.seed ^ 0x5a5a_a5a5_c0ff_ee00);
            (0..mix.heads)
                .map(|_| (0..mix.head_len).map(|_| hp.usize_in(1, spec.vocab) as i32).collect())
                .collect()
        }
        None => Vec::new(),
    };
    let mut prng = Prng::new(spec.seed);
    let mut sessions: Vec<Vec<i32>> = Vec::new();
    let mut t = Duration::ZERO;
    let mut out = Vec::with_capacity(n);
    for id in 0..n as u64 {
        // Arrival gap first, so the schedule shape is independent of the
        // per-request content draws below.
        let gap = match spec.arrivals {
            ArrivalProcess::Poisson { rate_per_sec } => prng.exp(rate_per_sec),
            ArrivalProcess::Bursty { rate_per_sec, burst_size } => {
                let b = burst_size.max(1);
                if id as usize % b == 0 {
                    prng.exp(rate_per_sec / b as f64)
                } else {
                    0.0
                }
            }
        };
        t += Duration::from_secs_f64(gap);

        let turn_len = prng.usize_in(spec.prompt_len.0, spec.prompt_len.1 + 1);
        let turn: Vec<i32> =
            (0..turn_len).map(|_| prng.usize_in(1, spec.vocab) as i32).collect();
        let reuse = !sessions.is_empty() && prng.f64() < spec.session_reuse;
        let prompt = if reuse {
            // Continue a session: prior context ⊕ fresh turn, truncated
            // to the window from the front (oldest context falls off).
            let s = prng.usize_in(0, sessions.len());
            let mut p = sessions[s].clone();
            p.extend_from_slice(&turn);
            if p.len() > spec.max_prompt {
                p.drain(..p.len() - spec.max_prompt);
            }
            sessions[s] = p.clone();
            p
        } else {
            // Fresh request: under a shared-prompt mix, prepend a
            // Zipf-chosen head (the validation above guarantees the
            // result fits `max_prompt`).
            let p = match spec.shared_prompts {
                Some(mix) => {
                    let mut p = heads[zipf_index(prng.f64(), mix.heads, mix.zipf_s)].clone();
                    p.extend_from_slice(&turn);
                    p
                }
                None => turn,
            };
            sessions.push(p.clone());
            p
        };
        let max_new = prng.usize_in(spec.max_new.0, spec.max_new.1 + 1);
        out.push(TimedRequest { at: t, req: Request::new(id, prompt, max_new) });
    }
    out
}

/// Replay a schedule against a serving front-end in (scaled) real time:
/// sleep to each arrival's offset × `time_scale`, submit, collect the
/// stream handles. `time_scale` < 1 compresses the schedule (offered
/// load ÷ time_scale); 0 submits everything back-to-back.
pub fn replay(
    frontend: &ServingFrontend,
    schedule: &[TimedRequest],
    time_scale: f64,
) -> anyhow::Result<Vec<StreamHandle>> {
    let start = std::time::Instant::now();
    let mut handles = Vec::with_capacity(schedule.len());
    for tr in schedule {
        let due = tr.at.mul_f64(time_scale);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        handles.push(frontend.submit(tr.req.clone())?);
    }
    Ok(handles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson_spec(seed: u64) -> WorkloadSpec {
        WorkloadSpec::small(seed, ArrivalProcess::Poisson { rate_per_sec: 100.0 })
    }

    #[test]
    fn schedules_are_deterministic_per_spec() {
        let a = generate(&poisson_spec(7), 50);
        let b = generate(&poisson_spec(7), 50);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
        // A different seed gives a different schedule.
        let c = generate(&poisson_spec(8), 50);
        assert!(a.iter().zip(&c).any(|(x, y)| x.req.prompt != y.req.prompt || x.at != y.at));
    }

    #[test]
    fn requests_are_in_range_and_arrivals_monotone() {
        let spec = poisson_spec(11);
        let sched = generate(&spec, 200);
        let mut prev = Duration::ZERO;
        let mut saw_reuse_length = false;
        for (i, tr) in sched.iter().enumerate() {
            assert_eq!(tr.req.id, i as u64);
            assert!(tr.at >= prev, "arrival offsets must be non-decreasing");
            prev = tr.at;
            let plen = tr.req.prompt.len();
            assert!(plen >= spec.prompt_len.0 && plen <= spec.max_prompt, "plen {plen}");
            saw_reuse_length |= plen > spec.prompt_len.1;
            assert!(tr.req.prompt.iter().all(|&t| t >= 1 && (t as usize) < spec.vocab));
            assert!(
                tr.req.max_new_tokens >= spec.max_new.0
                    && tr.req.max_new_tokens <= spec.max_new.1
            );
        }
        // With 30% session reuse over 200 requests, multi-turn prompts
        // longer than a single fresh turn must appear.
        assert!(saw_reuse_length, "session reuse never grew a prompt");
    }

    #[test]
    fn bursty_arrivals_share_timestamps_within_a_burst() {
        let spec = WorkloadSpec::small(
            3,
            ArrivalProcess::Bursty { rate_per_sec: 100.0, burst_size: 4 },
        );
        let sched = generate(&spec, 40);
        for chunk in sched.chunks(4) {
            // Zero gap inside the burst: all 4 share the leader's offset.
            assert!(chunk.iter().all(|tr| tr.at == chunk[0].at), "burst not back-to-back");
        }
        // Bursts themselves are separated (exponential gaps at rate/4
        // essentially never draw an exact zero).
        let leaders: Vec<Duration> = sched.iter().step_by(4).map(|tr| tr.at).collect();
        assert!(leaders.windows(2).all(|w| w[1] > w[0]), "bursts share a timestamp");
    }

    #[test]
    fn session_reuse_extends_a_prior_prompt_as_prefix() {
        // With reuse certain after the first request, every later prompt
        // must extend some earlier session's context: its head (up to the
        // window truncation) re-appears from an earlier prompt.
        let spec = WorkloadSpec { session_reuse: 1.0, ..poisson_spec(5) };
        let sched = generate(&spec, 12);
        for later in &sched[1..] {
            let p = &later.req.prompt;
            let shares_context = sched.iter().any(|earlier| {
                earlier.req.id != later.req.id
                    && !earlier.req.prompt.is_empty()
                    && p.len() > earlier.req.prompt.len().min(spec.max_prompt - 1)
                    && {
                        // Untruncated case: earlier prompt is a strict prefix.
                        p.starts_with(&earlier.req.prompt)
                            // Truncated case: some suffix of the earlier
                            // prompt is the head of this one.
                            || (1..earlier.req.prompt.len()).any(|cut| {
                                p.starts_with(&earlier.req.prompt[cut..])
                            })
                    }
            });
            assert!(shares_context, "request {} shares no context with any session", later.req.id);
        }
    }

    #[test]
    fn shared_prompt_mix_prepends_zipf_heads() {
        let mix = SharedPromptMix { heads: 3, head_len: 6, zipf_s: 1.2 };
        let spec = WorkloadSpec {
            session_reuse: 0.0,
            max_prompt: 24,
            shared_prompts: Some(mix),
            ..poisson_spec(21)
        };
        let sched = generate(&spec, 120);
        let again = generate(&spec, 120);
        // Determinism first: the mix is still a pure function of the spec.
        for (x, y) in sched.iter().zip(&again) {
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.at, y.at);
        }
        // Recover the head tables the generator used and classify every
        // prompt: all fresh (reuse 0.0), so each starts with some head.
        let mut hp = crate::util::Prng::new(spec.seed ^ 0x5a5a_a5a5_c0ff_ee00);
        let heads: Vec<Vec<i32>> = (0..mix.heads)
            .map(|_| (0..mix.head_len).map(|_| hp.usize_in(1, spec.vocab) as i32).collect())
            .collect();
        let mut counts = vec![0usize; mix.heads];
        for tr in &sched {
            let h = heads
                .iter()
                .position(|h| tr.req.prompt.starts_with(h))
                .expect("prompt starts with no known head");
            counts[h] += 1;
            assert!(tr.req.prompt.len() > mix.head_len, "head with no fresh turn");
            assert!(tr.req.prompt.len() <= spec.max_prompt);
        }
        // Zipf skew: the most popular head strictly dominates the least
        // popular, and every head appears (120 draws, 3 heads).
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(counts[0] > counts[2], "no Zipf skew: {counts:?}");
    }

    #[test]
    fn shared_prompt_mix_sessions_keep_their_head() {
        // Session continuations extend a head-carrying prompt, so the
        // shared head survives as the prefix until window truncation.
        let mix = SharedPromptMix { heads: 2, head_len: 4, zipf_s: 1.0 };
        let spec = WorkloadSpec {
            session_reuse: 0.5,
            max_prompt: 64,
            shared_prompts: Some(mix),
            ..poisson_spec(9)
        };
        let sched = generate(&spec, 60);
        let mut hp = crate::util::Prng::new(spec.seed ^ 0x5a5a_a5a5_c0ff_ee00);
        let heads: Vec<Vec<i32>> = (0..mix.heads)
            .map(|_| (0..mix.head_len).map(|_| hp.usize_in(1, spec.vocab) as i32).collect())
            .collect();
        for tr in &sched {
            if tr.req.prompt.len() <= spec.max_prompt - spec.prompt_len.1 {
                // Untruncated prompts must still open with a head.
                assert!(
                    heads.iter().any(|h| tr.req.prompt.starts_with(h)),
                    "request {} lost its shared head",
                    tr.req.id
                );
            }
        }
    }

    #[test]
    fn zipf_draw_is_a_valid_skewed_distribution() {
        // Inverse CDF sanity: u spanning [0,1) covers every index, in
        // order, and the first index owns the largest probability mass.
        let n = 5;
        let got: Vec<usize> =
            (0..1000).map(|i| zipf_index(i as f64 / 1000.0, n, 1.1)).collect();
        assert_eq!(got[0], 0);
        assert_eq!(*got.last().unwrap(), n - 1);
        assert!(got.windows(2).all(|w| w[0] <= w[1]), "inverse CDF must be monotone");
        let c0 = got.iter().filter(|&&k| k == 0).count();
        let c4 = got.iter().filter(|&&k| k == 4).count();
        assert!(c0 > c4, "head 0 ({c0}) must outweigh head 4 ({c4})");
    }

    #[test]
    fn specs_reject_malformed_ranges() {
        let ok = poisson_spec(1);
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { prompt_len: (0, 4), ..ok }, 1)
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { max_prompt: 3, ..ok }, 1)
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            generate(&WorkloadSpec { session_reuse: 1.5, ..ok }, 1)
        })
        .is_err());
        // Shared-prompt mixes validate too: zero heads, zero skew, and a
        // window too small for head ⊕ fresh turn are all rejected.
        let mix = |heads, head_len, zipf_s| WorkloadSpec {
            shared_prompts: Some(SharedPromptMix { heads, head_len, zipf_s }),
            ..ok
        };
        assert!(std::panic::catch_unwind(|| generate(&mix(0, 4, 1.0), 1)).is_err());
        assert!(std::panic::catch_unwind(|| generate(&mix(2, 4, 0.0), 1)).is_err());
        assert!(std::panic::catch_unwind(|| generate(&mix(2, 40, 1.0), 1)).is_err());
    }
}
