//! Admission scheduling policies for the batcher queue.
//!
//! The paper's serving scenario is FIFO iteration-based batching; real
//! deployments also use shortest-job-first (by generation budget) to cut
//! mean latency. SJF is implemented with aging so long requests cannot
//! starve — the property tests pin both the latency advantage and the
//! no-starvation bound.

use std::collections::VecDeque;

use super::request::Request;

/// Queue discipline for admitting requests into free slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Arrival order (the paper's iteration-based serving).
    Fifo,
    /// Smallest `max_new_tokens` first, with aging: a request's effective
    /// priority improves by one token per `aging_step` iterations waited,
    /// so every request is eventually admitted.
    ShortestJobFirst { aging_step: u64 },
}

/// A policy-aware queue (drop-in for the batcher's VecDeque).
#[derive(Debug)]
pub struct AdmissionQueue {
    policy: AdmissionPolicy,
    /// (request, iteration at enqueue).
    items: VecDeque<(Request, u64)>,
}

impl AdmissionQueue {
    pub fn new(policy: AdmissionPolicy) -> Self {
        AdmissionQueue { policy, items: VecDeque::new() }
    }

    pub fn push(&mut self, req: Request, now_iter: u64) {
        self.items.push_back((req, now_iter));
    }

    /// Enqueue unless the queue already holds `capacity` requests. A full
    /// queue hands the request back so the caller can shed it with a
    /// typed response instead of growing without bound.
    pub fn push_bounded(
        &mut self,
        req: Request,
        now_iter: u64,
        capacity: usize,
    ) -> Result<(), Request> {
        if self.items.len() >= capacity {
            return Err(req);
        }
        self.items.push_back((req, now_iter));
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Iterate the queued requests (storage order — admission order for
    /// FIFO). The batcher's deadline sweep and the serving scheduler's
    /// TTFT-headroom probe read the queue through this without popping.
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.items.iter().map(|(r, _)| r)
    }

    /// Remove and return every queued request matching `pred`, preserving
    /// the relative order of both the removed requests and the survivors
    /// (with their original enqueue iterations). Allocation-free when
    /// nothing matches — this runs once per batcher iteration.
    pub fn drain_matching<F: FnMut(&Request) -> bool>(&mut self, mut pred: F) -> Vec<Request> {
        if !self.items.iter().any(|(r, _)| pred(r)) {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut kept = VecDeque::with_capacity(self.items.len());
        for (req, enq) in self.items.drain(..) {
            if pred(&req) {
                out.push(req);
            } else {
                kept.push_back((req, enq));
            }
        }
        self.items = kept;
        out
    }

    /// Pop the next request to admit at iteration `now_iter`.
    pub fn pop(&mut self, now_iter: u64) -> Option<Request> {
        if self.items.is_empty() {
            return None;
        }
        let idx = match self.policy {
            AdmissionPolicy::Fifo => 0,
            AdmissionPolicy::ShortestJobFirst { aging_step } => {
                let step = aging_step.max(1);
                self.items
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, (r, enq))| {
                        let waited = now_iter.saturating_sub(*enq) / step;
                        let eff = (r.max_new_tokens as u64).saturating_sub(waited);
                        (eff, *i) // ties broken FIFO
                    })
                    .map(|(i, _)| i)
                    .unwrap()
            }
        };
        self.items.remove(idx).map(|(r, _)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Prng};

    fn req(id: u64, budget: usize) -> Request {
        Request::new(id, vec![1], budget)
    }

    #[test]
    fn fifo_preserves_order() {
        let mut q = AdmissionQueue::new(AdmissionPolicy::Fifo);
        for id in 0..5 {
            q.push(req(id, 10 - id as usize), id);
        }
        for id in 0..5 {
            assert_eq!(q.pop(100).unwrap().id, id);
        }
    }

    #[test]
    fn sjf_picks_shortest() {
        let mut q = AdmissionQueue::new(AdmissionPolicy::ShortestJobFirst { aging_step: 1000 });
        q.push(req(0, 30), 0);
        q.push(req(1, 5), 0);
        q.push(req(2, 10), 0);
        assert_eq!(q.pop(1).unwrap().id, 1);
        assert_eq!(q.pop(2).unwrap().id, 2);
        assert_eq!(q.pop(3).unwrap().id, 0);
    }

    #[test]
    fn sjf_ties_break_fifo() {
        let mut q = AdmissionQueue::new(AdmissionPolicy::ShortestJobFirst { aging_step: 1000 });
        q.push(req(0, 8), 0);
        q.push(req(1, 8), 0);
        assert_eq!(q.pop(1).unwrap().id, 0);
    }

    #[test]
    fn bounded_push_sheds_exactly_above_capacity() {
        let mut q = AdmissionQueue::new(AdmissionPolicy::Fifo);
        assert!(q.push_bounded(req(0, 4), 0, 2).is_ok());
        assert!(q.push_bounded(req(1, 4), 0, 2).is_ok());
        let back = q.push_bounded(req(2, 4), 0, 2).unwrap_err();
        assert_eq!(back.id, 2, "the shed request comes back to the caller");
        assert_eq!(q.len(), 2, "a shed push must not grow the queue");
        // Draining one slot re-opens admission.
        assert_eq!(q.pop(1).unwrap().id, 0);
        assert!(q.push_bounded(back, 1, 2).is_ok());
    }

    #[test]
    fn aging_prevents_starvation() {
        // A 100-token request enqueued at t=0 must win against an endless
        // stream of 1-token requests once it has aged enough.
        let mut q = AdmissionQueue::new(AdmissionPolicy::ShortestJobFirst { aging_step: 1 });
        q.push(req(0, 100), 0);
        // After 100 iterations of waiting its effective budget reaches 0.
        q.push(req(1, 1), 100);
        assert_eq!(q.pop(101).unwrap().id, 0, "aged request must be admitted");
    }

    #[test]
    fn every_request_eventually_pops() {
        propcheck::check(
            "admission-no-starvation",
            propcheck::Config { cases: 50, seed: 31 },
            |p, _| {
                let n = p.usize_in(1, 30);
                let budgets: Vec<usize> = (0..n).map(|_| p.usize_in(1, 64)).collect();
                let aging = p.usize_in(1, 10) as u64;
                (budgets, aging)
            },
            |(budgets, aging)| {
                let mut q =
                    AdmissionQueue::new(AdmissionPolicy::ShortestJobFirst { aging_step: *aging });
                for (id, &b) in budgets.iter().enumerate() {
                    q.push(req(id as u64, b), id as u64);
                }
                let mut seen = std::collections::HashSet::new();
                let mut now = budgets.len() as u64;
                while !q.is_empty() {
                    now += 1;
                    let r = q.pop(now).ok_or("pop on non-empty queue failed")?;
                    if !seen.insert(r.id) {
                        return Err(format!("request {} popped twice", r.id));
                    }
                }
                if seen.len() != budgets.len() {
                    return Err("lost requests".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sjf_improves_mean_wait_over_fifo() {
        // Classic scheduling result, checked end-to-end on the queue: for
        // a burst of mixed budgets, SJF's mean (budget-weighted) wait is
        // no worse than FIFO's.
        let mut prng = Prng::new(7);
        let budgets: Vec<usize> = (0..20).map(|_| prng.usize_in(1, 50)).collect();
        let order = |policy| {
            let mut q = AdmissionQueue::new(policy);
            for (id, &b) in budgets.iter().enumerate() {
                q.push(req(id as u64, b), 0);
            }
            let mut wait = 0u64;
            let mut clock = 0u64;
            while let Some(r) = q.pop(clock) {
                wait += clock;
                clock += r.max_new_tokens as u64; // service time ∝ budget
            }
            wait
        };
        let fifo = order(AdmissionPolicy::Fifo);
        let sjf = order(AdmissionPolicy::ShortestJobFirst { aging_step: 1_000_000 });
        assert!(sjf <= fifo, "SJF total wait {sjf} vs FIFO {fifo}");
    }
}
