//! Published measurement matrices from the paper, used as calibration /
//! residual targets (never as model inputs — see the calibration notes
//! in `crate::baselines`;
//! the one exception is the per-weight CPU constants in
//! `baselines::calib`, which are fitted from the single-thread columns
//! below and cross-validated against the rest).

/// One (model, quant) block of the paper's Table II: ARM / AMX / SAIL
/// tokens/s at 1, 2, 4, 8, 16 threads.
pub struct Table2Block {
    pub model: &'static str,
    pub level: &'static str,
    /// rows[0] = ARM, rows[1] = AMX, rows[2] = SAIL; columns = threads
    /// 1, 2, 4, 8, 16.
    pub rows: [[f64; 5]; 3],
}

/// The full published Table II.
pub const TABLE2: [Table2Block; 12] = [
    Table2Block {
        model: "7B",
        level: "Q2",
        rows: [
            [0.68, 1.34, 2.63, 4.97, 9.30],
            [2.06, 4.02, 7.65, 14.25, 24.96],
            [6.42, 12.62, 24.00, 43.50, 81.63],
        ],
    },
    Table2Block {
        model: "7B",
        level: "Q3",
        rows: [
            [0.70, 1.38, 2.71, 5.11, 9.62],
            [2.02, 3.93, 7.47, 13.69, 24.50],
            [5.53, 10.93, 20.87, 38.40, 73.75],
        ],
    },
    Table2Block {
        model: "7B",
        level: "Q4",
        rows: [
            [0.70, 1.37, 2.67, 5.15, 9.85],
            [3.45, 6.72, 11.51, 21.13, 33.55],
            [4.82, 9.61, 18.67, 35.17, 72.10],
        ],
    },
    Table2Block {
        model: "7B",
        level: "Q5",
        rows: [
            [0.60, 1.17, 2.32, 4.48, 8.49],
            [1.30, 2.56, 4.84, 9.17, 16.48],
            [3.98, 7.96, 15.52, 29.62, 61.84],
        ],
    },
    Table2Block {
        model: "7B",
        level: "Q6",
        rows: [
            [0.79, 1.20, 2.36, 4.52, 8.31],
            [1.20, 2.33, 4.47, 8.10, 14.62],
            [3.34, 6.67, 12.97, 24.60, 50.63],
        ],
    },
    Table2Block {
        model: "7B",
        level: "Q8",
        rows: [
            [0.66, 1.28, 2.51, 4.69, 5.54],
            [2.30, 4.51, 7.50, 13.55, 18.39],
            [2.60, 5.22, 10.28, 19.86, 43.27],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q2",
        rows: [
            [0.35, 0.70, 1.38, 2.68, 5.05],
            [1.06, 2.06, 3.91, 7.28, 12.75],
            [3.77, 7.44, 14.34, 26.63, 52.55],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q3",
        rows: [
            [0.35, 0.69, 1.36, 2.63, 5.01],
            [1.02, 2.01, 3.82, 7.00, 12.62],
            [3.67, 7.33, 13.84, 25.70, 51.10],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q4",
        rows: [
            [0.36, 0.72, 1.41, 2.75, 5.27],
            [1.82, 3.53, 5.79, 10.95, 17.42],
            [2.81, 5.62, 11.00, 21.06, 45.07],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q5",
        rows: [
            [0.31, 0.61, 1.20, 2.34, 4.44],
            [0.67, 1.32, 2.52, 4.78, 8.56],
            [2.32, 4.64, 9.10, 17.60, 38.24],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q6",
        rows: [
            [0.32, 0.62, 1.23, 2.40, 4.52],
            [0.62, 1.18, 2.17, 4.14, 7.25],
            [1.94, 3.88, 7.60, 14.61, 31.32],
        ],
    },
    Table2Block {
        model: "13B",
        level: "Q8",
        rows: [
            [0.34, 0.68, 1.29, 2.46, 4.80],
            [1.15, 2.20, 3.89, 7.19, 10.07],
            [1.51, 3.03, 5.98, 10.75, 26.25],
        ],
    },
];

/// Table III highlights: SAIL-16T-8B reported rows.
pub const TABLE3_SAIL: [(&str, &str, f64); 3] = [
    ("7B", "Q4", 134.22),
    ("7B", "Q8", 113.84),
    ("13B", "Q4", 73.93),
];

/// Headline claims (§I / abstract).
pub const HEADLINE_SPEEDUP_MAX: f64 = 10.7;
pub const HEADLINE_TPD_VS_CPU: f64 = 19.9;
pub const HEADLINE_TPD_VS_V100: f64 = 7.04;
pub const PRT_CYCLE_REDUCTION: f64 = 0.138;
pub const PATTERN_REPEAT_RATE: f64 = 0.17;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matrix_is_complete_and_monotone_in_threads() {
        assert_eq!(TABLE2.len(), 12);
        for b in &TABLE2 {
            for sys in &b.rows {
                for w in sys.windows(2) {
                    assert!(w[1] > w[0], "{}-{} not monotone: {sys:?}", b.model, b.level);
                }
            }
            // SAIL beats ARM everywhere in the published data.
            for t in 0..5 {
                assert!(b.rows[2][t] > b.rows[0][t]);
            }
        }
    }
}
