//! Paper table/figure regenerators.
//!
//! One function per evaluation artifact (Figs 1, 6, 9–13; Tables II–V),
//! shared by the `cargo bench` targets and `examples/paper_tables.rs`.
//! Where the paper published absolute numbers (Table II), the published
//! matrix is embedded as `PAPER_TABLE2_*` and residuals are reported —
//! the calibration contract is "who wins, by roughly what factor", see
//! EXPERIMENTS.md.

pub mod paper_data;

use crate::baselines::{CpuModel, GpuModel};
use crate::cost::{tokens_per_dollar, Platform};
use crate::lutgemv::bitserial::{lut_vs_bitserial_gain, BitSerialModel};
use crate::lutgemv::GemvCycleModel;
use crate::model::ModelConfig;
use crate::quant::QuantLevel;
use crate::sim::SailPerfModel;
use crate::util::table::{commas, f, Table};

const BATCHES: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// Fig 1: LUT vs bit-serial efficiency gain across batch sizes for
/// 2/3/4-bit quantization.
pub fn fig1_lut_vs_bitserial() -> Table {
    let mut t = Table::new(
        "Fig 1 — LUT-based over bit-serial efficiency gain (same C-SRAM substrate)",
        &["batch", "2-bit", "3-bit", "4-bit"],
    );
    for &b in &BATCHES {
        t.row(&[
            b.to_string(),
            f(lut_vs_bitserial_gain(QuantLevel::Q2, 4, b), 2),
            f(lut_vs_bitserial_gain(QuantLevel::Q3, 4, b), 2),
            f(lut_vs_bitserial_gain(QuantLevel::Q4, 4, b), 2),
        ]);
    }
    t
}

/// Fig 6: cycle counts across batch × NBW × precision.
pub fn fig6_design_space() -> Vec<Table> {
    let mut out = Vec::new();
    for level in [QuantLevel::Q2, QuantLevel::Q3, QuantLevel::Q4, QuantLevel::Q6, QuantLevel::Q8] {
        let mut t = Table::new(
            &format!("Fig 6 — tile cycles per batch item, {level} (1024×1024 GEMV)"),
            &["NBW", "b=1", "b=2", "b=4", "b=8", "b=16", "b=32"],
        );
        for nbw in 1..=4u32 {
            let m = GemvCycleModel::prototype(level, nbw);
            let mut row = vec![format!("NBW={nbw}")];
            for &b in &BATCHES {
                row.push(commas(m.cycles_per_item(1024, 1024, b) as u64));
            }
            t.row(&row);
        }
        out.push(t);
    }
    out
}

/// Fig 9: SAIL speedup over the ARM baseline per quantization level.
pub fn fig9_quant_speedup() -> Table {
    let mut t = Table::new(
        "Fig 9 — SAIL speedup over ARM (16 threads, batch 1)",
        &["quant", "7B SAIL t/s", "7B ARM t/s", "7B speedup", "13B speedup"],
    );
    let m7 = ModelConfig::llama2_7b();
    let m13 = ModelConfig::llama2_13b();
    let arm = CpuModel::arm_n1();
    for level in QuantLevel::ALL {
        let s7 = SailPerfModel::paper_config(level, 16).tokens_per_sec(&m7, 1);
        let a7 = arm.tokens_per_sec(&m7, level, 16, 1);
        let s13 = SailPerfModel::paper_config(level, 16).tokens_per_sec(&m13, 1);
        let a13 = arm.tokens_per_sec(&m13, level, 16, 1);
        t.row(&[
            level.to_string(),
            f(s7, 2),
            f(a7, 2),
            format!("{:.2}x", s7 / a7),
            format!("{:.2}x", s13 / a13),
        ]);
    }
    t
}

/// Fig 10: token generation speed per platform × batch (7B/13B, Q4/Q8).
pub fn fig10_batch_platforms() -> Table {
    let mut t = Table::new(
        "Fig 10 — tokens/s vs batch (16 threads; A100 at ctx 512)",
        &["config", "b=1", "b=2", "b=4", "b=8"],
    );
    let arm = CpuModel::arm_n1();
    let amx = CpuModel::amx();
    let a100 = GpuModel::a100_80g();
    for (m, level) in [
        (ModelConfig::llama2_7b(), QuantLevel::Q4),
        (ModelConfig::llama2_7b(), QuantLevel::Q8),
        (ModelConfig::llama2_13b(), QuantLevel::Q4),
        (ModelConfig::llama2_13b(), QuantLevel::Q8),
    ] {
        let tag = |p: &str| format!("{} {level} {p}", short(&m));
        let sail = SailPerfModel::paper_config(level, 16);
        let row4 = |g: &dyn Fn(usize) -> f64| -> Vec<String> {
            [1usize, 2, 4, 8].iter().map(|&b| f(g(b), 1)).collect()
        };
        let mut push = |name: String, vals: Vec<String>| {
            let mut row = vec![name];
            row.extend(vals);
            t.row(&row);
        };
        push(tag("ARM"), row4(&|b| arm.tokens_per_sec(&m, level, 16, b)));
        push(tag("AMX"), row4(&|b| amx.tokens_per_sec(&m, level, 16, b)));
        push(tag("A100"), row4(&|b| a100.tokens_per_sec_at(&m, level, 512, b)));
        push(tag("SAIL"), row4(&|b| sail.tokens_per_sec(&m, b)));
    }
    t
}

fn short(m: &ModelConfig) -> String {
    if m.name.contains("7B") {
        "7B".into()
    } else if m.name.contains("13B") {
        "13B".into()
    } else {
        m.name.clone()
    }
}

/// Fig 11: ARM vs Non-AMX vs AMX vs SAIL at Q2/Q4/Q8.
pub fn fig11_latest_cpus() -> Table {
    let mut t = Table::new(
        "Fig 11 — CPU-family comparison (16 threads, batch 1, tokens/s)",
        &["config", "ARM", "Non-AMX", "AMX", "SAIL"],
    );
    for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        for level in [QuantLevel::Q2, QuantLevel::Q4, QuantLevel::Q8] {
            t.row(&[
                format!("{} {level}", short(&m)),
                f(CpuModel::arm_n1().tokens_per_sec(&m, level, 16, 1), 2),
                f(CpuModel::non_amx().tokens_per_sec(&m, level, 16, 1), 2),
                f(CpuModel::amx().tokens_per_sec(&m, level, 16, 1), 2),
                f(SailPerfModel::paper_config(level, 16).tokens_per_sec(&m, 1), 2),
            ]);
        }
    }
    t
}

/// Fig 12: Q4 GEMV kernel latency breakdown — Baseline / NC / LUT / LUT+TC.
///
/// Kernel: one [1,4096]×[4096,4096] Q4 projection, 16 threads. The CPU
/// type-conversion term is the per-group float conversion NC and plain
/// LUT must bounce to the vector engine (§II-B: de-/quantization ≈ half
/// the QLLM work); LUT+TC runs it in-memory (Algorithm 1).
pub fn fig12_breakdown() -> Table {
    let (k, n) = (4096usize, 4096usize);
    let threads = 16u32;
    let clock = 3.0e9;
    let level = QuantLevel::Q4;

    // CPU-side type conversion for per-group sums.
    let conversions = (k * n / 32) as f64;
    let cpu_tc = conversions * 4.0 / (threads as f64 * clock);

    // A cold single kernel: the PIM configurations must also stream the
    // weight tile DRAM→LLC with no ping-pong to hide behind.
    let bytes = (k * n) as f64 * 0.5625;
    let dram = crate::arch::DramConfig::sail_6400();
    let pim_transfer = dram.stream_secs(bytes as u64);

    // Baseline: ARM vector-unit GEMV kernel (compute-bound at Q4; its own
    // memory traffic is folded into the 40 GB/s effective bandwidth).
    let base_compute = (k * n) as f64 * 0.636 / (clock * threads as f64 * 0.85);
    let base_bw = bytes / 40.0e9;
    let baseline = base_compute.max(base_bw);

    // NC: bit-serial in-SRAM compute (16 tiles over 16 thread-pipelines)
    // + CPU type conversion.
    let bs = BitSerialModel::prototype(level);
    let nc_compute = bs.tile_cycles(1024, 1024, 1) as f64 * (16.0 / threads as f64) / clock;

    // LUT: LUT-GEMV compute + CPU type conversion.
    let mut gm = GemvCycleModel::prototype(level, 4);
    gm.use_prt = true;
    gm.in_memory_typeconv = false;
    let lut_compute = gm.tile(1024, 1024, 1).total() as f64 * (16.0 / threads as f64) / clock;

    // LUT+TC: full SAIL — in-memory conversion replaces the CPU term.
    gm.in_memory_typeconv = true;
    let lut_tc = gm.tile(1024, 1024, 1).total() as f64 * (16.0 / threads as f64) / clock;

    let mut t = Table::new(
        "Fig 12 — Q4 GEMV kernel latency breakdown ([1,4096]×[4096,4096], 16T, cold)",
        &["config", "compute ms", "transfer ms", "cpu-typeconv ms", "total ms", "speedup"],
    );
    let mut push = |name: &str, compute: f64, transfer: f64, tc: f64| {
        let total = compute + transfer + tc;
        t.row(&[
            name.into(),
            f(compute * 1e3, 3),
            f(transfer * 1e3, 3),
            f(tc * 1e3, 3),
            f(total * 1e3, 3),
            format!("{:.2}x", baseline / total),
        ]);
    };
    push("Baseline (ARM)", baseline, 0.0, 0.0);
    push("NC (bit-serial)", nc_compute, pim_transfer, cpu_tc);
    push("LUT (SAIL w/o in-mem TC)", lut_compute, pim_transfer, cpu_tc);
    push("LUT+TC (full SAIL)", lut_tc, pim_transfer, 0.0);
    t
}

/// Fig 13 + Table IV: tokens per dollar across platforms.
pub fn fig13_tokens_per_dollar() -> Vec<Table> {
    let mut out = Vec::new();
    for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        for batch in [1usize, 8] {
            let mut t = Table::new(
                &format!("Fig 13 — tokens per dollar, {} (batch {batch})", m.name),
                &["quant", "CPU-5c", "CPU-16c", "1xV100", "SAIL-1T", "SAIL-16T"],
            );
            for level in [QuantLevel::Q8, QuantLevel::Q6, QuantLevel::Q4, QuantLevel::Q3, QuantLevel::Q2] {
                let arm = CpuModel::arm_n1();
                let cpu5 = arm.tokens_per_sec(&m, level, 5, batch);
                let cpu16 = arm.tokens_per_sec(&m, level, 16, batch);
                // GPU runs fp-path quant kernels; below Q4 it gains nothing
                // (use the Q4 bytes as its floor — favours the GPU).
                let gpu_level = if level.bits() < 4 { QuantLevel::Q4 } else { level };
                let gpu = GpuModel::v100()
                    .best_tokens_per_sec(&m, gpu_level, 2048)
                    .map(|(r, _)| r);
                let sail1 = SailPerfModel::paper_config(level, 1).tokens_per_sec(&m, batch);
                let sail16 = SailPerfModel::paper_config(level, 16).tokens_per_sec(&m, batch);
                t.row(&[
                    level.to_string(),
                    f(tokens_per_dollar(cpu5, Platform::cpu_5core()), 0),
                    f(tokens_per_dollar(cpu16, Platform::cpu_16core()), 0),
                    gpu.map(|g| f(tokens_per_dollar(g, Platform::gpu_1xv100()), 0))
                        .unwrap_or_else(|| "X".into()),
                    f(tokens_per_dollar(sail1, Platform::sail_5core()), 0),
                    f(tokens_per_dollar(sail16, Platform::sail_16core()), 0),
                ]);
            }
            out.push(t);
        }
    }
    out
}

/// Table II: CPU throughput across quantization levels and thread counts,
/// with paper residuals.
pub fn table2_cpu_throughput() -> Vec<Table> {
    let threads = [1u32, 2, 4, 8, 16];
    let mut main = Table::new(
        "Table II — tokens/s across quantization and threads (model values)",
        &[
            "config", "ARM 1T", "AMX 1T", "SAIL 1T", "ARM 4T", "AMX 4T", "SAIL 4T", "ARM 16T",
            "AMX 16T", "SAIL 16T",
        ],
    );
    let arm = CpuModel::arm_n1();
    let amx = CpuModel::amx();
    let mut geo: Vec<Vec<f64>> = vec![Vec::new(); 9];
    for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        for level in QuantLevel::ALL {
            let mut row = vec![format!("{}-{level}", short(&m))];
            let mut col = 0;
            for &t in &[1u32, 4, 16] {
                for sys in 0..3 {
                    let v = match sys {
                        0 => arm.tokens_per_sec(&m, level, t, 1),
                        1 => amx.tokens_per_sec(&m, level, t, 1),
                        _ => SailPerfModel::paper_config(level, t).tokens_per_sec(&m, 1),
                    };
                    geo[col].push(v);
                    col += 1;
                    row.push(f(v, 2));
                }
            }
            main.row(&row);
        }
    }
    let mut geo_row = vec!["GEO-MEAN".to_string()];
    for col in &geo {
        geo_row.push(f(crate::util::geomean(col), 2));
    }
    main.row(&geo_row);

    // Residuals vs the published matrix.
    let mut resid = Table::new(
        "Table II residuals — model / paper ratio (1.00 = exact)",
        &["config", "sys", "1T", "2T", "4T", "8T", "16T"],
    );
    for block in paper_data::TABLE2.iter() {
        let m = if block.model == "7B" {
            ModelConfig::llama2_7b()
        } else {
            ModelConfig::llama2_13b()
        };
        let level = QuantLevel::parse(block.level).unwrap();
        for (sys_idx, sys_name) in ["ARM", "AMX", "SAIL"].iter().enumerate() {
            let mut row = vec![format!("{}-{level}", block.model), sys_name.to_string()];
            for (ti, &t) in threads.iter().enumerate() {
                let model_v = match sys_idx {
                    0 => arm.tokens_per_sec(&m, level, t, 1),
                    1 => amx.tokens_per_sec(&m, level, t, 1),
                    _ => SailPerfModel::paper_config(level, t).tokens_per_sec(&m, 1),
                };
                let paper_v = block.rows[sys_idx][ti];
                row.push(f(model_v / paper_v, 2));
            }
            resid.row(&row);
        }
    }
    vec![main, resid]
}

/// Table III: GPU vs SAIL token generation across context lengths.
pub fn table3_gpu_comparison() -> Table {
    let mut t = Table::new(
        "Table III — tokens/s / best-batch vs context length",
        &["platform", "model", "quant", "ctx 512", "ctx 1K", "ctx 2K", "ctx 4K"],
    );
    let ctxs = [512usize, 1024, 2048, 4096];
    let gpus = [GpuModel::v100(), GpuModel::v100x2(), GpuModel::a100_80g()];
    for g in &gpus {
        for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
            for level in [QuantLevel::Q4, QuantLevel::Q8] {
                let mut row = vec![g.name.to_string(), short(&m), level.to_string()];
                for &ctx in &ctxs {
                    row.push(match g.best_tokens_per_sec(&m, level, ctx) {
                        Some((r, b)) => format!("{:.1}/{b}", r),
                        None => "X".into(),
                    });
                }
                t.row(&row);
            }
        }
    }
    // SAIL: context-independent (§V-G).
    for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        for level in [QuantLevel::Q4, QuantLevel::Q8] {
            let r = SailPerfModel::paper_config(level, 16).tokens_per_sec(&m, 8);
            let cell = format!("{:.1}/8", r);
            t.row(&[
                "SAIL-16T".into(),
                short(&m),
                level.to_string(),
                cell.clone(),
                cell.clone(),
                cell.clone(),
                cell,
            ]);
        }
    }
    t
}

/// Table IV: platform cost inputs.
pub fn table4_costs() -> Table {
    let mut t = Table::new("Table IV — GCP monthly cost", &["system", "$/month"]);
    for p in [
        Platform::cpu_5core(),
        Platform::cpu_16core(),
        Platform::gpu_1xv100(),
        Platform::gpu_4xv100(),
        Platform::sail_16core(),
    ] {
        t.row(&[p.name.to_string(), f(p.monthly_usd, 2)]);
    }
    t
}

/// Table V: overhead comparison.
pub fn table5_overhead() -> Table {
    let mut t = Table::new(
        "Table V — overhead comparison",
        &["approach", "HW overhead", "system overhead"],
    );
    for row in crate::cost::overhead::table5_rows() {
        t.row(&[row.approach.into(), row.hw_overhead.into(), row.sys_overhead.into()]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        assert!(fig1_lut_vs_bitserial().render().contains("Fig 1"));
        assert_eq!(fig6_design_space().len(), 5);
        assert!(fig9_quant_speedup().render().contains("speedup"));
        assert!(fig10_batch_platforms().render().contains("SAIL"));
        assert!(fig11_latest_cpus().render().contains("Non-AMX"));
        assert!(fig12_breakdown().render().contains("LUT+TC"));
        assert_eq!(fig13_tokens_per_dollar().len(), 4);
        assert_eq!(table2_cpu_throughput().len(), 2);
        assert!(table3_gpu_comparison().render().contains("X"));
        assert!(table4_costs().render().contains("665.45"));
        assert!(table5_overhead().render().contains("SAIL"));
    }

    #[test]
    fn fig12_final_speedup_near_paper() {
        // Paper: "achieving a final 3.81× speedup over the Baseline".
        let r = fig12_breakdown().render();
        let last = r.lines().last().unwrap();
        let speedup: f64 = last
            .split_whitespace()
            .last()
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!((2.2..=6.0).contains(&speedup), "LUT+TC speedup {speedup}");
    }

    #[test]
    fn fig12_ordering_matches_paper() {
        // Baseline < NC < LUT < LUT+TC in speedup.
        let r = fig12_breakdown().render();
        let speedups: Vec<f64> = r
            .lines()
            .skip(3)
            .map(|l| {
                l.split_whitespace()
                    .last()
                    .unwrap()
                    .trim_end_matches('x')
                    .parse()
                    .unwrap()
            })
            .collect();
        assert_eq!(speedups.len(), 4);
        assert!(speedups.windows(2).all(|w| w[0] < w[1]), "{speedups:?}");
    }

    #[test]
    fn table2_residuals_are_bounded() {
        // Every residual cell must be within [0.4, 2.5]; the bulk within
        // [0.7, 1.4] (see EXPERIMENTS.md for the per-cell discussion).
        let tables = table2_cpu_throughput();
        let resid = tables[1].render();
        let mut cells = Vec::new();
        for line in resid.lines().skip(3) {
            for tok in line.split_whitespace().skip(2) {
                if let Ok(v) = tok.parse::<f64>() {
                    cells.push(v);
                }
            }
        }
        assert!(cells.len() >= 150, "expected full residual matrix, got {}", cells.len());
        for &c in &cells {
            assert!((0.4..=2.5).contains(&c), "residual {c} out of band");
        }
        let close = cells.iter().filter(|&&c| (0.7..=1.4).contains(&c)).count();
        assert!(
            close * 10 >= cells.len() * 6,
            "only {close}/{} residuals within 30%",
            cells.len()
        );
    }
}
