//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `time_fn` warms up, then runs timed iterations until a wall-clock budget
//! or iteration cap is reached and reports ns/iter with stddev. Used by the
//! `perf_hotpath` bench target and by the §Perf iteration log.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of a timed run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    pub stddev_ns: f64,
    /// Optional throughput denominator (items processed per iteration).
    pub items_per_iter: f64,
}

impl BenchResult {
    /// Items per second implied by the measurement.
    pub fn items_per_sec(&self) -> f64 {
        if self.ns_per_iter == 0.0 {
            return 0.0;
        }
        self.items_per_iter * 1e9 / self.ns_per_iter
    }

    pub fn report(&self) -> String {
        if self.items_per_iter > 1.0 {
            format!(
                "{:<44} {:>12.1} ns/iter (±{:>8.1})  {:>14.3e} items/s",
                self.name,
                self.ns_per_iter,
                self.stddev_ns,
                self.items_per_sec()
            )
        } else {
            format!(
                "{:<44} {:>12.1} ns/iter (±{:>8.1})",
                self.name, self.ns_per_iter, self.stddev_ns
            )
        }
    }
}

/// Options controlling a timed run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_samples: u64,
    /// Iterations folded into one timing sample (amortizes clock overhead
    /// for very fast bodies).
    pub batch: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(700),
            max_samples: 10_000,
            batch: 1,
        }
    }
}

/// Prevent the optimizer from eliding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time `f`, returning ns/iter statistics.
pub fn time_fn<T>(name: &str, opts: BenchOpts, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        black_box(f());
    }
    // Timed samples.
    let mut s = Summary::new();
    let start = Instant::now();
    while start.elapsed() < opts.budget && s.count() < opts.max_samples {
        let t0 = Instant::now();
        for _ in 0..opts.batch {
            black_box(f());
        }
        s.push(t0.elapsed().as_nanos() as f64 / opts.batch as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: s.count() * opts.batch,
        ns_per_iter: s.mean(),
        stddev_ns: s.stddev(),
        items_per_iter: 1.0,
    }
}

/// Time `f` where each call processes `items` items (reports items/s too).
pub fn time_throughput<T>(
    name: &str,
    opts: BenchOpts,
    items: f64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = time_fn(name, opts, f);
    r.items_per_iter = items;
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            max_samples: 1000,
            batch: 10,
        };
        let r = time_fn("noop-ish", opts, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters > 0);
        assert!(r.ns_per_iter > 0.0);
        // 100 multiply-adds should take well under 100µs per iteration.
        assert!(r.ns_per_iter < 100_000.0, "ns/iter = {}", r.ns_per_iter);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            ns_per_iter: 1000.0,
            stddev_ns: 0.0,
            items_per_iter: 500.0,
        };
        assert!((r.items_per_sec() - 5e8).abs() < 1.0);
    }
}
