//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options by querying an `Args` instance; unknown
//! options are reported at the end via `finish()`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — first element is NOT
    /// skipped here; use `from_env` for real argv.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    /// Parse process argv (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional argument (subcommand), if any.
    pub fn subcommand(&mut self) -> Option<String> {
        if self.positional.is_empty() {
            None
        } else {
            Some(self.positional.remove(0))
        }
    }

    /// Remaining positional args.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Boolean flag. A bare `--name` followed by a non-option token is
    /// initially parsed as `--name <value>`; querying it as a flag
    /// reclassifies it, returning the token to the positional list.
    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        if self.flags.iter().any(|f| f == name) {
            return true;
        }
        if let Some(v) = self.opts.remove(name) {
            self.positional.push(v);
            return true;
        }
        false
    }

    /// String option with default.
    pub fn opt_str(&mut self, name: &str, default: &str) -> String {
        self.consumed.push(name.to_string());
        self.opts.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_str_opt(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.opts.get(name).cloned()
    }

    /// Parsed numeric option with default; panics with a clear message on a
    /// malformed value (user error, not a bug).
    pub fn opt<T: std::str::FromStr>(&mut self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.push(name.to_string());
        match self.opts.get(name) {
            None => default,
            Some(v) => v
                .parse::<T>()
                .unwrap_or_else(|e| panic!("invalid value for --{name}: '{v}' ({e})")),
        }
    }

    /// Comma-separated list option, e.g. `--quant 2,4,8`.
    pub fn opt_list<T: std::str::FromStr>(&mut self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        self.consumed.push(name.to_string());
        match self.opts.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .unwrap_or_else(|e| panic!("invalid item in --{name}: '{s}' ({e})"))
                })
                .collect(),
        }
    }

    /// Error on any option the binary never asked about (catches typos).
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown options: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parse_kinds() {
        let mut a = argv("serve --batch 8 --quant=4 --verbose pos1");
        assert_eq!(a.subcommand().as_deref(), Some("serve"));
        assert_eq!(a.opt::<usize>("batch", 1), 8);
        assert_eq!(a.opt::<u32>("quant", 2), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_and_lists() {
        let mut a = argv("--levels 2,3,4");
        assert_eq!(a.opt_list::<u32>("levels", &[8]), vec![2, 3, 4]);
        assert_eq!(a.opt_list::<u32>("other", &[7]), vec![7]);
        assert_eq!(a.opt::<f64>("rate", 1.5), 1.5);
    }

    #[test]
    fn unknown_options_caught() {
        let mut a = argv("--oops 1");
        let _ = a.opt::<u32>("known", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    #[should_panic(expected = "invalid value for --n")]
    fn bad_numeric_panics() {
        let mut a = argv("--n abc");
        let _ = a.opt::<u32>("n", 0);
    }
}
