//! Plain-text table printer for the paper-style benchmark output.
//!
//! Every bench target regenerates one of the paper's tables/figures as an
//! aligned text table so that `cargo bench` output can be compared against
//! the published numbers line by line.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers. All columns are
    /// right-aligned except the first (label column).
    pub fn new(title: &str, headers: &[&str]) -> Self {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns,
            rows: Vec::new(),
        }
    }

    /// Override column alignments.
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    /// Append a row of displayable items.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                match aligns[i] {
                    Align::Left => line.push_str(&format!("{:<w$}", c, w = widths[i])),
                    Align::Right => line.push_str(&format!("{:>w$}", c, w = widths[i])),
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `p` decimal places.
pub fn f(x: f64, p: usize) -> String {
    format!("{:.*}", p, x)
}

/// Format a large integer with thousands separators (e.g. 3_000_000 -> "3,000,000").
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["bbbb".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("a       1"), "rendered:\n{r}");
        assert!(r.contains("bbbb   22"), "rendered:\n{r}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn commas_format() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(3_000_000), "3,000,000");
    }
}
