//! Minimal JSON parser (serde is unavailable offline).
//!
//! Supports the full JSON grammar except exotic number forms; used to read
//! the AOT `manifest.json`. Parsing is recursive-descent over chars with
//! positions in error messages.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { chars: text.chars().collect(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(format!("trailing garbage at {}", p.pos));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize to compact JSON text (the writer half the bench emitters
    /// use for BENCH_*.json artifacts). `parse(dump(x)) == x` for every
    /// value with finite numbers; non-finite numbers serialize as `null`.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize to `path` atomically: the text is written to a
    /// pid-unique temp file in the same directory and renamed into
    /// place. A crash (or injected fault) mid-write can therefore never
    /// leave a truncated artifact at `path` — readers see either the old
    /// complete file or the new complete file. BENCH_*.json emitters use
    /// this so a killed bench run cannot corrupt a previous result.
    pub fn write_atomic(&self, path: &std::path::Path) -> std::io::Result<()> {
        let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
        let file_name = path
            .file_name()
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    format!("write_atomic target '{}' has no file name", path.display()),
                )
            })?
            .to_os_string();
        let mut tmp_name = file_name;
        tmp_name.push(format!(".{}.tmp", std::process::id()));
        let tmp = match dir {
            Some(d) => d.join(&tmp_name),
            None => std::path::PathBuf::from(&tmp_name),
        };
        std::fs::write(&tmp, self.dump())?;
        // Same-directory rename is atomic on POSIX; on failure, clean up
        // the temp file so aborted writes do not accumulate.
        match std::fs::rename(&tmp, path) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if *n == n.trunc() && n.abs() < 9.0e15 {
                    // Integral values print without an exponent/fraction so
                    // downstream tools can read counts as integers.
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<char, String> {
        let c = self.peek().ok_or_else(|| "unexpected end".to_string())?;
        self.pos += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        let got = self.bump()?;
        if got == c {
            Ok(())
        } else {
            Err(format!("expected '{c}' got '{got}' at {}", self.pos - 1))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        for c in s.chars() {
            self.expect(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end")? {
            'n' => self.lit("null", Json::Null),
            't' => self.lit("true", Json::Bool(true)),
            'f' => self.lit("false", Json::Bool(false)),
            '"' => Ok(Json::Str(self.string()?)),
            '[' => self.array(),
            '{' => self.object(),
            c if c == '-' || c.is_ascii_digit() => self.number(),
            c => Err(format!("unexpected '{c}' at {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                '"' => return Ok(s),
                '\\' => match self.bump()? {
                    '"' => s.push('"'),
                    '\\' => s.push('\\'),
                    '/' => s.push('/'),
                    'b' => s.push('\u{8}'),
                    'f' => s.push('\u{c}'),
                    'n' => s.push('\n'),
                    'r' => s.push('\r'),
                    't' => s.push('\t'),
                    'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()?;
                            code = code * 16
                                + d.to_digit(16)
                                    .ok_or(format!("bad \\u digit '{d}'"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    e => return Err(format!("bad escape '\\{e}'")),
                },
                c => s.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || "+-.eE".contains(c)) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                ']' => return Ok(Json::Arr(items)),
                c => return Err(format!("expected ',' or ']' got '{c}'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect('{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                '}' => return Ok(Json::Obj(map)),
                c => return Err(format!("expected ',' or '}}' got '{c}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let j = Json::parse(
            r#"{"config": {"hidden": 256, "layers": 4},
                "weights": [{"name": "embed", "shape": [2048, 256]}],
                "ok": true, "x": null, "f": -1.5e3}"#,
        )
        .unwrap();
        assert_eq!(j.get("config").unwrap().get("hidden").unwrap().as_usize(), Some(256));
        let w = &j.get("weights").unwrap().as_arr().unwrap()[0];
        assert_eq!(w.get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(w.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(2048));
        assert_eq!(j.get("f").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(j.get("x"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn dump_parse_roundtrip() {
        let src = r#"{"config": {"hidden": 256, "neg": -1.5e3},
                      "list": [1, 2.25, true, null, "a\"b\\c\nd"],
                      "empty_a": [], "empty_o": {}}"#;
        let j = Json::parse(src).unwrap();
        let dumped = j.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), j);
        // Integral floats come out as integers.
        assert!(dumped.contains("\"hidden\":256"), "{dumped}");
    }

    #[test]
    fn dump_handles_non_finite() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(2.5).dump(), "2.5");
    }

    #[test]
    fn write_atomic_survives_a_simulated_partial_write() {
        let dir = std::env::temp_dir().join(format!("sail-json-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");

        let old = Json::Obj(BTreeMap::from([("v".to_string(), Json::Num(1.0))]));
        old.write_atomic(&path).unwrap();
        assert_eq!(Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap(), old);

        // A writer that died mid-write leaves a truncated *temp* file —
        // the published path is untouched. Simulate the torn state the
        // non-atomic `fs::write(path, …)` would have produced and check
        // the atomic protocol never exposes it.
        let new = Json::Obj(BTreeMap::from([("v".to_string(), Json::Num(2.0))]));
        let full = new.dump();
        let torn = &full[..full.len() / 2];
        let tmp = dir.join(format!("bench.json.{}.tmp", std::process::id()));
        std::fs::write(&tmp, torn).unwrap();
        assert!(Json::parse(torn).is_err(), "the torn prefix must not be valid JSON");
        assert_eq!(
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap(),
            old,
            "a dead writer's temp file must not clobber the published artifact"
        );

        // Completing the protocol (write_atomic reuses the same temp
        // name) replaces the file with the complete new value.
        new.write_atomic(&path).unwrap();
        assert_eq!(Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap(), new);
        assert!(!tmp.exists(), "temp file must be renamed away, not left behind");

        // And the target must be a real file name, typed.
        assert!(new.write_atomic(std::path::Path::new("/")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
