//! Streaming statistics: latency percentiles, mean/stddev, histograms.
//!
//! Used by the serving coordinator's metrics and by the bench harness.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Reservoir of raw samples for exact percentiles. For the request volumes
/// the coordinator sees (≤ millions) storing raw f64s is fine; `percentile`
/// sorts a copy on demand.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { samples: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// p in [0,100]. Linear interpolation between closest ranks.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentile_exact_ranks() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((p.p99() - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentile_empty_is_nan() {
        assert!(Percentiles::new().p50().is_nan());
    }
}
