//! Small self-contained utilities.
//!
//! The build environment is fully offline and only the crates vendored for
//! the `xla` bridge are available, so the usual ecosystem helpers (rand,
//! proptest, criterion, prettytable, …) are re-implemented here in minimal
//! form: a xorshift PRNG, a table printer for the paper-style benchmark
//! output, a tiny property-testing driver, and a micro-bench harness.

pub mod bench;
pub mod cli;
pub mod toml;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod stats;
pub mod table;

pub use prng::Prng;
pub use table::Table;

/// Geometric mean of a slice of positive values (used by Table II's GEO-MEAN
/// row). Returns 0.0 for an empty slice.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Ceiling division for unsigned integers.
pub const fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Deterministic token/position embedding component `i` in `[-1, 1)`
/// (SplitMix64-style finalizer): stateless, so it is identical on every
/// thread, at every batch size, and across pool widths/placements. The
/// single definition shared by the decode models — the toy serving engine
/// and the multi-layer transformer must embed identically or cross-engine
/// comparisons silently desynchronize.
pub fn splitmix_embed(token: i32, position: u64, i: usize) -> f32 {
    let mut z = (token as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(position << 32)
        .wrapping_add((i as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 40) as f32) / ((1u64 << 23) as f32) - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(1024, 3), 342);
    }
}
