//! Deterministic xorshift128+ PRNG.
//!
//! No `rand` crate offline; simulations, workload generators, and property
//! tests all need reproducible randomness, so seeds are explicit everywhere.

/// xorshift128+ generator. Fast, passes BigCrush except MatrixRank, more
/// than adequate for workload synthesis and property testing.
#[derive(Debug, Clone)]
pub struct Prng {
    s0: u64,
    s1: u64,
}

impl Prng {
    /// Create a generator from a seed. The seed is mixed with SplitMix64 so
    /// that small consecutive seeds produce uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Prng { s0, s1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.gen_range(span) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Random signed integer representable in `bits` two's-complement bits,
    /// i.e. in `[-2^(bits-1), 2^(bits-1) - 1]`.
    pub fn signed_bits(&mut self, bits: u32) -> i64 {
        assert!((1..=63).contains(&bits));
        let half = 1i64 << (bits - 1);
        self.i64_in(-half, half - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponentially distributed value with the given rate (for Poisson
    /// arrival synthesis in the serving workload generator).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_bounds() {
        let mut p = Prng::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(p.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn signed_bits_bounds() {
        let mut p = Prng::new(9);
        for bits in 1..=8u32 {
            let half = 1i64 << (bits - 1);
            let mut seen_neg = false;
            for _ in 0..500 {
                let v = p.signed_bits(bits);
                assert!(v >= -half && v < half, "v={v} bits={bits}");
                seen_neg |= v < 0;
            }
            assert!(seen_neg, "never saw negative at bits={bits}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(3);
        let mean: f64 = (0..10_000).map(|_| p.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
