//! Minimal TOML-subset parser for configuration files.
//!
//! Supports: `[table]` and `[table.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and flat arrays, plus `#`
//! comments. Values are exposed through dotted-path lookup
//! (`get("serving.batch")`). This covers everything `configs/*.toml`
//! needs without an external crate.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML document with dotted-path access.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", ln + 1))?;
                if h.is_empty() || h.split('.').any(|p| p.trim().is_empty()) {
                    return Err(format!("line {}: bad table name '{h}'", ln + 1));
                }
                prefix = h.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", ln + 1));
            }
            let full = if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", ln + 1))?;
            if doc.values.insert(full.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key '{full}'", ln + 1));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    /// Typed getters with defaults.
    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path).and_then(TomlValue::as_str).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(TomlValue::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue, String> {
    if v.is_empty() {
        return Err("empty value".into());
    }
    if let Some(s) = v.strip_prefix('"') {
        let s = s.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(s.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if !v.contains('.') && !v.contains('e') && !v.contains('E') {
        if let Ok(i) = v.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving configuration
name = "sail-demo"

[serving]
batch = 8
rate = 4.5            # requests/sec
mock = false
quants = [2, 4, 8]

[arch.dram]
mt_per_sec = 6400
"#;

    #[test]
    fn parses_sample() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("name", ""), "sail-demo");
        assert_eq!(d.usize_or("serving.batch", 0), 8);
        assert_eq!(d.f64_or("serving.rate", 0.0), 4.5);
        assert!(!d.bool_or("serving.mock", true));
        assert_eq!(d.usize_or("arch.dram.mt_per_sec", 0), 6400);
        match d.get("serving.quants").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("anything", 7), 7);
        assert_eq!(d.str_or("x", "dflt"), "dflt");
    }

    #[test]
    fn errors_are_located() {
        assert!(TomlDoc::parse("[unterminated").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("novalue").unwrap_err().contains("key = value"));
        assert!(TomlDoc::parse("a = 1\na = 2").unwrap_err().contains("duplicate"));
        assert!(TomlDoc::parse("a = \"open").unwrap_err().contains("unterminated"));
    }

    #[test]
    fn comments_and_strings_interact() {
        let d = TomlDoc::parse(r##"s = "a # not comment" # real comment"##).unwrap();
        assert_eq!(d.str_or("s", ""), "a # not comment");
    }
}
