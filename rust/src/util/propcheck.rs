//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! `check` runs a property over `n` random cases generated from a seeded
//! PRNG; on failure it re-runs a simple halving shrink over the case index
//! space is not possible (cases are opaque), so instead it reports the seed
//! and case number so the exact failing input can be reproduced with
//! `reproduce`. Generators receive the case index to allow size ramping
//! (small cases first, like proptest's sizing).

use super::prng::Prng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` random inputs produced by `gen`.
///
/// `gen(prng, i)` should scale input size with `i` (ramping) so early
/// failures are small. `prop` returns `Err(msg)` on violation; the driver
/// panics with the seed/case coordinates for reproduction.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Prng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        // Independent stream per case: failures are reproducible in
        // isolation without replaying preceding cases.
        let mut prng = Prng::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut prng, case);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}):\n  input: {input:?}\n  {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Reproduce a single case by (seed, case) coordinates, returning the input.
pub fn reproduce<T>(
    cfg: Config,
    case: usize,
    mut gen: impl FnMut(&mut Prng, usize) -> T,
) -> T {
    let mut prng = Prng::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9));
    gen(&mut prng, case)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            "abs-nonneg",
            Config::default(),
            |p, i| p.i64_in(-(i as i64 + 1), i as i64 + 1),
            |x| {
                if x.abs() >= 0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn fails_with_coordinates() {
        check(
            "always-small",
            Config { cases: 64, seed: 1 },
            |p, _| p.gen_range(1000),
            |x| {
                if *x < 10 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 10"))
                }
            },
        );
    }

    #[test]
    fn reproduce_matches_check_stream() {
        let cfg = Config { cases: 8, seed: 99 };
        let mut seen = Vec::new();
        check(
            "collect",
            cfg,
            |p, _| p.next_u64(),
            |x| {
                seen.push(*x);
                Ok(())
            },
        );
        for (case, want) in seen.iter().enumerate() {
            let got = reproduce(cfg, case, |p, _| p.next_u64());
            assert_eq!(got, *want);
        }
    }
}
