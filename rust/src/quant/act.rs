//! Activation quantization.
//!
//! The LUT-GEMV datapath consumes integer activations bit-serially (Fig 2
//! streams activation bits LSB→MSB). Activations are quantized to int8 with
//! one f32 scale per vector — the llama.cpp Q8 activation scheme the paper's
//! benchmarks inherit. The CPU vector engine performs the float-side
//! scaling during de-/re-quantization (paper §III-B).

/// An int8-quantized activation vector with a single scale.
#[derive(Debug, Clone)]
pub struct QuantizedVector {
    pub q: Vec<i8>,
    pub scale: f32,
    /// Bit-width the DFM streams (8 for int8 activations).
    pub bits: u32,
}

impl QuantizedVector {
    /// Symmetric int8 quantization: `x ≈ scale * q`, q in [-127, 127].
    pub fn quantize(x: &[f32]) -> Self {
        let mut qv = QuantizedVector { q: Vec::new(), scale: 1.0, bits: 8 };
        qv.quantize_into(x);
        qv
    }

    /// Re-quantize `x` into this vector, reusing the code buffer — the
    /// decode hot path re-quantizes activations many times per token, so
    /// steady state must not allocate. Produces exactly the same `q`,
    /// `scale`, and `bits` as [`quantize`](Self::quantize).
    pub fn quantize_into(&mut self, x: &[f32]) {
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if amax == 0.0 { 1.0 } else { amax / 127.0 };
        self.scale = scale;
        self.bits = 8;
        self.q.clear();
        self.q.extend(x.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8));
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Dequantize back to f32.
    pub fn dequant(&self) -> Vec<f32> {
        self.q.iter().map(|&v| v as f32 * self.scale).collect()
    }

    /// Two's-complement bit `plane` of element `i` (0 = LSB). The DFM
    /// broadcasts one plane of NBW consecutive elements per cycle.
    #[inline]
    pub fn bit(&self, i: usize, plane: u32) -> u8 {
        debug_assert!(plane < self.bits);
        ((self.q[i] as u8) >> plane) & 1
    }

    /// The NBW-bit pattern formed by elements `[start, start+nbw)` at bit
    /// `plane` — the LUT index for one lookup (and the PRT hash input).
    /// Element `start` contributes the MSB of the pattern, matching Fig 2
    /// where activation A (the first input) maps to LUT address bit 2.
    #[inline]
    pub fn pattern(&self, start: usize, nbw: u32, plane: u32) -> u32 {
        let mut p = 0u32;
        for k in 0..nbw as usize {
            let b = if start + k < self.q.len() {
                self.bit(start + k, plane) as u32
            } else {
                0 // zero-padding beyond the vector end
            };
            p = (p << 1) | b;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut prng = Prng::new(3);
        let x: Vec<f32> = (0..256).map(|_| prng.normal() as f32).collect();
        let qv = QuantizedVector::quantize(&x);
        let d = qv.dequant();
        for (a, b) in x.iter().zip(d.iter()) {
            assert!((a - b).abs() <= qv.scale * 0.50001);
        }
    }

    #[test]
    fn zero_vector_stable() {
        let qv = QuantizedVector::quantize(&[0.0; 8]);
        assert!(qv.q.iter().all(|&v| v == 0));
        assert!(qv.scale > 0.0);
    }

    #[test]
    fn quantize_into_matches_quantize_and_reuses_buffer() {
        let mut prng = Prng::new(17);
        let mut qv = QuantizedVector::quantize(&[1.0; 64]);
        let cap = qv.q.capacity();
        for _ in 0..20 {
            let x: Vec<f32> = (0..prng.usize_in(1, 65)).map(|_| prng.normal() as f32).collect();
            qv.quantize_into(&x);
            let fresh = QuantizedVector::quantize(&x);
            assert_eq!(qv.q, fresh.q);
            assert_eq!(qv.scale, fresh.scale);
            assert_eq!(qv.bits, fresh.bits);
            // Shrinking-or-equal re-quantizations never reallocate.
            assert_eq!(qv.q.capacity(), cap, "steady-state requantize reallocated");
        }
    }

    #[test]
    fn bits_reconstruct_two_complement() {
        let qv = QuantizedVector { q: vec![-3, 5, 127, -128i8 + 1], scale: 1.0, bits: 8 };
        for (i, &v) in qv.q.iter().enumerate() {
            let mut rec = 0u8;
            for plane in 0..8 {
                rec |= qv.bit(i, plane) << plane;
            }
            assert_eq!(rec as i8, v);
        }
    }

    #[test]
    fn pattern_matches_fig2_convention() {
        // Fig 2: inputs [A, B, C]; pattern 001 -> W2 means C (last element)
        // is the LSB of the LUT address.
        let qv = QuantizedVector { q: vec![0, 0, 1], scale: 1.0, bits: 8 };
        assert_eq!(qv.pattern(0, 3, 0), 0b001);
        let qv = QuantizedVector { q: vec![1, 0, 0], scale: 1.0, bits: 8 };
        assert_eq!(qv.pattern(0, 3, 0), 0b100);
    }

    #[test]
    fn pattern_pads_past_end_with_zeros() {
        let qv = QuantizedVector { q: vec![1], scale: 1.0, bits: 8 };
        assert_eq!(qv.pattern(0, 3, 0), 0b100);
        assert_eq!(qv.pattern(1, 3, 0), 0);
    }
}
