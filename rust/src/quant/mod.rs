//! Quantization substrate.
//!
//! SAIL serves llama.cpp-style group-wise quantized models at 2/3/4/5/6/8
//! bits (the paper's Q2..Q8 levels). This module provides:
//!
//! - [`QuantLevel`]: the supported precision levels and their metadata,
//! - [`pack`]: a dense bitstream packer/unpacker for sub-byte integers,
//! - [`groupwise`]: group-wise symmetric weight quantization producing the
//!   integer weights + scales consumed by the LUT-GEMV engine, and
//! - [`act`]: int8 activation quantization with a per-vector scale.
//!
//! The functional contract that the rest of the system relies on (and that
//! the tests pin down): `dequant(quantize(W))` equals the integer weights
//! times the group scale, *bit-exactly* — all downstream GEMV paths
//! (naive reference, LUT engine, bit-serial baseline, and the Pallas
//! kernel on the Python side) must agree on these integers.

pub mod act;
pub mod groupwise;
pub mod pack;

pub use act::QuantizedVector;
pub use groupwise::QuantizedMatrix;

/// Weight precision levels supported by the `lutmm_1k` instruction's `ql`
/// field (paper §IV-A: "all common quantization levels (2/3/4/5/6/8-bit)").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QuantLevel {
    Q2,
    Q3,
    Q4,
    Q5,
    Q6,
    Q8,
}

impl QuantLevel {
    /// All levels in ascending bit order.
    pub const ALL: [QuantLevel; 6] = [
        QuantLevel::Q2,
        QuantLevel::Q3,
        QuantLevel::Q4,
        QuantLevel::Q5,
        QuantLevel::Q6,
        QuantLevel::Q8,
    ];

    /// Weight bit-width.
    pub const fn bits(self) -> u32 {
        match self {
            QuantLevel::Q2 => 2,
            QuantLevel::Q3 => 3,
            QuantLevel::Q4 => 4,
            QuantLevel::Q5 => 5,
            QuantLevel::Q6 => 6,
            QuantLevel::Q8 => 8,
        }
    }

    /// Encoding used in the `lutmm_1k` instruction `ql` field (3 bits).
    pub const fn ql_code(self) -> u8 {
        match self {
            QuantLevel::Q2 => 0,
            QuantLevel::Q3 => 1,
            QuantLevel::Q4 => 2,
            QuantLevel::Q5 => 3,
            QuantLevel::Q6 => 4,
            QuantLevel::Q8 => 5,
        }
    }

    /// Decode the `ql` field.
    pub fn from_ql_code(code: u8) -> Option<QuantLevel> {
        Some(match code {
            0 => QuantLevel::Q2,
            1 => QuantLevel::Q3,
            2 => QuantLevel::Q4,
            3 => QuantLevel::Q5,
            4 => QuantLevel::Q6,
            5 => QuantLevel::Q8,
            _ => return None,
        })
    }

    /// Parse "2"/"Q2"/"q2" style names.
    pub fn parse(s: &str) -> Option<QuantLevel> {
        let t = s.trim().trim_start_matches(['q', 'Q']);
        Some(match t {
            "2" => QuantLevel::Q2,
            "3" => QuantLevel::Q3,
            "4" => QuantLevel::Q4,
            "5" => QuantLevel::Q5,
            "6" => QuantLevel::Q6,
            "8" => QuantLevel::Q8,
            _ => return None,
        })
    }

    /// Largest representable magnitude for symmetric quantization:
    /// values live in `[-2^(b-1)+1, 2^(b-1)-1]` (we sacrifice the most
    /// negative code to keep the range symmetric, as llama.cpp does).
    pub const fn max_q(self) -> i32 {
        (1 << (self.bits() - 1)) - 1
    }

    /// Effective bits per weight including the per-group f16 scale
    /// amortized over a group of `group` weights (model-size accounting).
    pub fn bits_per_weight(self, group: usize) -> f64 {
        self.bits() as f64 + 16.0 / group as f64
    }
}

impl std::fmt::Display for QuantLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Q{}", self.bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_and_codes_roundtrip() {
        for q in QuantLevel::ALL {
            assert_eq!(QuantLevel::from_ql_code(q.ql_code()), Some(q));
            assert_eq!(QuantLevel::parse(&q.to_string()), Some(q));
            assert_eq!(QuantLevel::parse(&q.bits().to_string()), Some(q));
        }
        assert_eq!(QuantLevel::from_ql_code(7), None);
        assert_eq!(QuantLevel::parse("Q7"), None);
    }

    #[test]
    fn max_q_symmetric() {
        assert_eq!(QuantLevel::Q2.max_q(), 1);
        assert_eq!(QuantLevel::Q3.max_q(), 3);
        assert_eq!(QuantLevel::Q4.max_q(), 7);
        assert_eq!(QuantLevel::Q8.max_q(), 127);
    }

    #[test]
    fn bits_per_weight_includes_scale() {
        let b = QuantLevel::Q4.bits_per_weight(32);
        assert!((b - 4.5).abs() < 1e-12);
    }
}
