//! Dense bitstream packing for sub-byte integers.
//!
//! Weights quantized to b bits are stored b-bit-aligned (no padding to byte
//! boundaries), matching how SAIL lays weights out in cache lines: a 512-bit
//! C-SRAM row holds `512/b` b-bit weights. Values are two's-complement,
//! packed LSB-first into little-endian u64 words.

/// A packed stream of fixed-width two's-complement integers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPacked {
    words: Vec<u64>,
    bits: u32,
    len: usize,
}

impl BitPacked {
    /// Pack `values` at `bits` width. Panics if any value is out of range
    /// for a `bits`-bit two's-complement integer.
    pub fn pack(values: &[i32], bits: u32) -> Self {
        assert!((1..=32).contains(&bits), "bits must be in 1..=32");
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        let total_bits = values.len() * bits as usize;
        let mut words = vec![0u64; total_bits.div_ceil(64)];
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        for (i, &v) in values.iter().enumerate() {
            assert!(
                (v as i64) >= lo && (v as i64) <= hi,
                "value {v} out of range for {bits}-bit"
            );
            let u = (v as u64) & mask;
            let bitpos = i * bits as usize;
            let word = bitpos / 64;
            let off = bitpos % 64;
            words[word] |= u << off;
            if off + bits as usize > 64 {
                words[word + 1] |= u >> (64 - off);
            }
        }
        BitPacked { words, bits, len: values.len() }
    }

    /// Number of packed values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bit width per value.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Storage size in bytes (the quantity the memory-traffic model uses).
    pub fn nbytes(&self) -> usize {
        (self.len * self.bits as usize).div_ceil(8)
    }

    /// Get value `i` (sign-extended).
    #[inline]
    pub fn get(&self, i: usize) -> i32 {
        debug_assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let word = bitpos / 64;
        let off = bitpos % 64;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut u = self.words[word] >> off;
        if off + bits > 64 {
            u |= self.words[word + 1] << (64 - off);
        }
        u &= mask;
        // Sign-extend.
        let sign = 1u64 << (bits - 1);
        ((u ^ sign).wrapping_sub(sign)) as i64 as i32
    }

    /// Unpack all values.
    pub fn unpack(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.len];
        self.unpack_range_into(0, &mut out);
        out
    }

    /// Unpack the range `[start, start+out.len())` into a caller buffer —
    /// the allocation-free fast path the GEMV engine's column-tile kernel
    /// uses (every column visit pays K of these).
    ///
    /// Word-at-a-time extraction: a running bit buffer is refilled from the
    /// packed words sequentially, so each value costs one shift+mask (plus
    /// one word load every `64/bits` values) instead of the div/mod address
    /// arithmetic and two-word gather the naive per-element path pays.
    pub fn unpack_range_into(&self, start: usize, out: &mut [i32]) {
        assert!(start + out.len() <= self.len);
        if out.is_empty() {
            return;
        }
        let bits = self.bits as usize;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let sign = 1u64 << (bits - 1);
        let bitpos = start * bits;
        let mut word = bitpos / 64;
        let off = bitpos % 64;
        // `buf` holds the next `avail` not-yet-consumed bits in its low end.
        let mut buf = self.words[word] >> off;
        let mut avail = 64 - off;
        for o in out.iter_mut() {
            let u = if avail < bits {
                // Value straddles into the next word (or `buf` is drained):
                // splice the remainder from the next word's low bits.
                word += 1;
                let next = self.words[word];
                let u = (buf | (next << avail)) & mask;
                let used_of_next = bits - avail;
                buf = next >> used_of_next;
                avail = 64 - used_of_next;
                u
            } else {
                let u = buf & mask;
                buf >>= bits;
                avail -= bits;
                u
            };
            // Sign-extend from `bits` wide.
            *o = ((u ^ sign).wrapping_sub(sign)) as i64 as i32;
        }
    }

    /// Extract bit-plane `plane` (0 = LSB) of values `[start, start+n)` as a
    /// u64-packed bit vector — this is what the DFM broadcasts to C-SRAMs
    /// during bit-serial streaming.
    pub fn bit_plane(&self, plane: u32, start: usize, n: usize) -> Vec<u64> {
        assert!(plane < self.bits);
        assert!(start + n <= self.len);
        let mut out = vec![0u64; n.div_ceil(64)];
        for i in 0..n {
            let v = self.get(start + i) as u32;
            if (v >> plane) & 1 == 1 {
                out[i / 64] |= 1u64 << (i % 64);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Prng};

    #[test]
    fn roundtrip_simple() {
        for bits in [2u32, 3, 4, 5, 6, 8, 12, 16] {
            let hi = (1i32 << (bits - 1)) - 1;
            let lo = -(1i32 << (bits - 1));
            let vals: Vec<i32> = vec![0, 1, -1, hi, lo, hi / 2, lo / 2];
            let p = BitPacked::pack(&vals, bits);
            assert_eq!(p.unpack(), vals, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_property() {
        propcheck::check(
            "pack-unpack-roundtrip",
            propcheck::Config { cases: 200, seed: 11 },
            |p, i| {
                let bits = [2u32, 3, 4, 5, 6, 8][p.usize_in(0, 6)];
                let n = p.usize_in(0, 3 + i);
                let vals: Vec<i32> =
                    (0..n).map(|_| p.signed_bits(bits) as i32).collect();
                (bits, vals)
            },
            |(bits, vals)| {
                let p = BitPacked::pack(vals, *bits);
                if p.unpack() == *vals {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn nbytes_dense() {
        // 1024 3-bit values = 3072 bits = 384 bytes (no per-value padding).
        let vals = vec![1i32; 1024];
        assert_eq!(BitPacked::pack(&vals, 3).nbytes(), 384);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn range_checked() {
        BitPacked::pack(&[8], 4); // 4-bit max is 7
    }

    #[test]
    fn bit_planes_reconstruct_values() {
        let mut prng = Prng::new(123);
        let bits = 4u32;
        let vals: Vec<i32> = (0..100).map(|_| prng.signed_bits(bits) as i32).collect();
        let p = BitPacked::pack(&vals, bits);
        for (i, &v) in vals.iter().enumerate() {
            let mut rec = 0u32;
            for plane in 0..bits {
                let bp = p.bit_plane(plane, 0, vals.len());
                let bit = (bp[i / 64] >> (i % 64)) & 1;
                rec |= (bit as u32) << plane;
            }
            let sign = 1u32 << (bits - 1);
            let signed = ((rec ^ sign).wrapping_sub(sign)) as i32;
            assert_eq!(signed, v, "i={i}");
        }
    }

    #[test]
    fn crossing_word_boundaries() {
        // 3-bit values: value 21 starts at bit 63, crossing into word 1.
        let vals: Vec<i32> = (0..64).map(|i| (i % 7) - 3).collect();
        let p = BitPacked::pack(&vals, 3);
        assert_eq!(p.unpack(), vals);
    }

    #[test]
    fn range_unpack_matches_get_property() {
        // The word-at-a-time fast path must agree with the per-element
        // `get` for every width, start offset, and length — including
        // ranges whose first value starts mid-word and whose last value
        // ends exactly on a word boundary.
        propcheck::check(
            "unpack-range-vs-get",
            propcheck::Config { cases: 300, seed: 17 },
            |p, i| {
                let bits = [2u32, 3, 4, 5, 6, 8, 12, 16, 32][p.usize_in(0, 9)];
                let n = p.usize_in(1, 8 + 2 * i);
                let vals: Vec<i32> = (0..n).map(|_| p.signed_bits(bits) as i32).collect();
                let start = p.usize_in(0, n);
                let len = p.usize_in(0, n - start + 1);
                (bits, vals, start, len)
            },
            |&(bits, ref vals, start, len)| {
                let p = BitPacked::pack(vals, bits);
                let mut out = vec![0i32; len];
                p.unpack_range_into(start, &mut out);
                for (j, &o) in out.iter().enumerate() {
                    if o != p.get(start + j) {
                        return Err(format!(
                            "bits={bits} start={start} len={len} elem {j}: {} != {}",
                            o,
                            p.get(start + j)
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn range_unpack_empty_and_tail() {
        let vals: Vec<i32> = (0..100).map(|i| (i % 15) - 7).collect();
        let p = BitPacked::pack(&vals, 5);
        let mut empty: [i32; 0] = [];
        p.unpack_range_into(100, &mut empty); // start == len, zero-length
        let mut tail = vec![0i32; 3];
        p.unpack_range_into(97, &mut tail);
        assert_eq!(tail, &vals[97..]);
    }
}
