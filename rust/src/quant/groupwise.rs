//! Group-wise symmetric weight quantization.
//!
//! Each row of a weight matrix is split into groups of `group_size`
//! consecutive elements sharing one f32 scale (llama.cpp block quantization,
//! the format the paper benchmarks). Integer codes are stored bit-packed;
//! `w[r][c] ≈ scale(r,c) * q(r,c)` with `q` in the symmetric range
//! `[-max_q, max_q]`.

use super::pack::BitPacked;
use super::QuantLevel;

/// A group-wise quantized row-major matrix.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub level: QuantLevel,
    pub group_size: usize,
    /// Packed integer codes, row-major.
    data: BitPacked,
    /// One scale per (row, group): `scales[r * groups_per_row + g]`.
    scales: Vec<f32>,
}

impl QuantizedMatrix {
    /// Quantize a row-major f32 matrix. `group_size` must divide `cols`.
    pub fn quantize(
        w: &[f32],
        rows: usize,
        cols: usize,
        level: QuantLevel,
        group_size: usize,
    ) -> Self {
        assert_eq!(w.len(), rows * cols, "matrix shape mismatch");
        assert!(group_size > 0 && cols % group_size == 0, "group_size must divide cols");
        let max_q = level.max_q() as f32;
        let groups_per_row = cols / group_size;
        let mut scales = Vec::with_capacity(rows * groups_per_row);
        let mut codes = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for g in 0..groups_per_row {
                let base = r * cols + g * group_size;
                let grp = &w[base..base + group_size];
                let amax = grp.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let scale = if amax == 0.0 { 1.0 } else { amax / max_q };
                scales.push(scale);
                for &x in grp {
                    let q = (x / scale).round().clamp(-max_q, max_q) as i32;
                    codes.push(q);
                }
            }
        }
        QuantizedMatrix {
            rows,
            cols,
            level,
            group_size,
            data: BitPacked::pack(&codes, level.bits()),
            scales,
        }
    }

    /// Integer code at (r, c).
    #[inline]
    pub fn q(&self, r: usize, c: usize) -> i32 {
        self.data.get(r * self.cols + c)
    }

    /// Scale applying to (r, c).
    #[inline]
    pub fn scale(&self, r: usize, c: usize) -> f32 {
        let groups_per_row = self.cols / self.group_size;
        self.scales[r * groups_per_row + c / self.group_size]
    }

    /// Dequantized value at (r, c).
    #[inline]
    pub fn dequant(&self, r: usize, c: usize) -> f32 {
        self.q(r, c) as f32 * self.scale(r, c)
    }

    /// Full dequantized matrix (row-major).
    pub fn dequant_all(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(self.dequant(r, c));
            }
        }
        out
    }

    /// A whole row of integer codes (used by the LUT engine's tile loader).
    pub fn q_row(&self, r: usize) -> Vec<i32> {
        (0..self.cols).map(|c| self.q(r, c)).collect()
    }

    /// Storage bytes: packed codes + f16 scales (2 bytes each), the figure
    /// the memory-traffic model charges for weight movement.
    pub fn nbytes(&self) -> usize {
        self.data.nbytes() + self.scales.len() * 2
    }

    /// Number of scale groups per row.
    pub fn groups_per_row(&self) -> usize {
        self.cols / self.group_size
    }

    /// Access to the raw packed stream (for cache-layout simulation).
    pub fn packed(&self) -> &BitPacked {
        &self.data
    }

    /// An exact copy of rows `[r0, r1)` as a standalone matrix (codes
    /// repacked, scales sliced — integer-identical to the source rows, so
    /// any GEMV over the slice matches the same rows of the original
    /// bit-for-bit).
    ///
    /// This is the NUMA weight-sharding primitive: the LUT-GEMV engine
    /// gives each node a first-touch copy of exactly the output columns
    /// (rows of the transposed matrix) that node's workers own, and runs
    /// the copy *on* the owning node so the pages land there.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> QuantizedMatrix {
        assert!(r0 <= r1 && r1 <= self.rows, "row slice [{r0}, {r1}) out of bounds");
        let cols = self.cols;
        let mut codes = vec![0i32; (r1 - r0) * cols];
        self.data.unpack_range_into(r0 * cols, &mut codes);
        let gpr = self.groups_per_row();
        QuantizedMatrix {
            rows: r1 - r0,
            cols,
            level: self.level,
            group_size: self.group_size,
            data: BitPacked::pack(&codes, self.level.bits()),
            scales: self.scales[r0 * gpr..r1 * gpr].to_vec(),
        }
    }

    /// Worst-case absolute quantization error bound: scale/2 per element.
    pub fn max_abs_error(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(s)) * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Prng};

    fn random_matrix(prng: &mut Prng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| prng.normal() as f32).collect()
    }

    #[test]
    fn reconstruction_error_bounded() {
        let mut prng = Prng::new(1);
        for level in QuantLevel::ALL {
            let (rows, cols, group) = (16, 64, 32);
            let w = random_matrix(&mut prng, rows, cols);
            let qm = QuantizedMatrix::quantize(&w, rows, cols, level, group);
            let deq = qm.dequant_all();
            let bound = qm.max_abs_error() * 1.0001;
            for (i, (&a, &b)) in w.iter().zip(deq.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= bound,
                    "{level}: elem {i}: |{a} - {b}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn codes_in_symmetric_range() {
        propcheck::check(
            "codes-in-range",
            propcheck::Config { cases: 60, seed: 2 },
            |p, _| {
                let level = QuantLevel::ALL[p.usize_in(0, 6)];
                let rows = p.usize_in(1, 8);
                let group = 8;
                let cols = group * p.usize_in(1, 6);
                let w: Vec<f32> = (0..rows * cols).map(|_| p.normal() as f32 * 3.0).collect();
                (level, rows, cols, group, w)
            },
            |(level, rows, cols, group, w)| {
                let qm = QuantizedMatrix::quantize(w, *rows, *cols, *level, *group);
                let mq = level.max_q();
                for r in 0..*rows {
                    for c in 0..*cols {
                        let q = qm.q(r, c);
                        if q < -mq || q > mq {
                            return Err(format!("code {q} outside ±{mq} at ({r},{c})"));
                        }
                        if qm.scale(r, c) <= 0.0 {
                            return Err("non-positive scale".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_matrix_is_stable() {
        let w = vec![0.0f32; 4 * 32];
        let qm = QuantizedMatrix::quantize(&w, 4, 32, QuantLevel::Q4, 32);
        assert!(qm.dequant_all().iter().all(|&x| x == 0.0));
        assert!(qm.scale(0, 0) > 0.0);
    }

    #[test]
    fn group_scales_are_local() {
        // Two groups with very different magnitudes must get different scales.
        let mut w = vec![0.01f32; 64];
        for x in w.iter_mut().skip(32) {
            *x = 100.0;
        }
        let qm = QuantizedMatrix::quantize(&w, 1, 64, QuantLevel::Q4, 32);
        assert!(qm.scale(0, 0) < qm.scale(0, 32) / 100.0);
        // Small group still reconstructs to within its own scale.
        assert!((qm.dequant(0, 0) - 0.01).abs() < qm.scale(0, 0));
    }

    #[test]
    fn nbytes_accounting() {
        let w = vec![1.0f32; 1024 * 1024];
        let qm = QuantizedMatrix::quantize(&w, 1024, 1024, QuantLevel::Q4, 32);
        // 4 bits/weight = 512KiB codes + 32K groups * 2B = 64KiB scales.
        assert_eq!(qm.nbytes(), 512 * 1024 + 64 * 1024);
    }

    #[test]
    #[should_panic(expected = "group_size must divide cols")]
    fn group_divides_cols() {
        QuantizedMatrix::quantize(&[0.0; 10], 1, 10, QuantLevel::Q4, 3);
    }

    #[test]
    fn slice_rows_is_integer_identical() {
        let mut prng = Prng::new(7);
        for level in [QuantLevel::Q3, QuantLevel::Q4, QuantLevel::Q8] {
            let (rows, cols, group) = (11, 48, 16);
            let w = random_matrix(&mut prng, rows, cols);
            let qm = QuantizedMatrix::quantize(&w, rows, cols, level, group);
            for (r0, r1) in [(0, rows), (3, 9), (0, 1), (10, 11), (5, 5)] {
                let s = qm.slice_rows(r0, r1);
                assert_eq!(s.rows, r1 - r0);
                assert_eq!((s.cols, s.group_size, s.level), (cols, group, level));
                for r in r0..r1 {
                    for c in 0..cols {
                        assert_eq!(s.q(r - r0, c), qm.q(r, c), "{level} ({r},{c})");
                        assert_eq!(
                            s.scale(r - r0, c).to_bits(),
                            qm.scale(r, c).to_bits(),
                            "{level} scale ({r},{c})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_rows_bounds_checked() {
        let qm = QuantizedMatrix::quantize(&[0.0; 64], 4, 16, QuantLevel::Q4, 16);
        let _ = qm.slice_rows(2, 5);
    }
}
