//! # SAIL — SRAM-Accelerated LLM Inference with LUT-based GEMV
//!
//! A full-system reproduction of the SAIL paper (Zhang, Park, Lee,
//! Sadredini; CS.AR 2025): a near-cache processing-in-memory architecture
//! for quantized LLM inference, built as a three-layer Rust + JAX/Pallas
//! stack.
//!
//! Layer map (see DESIGN.md):
//! - **Substrates**: [`quant`], [`isa`], [`csram`], [`typeconv`], [`arch`]
//! - **Core contribution**: [`lutgemv`] (LUT-based GEMV + Pattern Reuse
//!   Table, executed by a tiled backend with lane-parallel i32 plane
//!   accumulation over the persistent shared [`runtime::WorkerPool`],
//!   bit-exact at every thread count), [`sim`] (tensor-level scheduling +
//!   ping-pong pipeline)
//! - **Evaluation substrate**: [`baselines`] (ARM / AMX / GPU / Neural
//!   Cache models), [`model`] (transformer shape inventory — plus the
//!   executable multi-layer KV-cached decode model every serving token
//!   runs through), [`cost`] (tokens-per-dollar and overhead accounting)
//! - **Serving system**: [`coordinator`] (multi-user batched serving),
//!   [`runtime`] (PJRT execution of the AOT-compiled JAX/Pallas model)
//! - **Support**: [`util`]

pub mod arch;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod csram;
pub mod isa;
pub mod lutgemv;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod typeconv;
pub mod util;
