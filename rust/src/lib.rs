//! # SAIL — SRAM-Accelerated LLM Inference with LUT-based GEMV
//!
//! A full-system reproduction of the SAIL paper (cs.AR 2025): a near-cache
//! processing-in-memory architecture for quantized LLM inference, built as
//! a Rust + JAX/Pallas stack that both *models* the hardware (cycle
//! models, simulators, paper-table regenerators) and *executes* the
//! algorithm for real (a bit-exact LUT-GEMV engine serving a multi-layer
//! KV-cached transformer under a multi-user batching coordinator).
//!
//! **Start here:** `README.md` (repository root) for the quick tour and
//! build/run commands, and `ARCHITECTURE.md` for the full layer map, the
//! decode data path from manifest to token stream, and where each of the
//! paper's three innovations lives in the code.
//!
//! ## Layer map (bottom-up)
//!
//! - **Substrates**: [`quant`] (group-wise Q2–Q8 weights, int8
//!   activations, dense bit-packing), [`isa`] (the `lutmm_1k`
//!   instruction), [`csram`] (compute-SRAM geometry), [`typeconv`]
//!   (Algorithm 1 in-memory int→fp32), [`arch`] (cache/DRAM/NoC models)
//! - **Core contribution**: [`lutgemv`] — LUT-based GEMV + Pattern Reuse
//!   Table, executed by a tiled backend with lane-parallel i32 plane
//!   accumulation over the persistent NUMA-aware
//!   [`runtime::WorkerPool`]; bit-exact at every thread count and
//!   placement. [`sim`] adds tensor-level scheduling + the ping-pong
//!   pipeline
//! - **Evaluation substrate**: [`baselines`] (ARM / AMX / GPU / Neural
//!   Cache models), [`model`] (transformer shape inventory — plus the
//!   executable multi-layer KV-cached decode model every serving token
//!   runs through), [`cost`] (tokens-per-dollar and overhead accounting)
//! - **Serving system**: [`coordinator`] (multi-user iteration-level
//!   batched serving), [`runtime`] (worker pool + NUMA topology/placement,
//!   and PJRT execution of the AOT-compiled JAX/Pallas model)
//! - **Reporting**: [`report`] (paper table/figure regenerators);
//!   **support**: [`util`]
//!
//! ## The invariants everything leans on
//!
//! - **Bit-exactness**: [`lutgemv::LutGemvEngine`] reduces the same
//!   integers in the same per-column order as the naive quantized dot
//!   product, then applies float scales — so LUT execution, tiling,
//!   threading, lane-parallel i32 accumulation, and NUMA placement are
//!   all *invisible in the output*, and the serving layer inherits
//!   bit-identical token streams at every pool width and placement
//!   policy.
//! - **The i32 range proof** (`lutgemv::planes`): per scale group,
//!   `|LUT entry| ≤ Σ|w|` and every partial sum is bounded by
//!   `Σ|w| · (2^act_bits − 1)`; when that fits `i32`, the narrow lane
//!   kernels compute the very same integers as the i64 reference, else
//!   the engine falls back automatically.
//! - **KV byte accounting**: the executable [`model::KvCache`] allocates
//!   its element payload exactly as [`model::KvCacheSpec::seq_bytes`]
//!   accounts it, so the capacity/cost models and the running system can
//!   never drift apart silently.
//! - **Determinism**: no global state, seeded PRNGs, fixed sequential
//!   float reduction orders outside the integer kernels — the same
//!   request stream yields the same tokens on any machine.

pub mod arch;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod csram;
pub mod isa;
pub mod lutgemv;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod typeconv;
pub mod util;
