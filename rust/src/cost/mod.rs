//! Cost-efficiency and hardware-overhead models (paper §V-H, §V-I, §V-J).
//!
//! - [`Platform`] carries Table IV's GCP monthly prices;
//! - [`tokens_per_dollar`] computes the TPD metric:
//!   `TPD = tokens/s × 30 days / monthly price`;
//! - [`overhead`] reproduces Table V and the §V-I overhead accounting
//!   (C-SRAM capacity, area, power).

pub mod energy;
pub mod overhead;

/// A priced deployment platform (Table IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    pub name: &'static str,
    pub monthly_usd: f64,
}

impl Platform {
    /// 5-core CPU w/ 32 GB DRAM — $292.31/month.
    pub fn cpu_5core() -> Self {
        Platform { name: "5-core CPU", monthly_usd: 292.31 }
    }

    /// 16-core CPU w/ 32 GB DRAM — $665.45/month.
    pub fn cpu_16core() -> Self {
        Platform { name: "16-core CPU", monthly_usd: 665.45 }
    }

    /// 2-core CPU + 1×V100 (16 GB VRAM) — $1861.50/month.
    pub fn gpu_1xv100() -> Self {
        Platform { name: "1xV100", monthly_usd: 1861.5 }
    }

    /// 2-core CPU + 4×V100 — $7446.00/month.
    pub fn gpu_4xv100() -> Self {
        Platform { name: "4xV100", monthly_usd: 7446.0 }
    }

    /// SAIL deploys on the 16-core CPU node; the added silicon is ~2% of
    /// the SoC (§V-J), which we surface as a 2% price uplift to keep the
    /// comparison conservative.
    pub fn sail_16core() -> Self {
        Platform { name: "SAIL (16-core)", monthly_usd: 665.45 * 1.02 }
    }

    /// Single-thread SAIL on the small node (Fig 13's SAIL-1T).
    pub fn sail_5core() -> Self {
        Platform { name: "SAIL-1T (5-core)", monthly_usd: 292.31 * 1.02 }
    }
}

/// Tokens per dollar: tokens/s sustained for 30 days per monthly dollar.
pub fn tokens_per_dollar(tokens_per_sec: f64, platform: Platform) -> f64 {
    let tokens_per_month = tokens_per_sec * 30.0 * 24.0 * 3600.0;
    tokens_per_month / platform.monthly_usd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_prices() {
        assert_eq!(Platform::cpu_5core().monthly_usd, 292.31);
        assert_eq!(Platform::cpu_16core().monthly_usd, 665.45);
        assert_eq!(Platform::gpu_1xv100().monthly_usd, 1861.5);
        assert_eq!(Platform::gpu_4xv100().monthly_usd, 7446.0);
    }

    #[test]
    fn tpd_arithmetic() {
        // 1 tok/s on the 16-core node: 2.592M tokens / $665.45.
        let tpd = tokens_per_dollar(1.0, Platform::cpu_16core());
        assert!((tpd - 2_592_000.0 / 665.45).abs() < 1.0);
    }

    #[test]
    fn headline_cost_ratios() {
        // §I: SAIL up to 19.9× tokens/dollar vs the ARM CPU baseline and
        // up to 7.04× vs V100 — check our models land in that regime for
        // the favourable configuration (7B-Q2, batch 8).
        use crate::baselines::{CpuModel, GpuModel};
        use crate::model::ModelConfig;
        use crate::quant::QuantLevel;
        use crate::sim::SailPerfModel;
        let m = ModelConfig::llama2_7b();
        let q = QuantLevel::Q2;
        let sail = SailPerfModel::paper_config(q, 16).tokens_per_sec(&m, 8);
        let arm = CpuModel::arm_n1().tokens_per_sec(&m, q, 16, 8);
        let sail_tpd = tokens_per_dollar(sail, Platform::sail_16core());
        let arm_tpd = tokens_per_dollar(arm, Platform::cpu_16core());
        let ratio = sail_tpd / arm_tpd;
        assert!((5.0..=35.0).contains(&ratio), "SAIL/ARM TPD ratio {ratio}");

        // vs V100 at Q2 (GPU quant kernels don't speed up below Q4; use Q4
        // bytes as the GPU's effective floor, favouring the GPU).
        let gpu = GpuModel::v100();
        if let Some((gr, _)) = gpu.best_tokens_per_sec(&m, QuantLevel::Q4, 2048) {
            let gpu_tpd = tokens_per_dollar(gr, Platform::gpu_1xv100());
            let gratio = sail_tpd / gpu_tpd;
            assert!((1.5..=15.0).contains(&gratio), "SAIL/V100 TPD ratio {gratio}");
        } else {
            panic!("V100 7B-Q4@2K must fit");
        }
    }
}
