//! Hardware-overhead accounting (paper §III-D, §V-I, Table V).
//!
//! All constants are the paper's published synthesis results (FreePDK-45,
//! PyMTL3 + OpenRAM, Synopsys DC + Cadence Innovus); this module
//! reproduces the derived percentages and the Table V comparison rows.

use crate::csram::CSramGeometry;

/// Per-PRT synthesis figures (§III-D).
pub const PRT_AREA_MM2: f64 = 0.0012;
pub const PRT_POWER_MW: f64 = 0.25;
/// DFM count in the evaluated system.
pub const DFM_COUNT: u32 = 8;

/// §V-I accounting for the evaluated SAIL configuration.
#[derive(Debug, Clone, Copy)]
pub struct OverheadModel {
    pub geom: CSramGeometry,
    /// Hardware threads (each controls two C-SRAM blocks).
    pub threads: u32,
    /// LLC capacity in bytes (32 MB).
    pub llc_bytes: u64,
}

impl Default for OverheadModel {
    fn default() -> Self {
        OverheadModel {
            geom: CSramGeometry::default(),
            threads: 16,
            llc_bytes: 32 * 1024 * 1024,
        }
    }
}

impl OverheadModel {
    /// C-SRAM bytes per thread: two 256×512-bit blocks = 32 KB (§V-I).
    pub fn csram_bytes_per_thread(&self) -> u64 {
        2 * self.geom.capacity_bytes()
    }

    /// Total added C-SRAM capacity.
    pub fn total_csram_bytes(&self) -> u64 {
        self.threads as u64 * self.csram_bytes_per_thread()
    }

    /// Capacity overhead relative to the LLC (§V-I: "only about 1.6%").
    pub fn capacity_overhead_pct(&self) -> f64 {
        self.total_csram_bytes() as f64 / self.llc_bytes as f64 * 100.0
    }

    /// PRT aggregate area (mm²) — "<0.01 mm²" for eight DFMs.
    pub fn prt_total_area_mm2(&self) -> f64 {
        DFM_COUNT as f64 * PRT_AREA_MM2
    }

    /// PRT aggregate power (mW) — "under 2 mW".
    pub fn prt_total_power_mw(&self) -> f64 {
        DFM_COUNT as f64 * PRT_POWER_MW
    }

    /// System-level area overhead (Table V: "~2%"): the C-SRAM arrays are
    /// ~10% extra area *at the SRAM level* (per [9]); amortized over a die
    /// where the LLC is ~20% of area, the system-level figure is ~2%.
    pub fn system_area_overhead_pct(&self) -> f64 {
        let sram_level = 10.0;
        let llc_die_share = 0.20;
        sram_level * llc_die_share
    }

    /// SRAM-level energy overhead (per [9], §V-I).
    pub fn sram_energy_overhead_pct(&self) -> f64 {
        20.0
    }
}

/// One row of Table V.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    pub approach: &'static str,
    pub hw_overhead: &'static str,
    pub sys_overhead: &'static str,
}

/// Table V's comparison rows, verbatim.
pub fn table5_rows() -> Vec<OverheadRow> {
    vec![
        OverheadRow {
            approach: "Large-scale ASICs (TPU)",
            hw_overhead: "Large buffers and dedicated logics",
            sys_overhead: "Limited memory scalability",
        },
        OverheadRow {
            approach: "Small-scale ASICs (AMX)",
            hw_overhead: "Extra accelerator for tile-based MM",
            sys_overhead: "Special instructions and compiler",
        },
        OverheadRow {
            approach: "PIMs (EVE)",
            hw_overhead: "Compute peripherals (~10% area)",
            sys_overhead: "New instructions & OS modifications",
        },
        OverheadRow {
            approach: "SAIL",
            hw_overhead: "Minimal CPU and cache modifications (~2% area)",
            sys_overhead: "Only one instruction; standard memory hierarchy",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_overhead_matches_paper() {
        let o = OverheadModel::default();
        assert_eq!(o.csram_bytes_per_thread(), 32 * 1024);
        assert_eq!(o.total_csram_bytes(), 512 * 1024);
        // §V-I: "only about 1.6% compared with our 32MB LLC".
        assert!((o.capacity_overhead_pct() - 1.5625).abs() < 1e-9);
    }

    #[test]
    fn prt_aggregates_match_paper() {
        let o = OverheadModel::default();
        assert!(o.prt_total_area_mm2() < 0.01);
        assert!(o.prt_total_power_mw() <= 2.0);
    }

    #[test]
    fn system_area_is_about_2pct() {
        let o = OverheadModel::default();
        assert!((o.system_area_overhead_pct() - 2.0).abs() < 0.5);
    }

    #[test]
    fn table5_has_sail_with_single_instruction() {
        let rows = table5_rows();
        assert_eq!(rows.len(), 4);
        let sail = rows.last().unwrap();
        assert_eq!(sail.approach, "SAIL");
        assert!(sail.sys_overhead.to_lowercase().contains("one instruction"));
    }
}
