//! Energy-per-token model.
//!
//! The TPD metric (§V-H) folds energy into the GCP price; this module
//! makes the energy term explicit so the "C-SRAM energy cost ≈ 20% at
//! the SRAM level" claim (§V-I, via [9]) can be connected to end-to-end
//! joules per token. Power figures: C-SRAM 37.076 mW/array (paper
//! Table I), ARM N1 core ≈ 1.2 W @3 GHz (Neoverse-N1 platform paper),
//! DDR4 ≈ 15 pJ/bit transferred, V100 board 300 W TDP, A100 400 W.

use crate::baselines::{CpuModel, GpuModel};
use crate::model::ModelConfig;
use crate::quant::QuantLevel;
use crate::sim::SailPerfModel;

/// Energy rates used by the model.
#[derive(Debug, Clone, Copy)]
pub struct EnergyRates {
    pub cpu_core_w: f64,
    pub csram_array_w: f64,
    pub dram_pj_per_bit: f64,
    pub gpu_board_w: f64,
    /// Uncore/SoC static power.
    pub soc_static_w: f64,
}

impl Default for EnergyRates {
    fn default() -> Self {
        EnergyRates {
            cpu_core_w: 1.2,
            csram_array_w: 0.037076,
            dram_pj_per_bit: 15.0,
            gpu_board_w: 300.0,
            soc_static_w: 10.0,
        }
    }
}

/// Joules per generated token on SAIL: active cores (DFM control) +
/// C-SRAM arrays + weight DRAM traffic + static.
pub fn sail_joules_per_token(
    m: &ModelConfig,
    level: QuantLevel,
    threads: u32,
    batch: usize,
    rates: EnergyRates,
) -> f64 {
    let perf = SailPerfModel::paper_config(level, threads);
    let iter_secs = 1.0 / perf.tokens_per_sec(m, batch) * batch as f64;
    let power = rates.soc_static_w
        + threads as f64 * 0.3 * rates.cpu_core_w   // cores mostly idle (DFM control)
        + (threads * 2) as f64 * rates.csram_array_w;
    let dram_j =
        m.weight_bytes(level, 32) as f64 * 8.0 * rates.dram_pj_per_bit * 1e-12;
    (power * iter_secs + dram_j) / batch as f64
}

/// Joules per token on the ARM baseline (all cores active + its own
/// DRAM traffic).
pub fn arm_joules_per_token(
    m: &ModelConfig,
    level: QuantLevel,
    threads: u32,
    batch: usize,
    rates: EnergyRates,
) -> f64 {
    let arm = CpuModel::arm_n1();
    let iter_secs = 1.0 / arm.tokens_per_sec(m, level, threads, batch) * batch as f64;
    let power = rates.soc_static_w + threads as f64 * rates.cpu_core_w;
    let dram_j =
        m.weight_bytes(level, 32) as f64 * 8.0 * rates.dram_pj_per_bit * 1e-12;
    (power * iter_secs + dram_j) / batch as f64
}

/// Joules per token on a GPU at its best feasible batch.
pub fn gpu_joules_per_token(
    gpu: &GpuModel,
    m: &ModelConfig,
    level: QuantLevel,
    ctx: usize,
    rates: EnergyRates,
) -> Option<f64> {
    let (rate, _) = gpu.best_tokens_per_sec(m, level, ctx)?;
    Some(rates.gpu_board_w / rate)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sail_beats_arm_on_energy() {
        let m = ModelConfig::llama2_7b();
        let r = EnergyRates::default();
        let s = sail_joules_per_token(&m, QuantLevel::Q4, 16, 8, r);
        let a = arm_joules_per_token(&m, QuantLevel::Q4, 16, 1, r);
        assert!(s < a / 3.0, "SAIL {s} J/tok vs ARM {a} J/tok");
    }

    #[test]
    fn csram_power_share_is_small() {
        // §V-I: the added arrays are ~1.2 W for 32 arrays — a small
        // fraction of socket power (the 20% figure is at the SRAM level,
        // not system level).
        let r = EnergyRates::default();
        let arrays_w = 32.0 * r.csram_array_w;
        let socket_w = r.soc_static_w + 16.0 * r.cpu_core_w;
        assert!(arrays_w / socket_w < 0.05, "{}", arrays_w / socket_w);
    }

    #[test]
    fn gpu_energy_reasonable() {
        let m = ModelConfig::llama2_7b();
        let g = GpuModel::v100();
        let j = gpu_joules_per_token(&g, &m, QuantLevel::Q4, 512, EnergyRates::default())
            .unwrap();
        // ~300 W / ~200 tok/s ≈ 1.5 J/token.
        assert!((0.5..=5.0).contains(&j), "{j}");
        // Does-not-fit propagates.
        assert!(gpu_joules_per_token(
            &g,
            &ModelConfig::llama2_13b(),
            QuantLevel::Q8,
            4096,
            EnergyRates::default()
        )
        .is_none());
    }
}
