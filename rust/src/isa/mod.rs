//! The `lutmm_1k` RISC-V ISA extension (paper Fig 8).
//!
//! A single new instruction drives the whole accelerator: a tiled
//! `[1,1024]×[1024,1024]` LUT-GEMV. Bit layout (Fig 8):
//!
//! ```text
//! [31:27] [26:25] [24:20] [19:15] [14:12] [11:7] [6:0]
//!   loc     sc      rw      ri      ql      rd   opcode
//! ```
//!
//! - `loc`  (5b): which 1024-wide tile of the full GEMV this is,
//! - `sc`   (2b): log2 scale factor — full matrix width = 1024 × 2^sc,
//! - `rw`   (5b): register holding the weight-tile base address,
//! - `ri`   (5b): register holding the input-vector base address,
//! - `ql`   (3b): quantization level (Q2/3/4/5/6/8),
//! - `rd`   (5b): register holding the result base address,
//! - `opcode` (7b): custom-0 (0x0B), the RISC-V custom opcode space.
//!
//! The coordinator emits streams of these; the simulator decodes and
//! executes them (see `sim::`). Encode∘decode is bit-exact and tested
//! exhaustively over field ranges.

use crate::quant::QuantLevel;

/// The custom-0 RISC-V opcode used by `lutmm_1k`.
pub const LUTMM_OPCODE: u32 = 0x0B;

/// Tile dimension the instruction contracts to (paper §IV-A).
pub const TILE_DIM: usize = 1024;

/// Decoded `lutmm_1k` instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LutMm1k {
    /// Tile index within the full GEMV (column-tile position).
    pub loc: u8,
    /// log2(width/1024): full weight width = 1024 << sc.
    pub sc: u8,
    /// Weight base-address register index.
    pub rw: u8,
    /// Input base-address register index.
    pub ri: u8,
    /// Quantization level.
    pub ql: QuantLevel,
    /// Result base-address register index.
    pub rd: u8,
}

/// Errors from decoding a 32-bit word that is not a valid `lutmm_1k`.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum IsaError {
    #[error("opcode {0:#x} is not lutmm_1k ({LUTMM_OPCODE:#x})")]
    BadOpcode(u32),
    #[error("ql field {0} does not name a quantization level")]
    BadQl(u8),
    #[error("loc {loc} out of range for sc {sc} (width {width})")]
    LocOutOfRange { loc: u8, sc: u8, width: usize },
}

impl LutMm1k {
    /// Construct, validating that `loc` addresses a tile inside the matrix
    /// width implied by `sc`.
    pub fn new(loc: u8, sc: u8, rw: u8, ri: u8, ql: QuantLevel, rd: u8) -> Result<Self, IsaError> {
        assert!(loc < 32 && sc < 4 && rw < 32 && ri < 32 && rd < 32, "field width overflow");
        let tiles = 1usize << sc;
        if (loc as usize) >= tiles {
            return Err(IsaError::LocOutOfRange { loc, sc, width: TILE_DIM << sc });
        }
        Ok(LutMm1k { loc, sc, rw, ri, ql, rd })
    }

    /// Full weight-matrix width implied by `sc` (paper example: sc=3 →
    /// width 8192).
    pub fn full_width(&self) -> usize {
        TILE_DIM << self.sc
    }

    /// Column range `[start, end)` of the tile this instruction computes
    /// (paper example: loc=5 → columns 5120..6144).
    pub fn tile_columns(&self) -> (usize, usize) {
        let start = self.loc as usize * TILE_DIM;
        (start, start + TILE_DIM)
    }

    /// Encode to the 32-bit instruction word.
    pub fn encode(&self) -> u32 {
        ((self.loc as u32) << 27)
            | ((self.sc as u32) << 25)
            | ((self.rw as u32) << 20)
            | ((self.ri as u32) << 15)
            | ((self.ql.ql_code() as u32) << 12)
            | ((self.rd as u32) << 7)
            | LUTMM_OPCODE
    }

    /// Decode a 32-bit instruction word.
    pub fn decode(word: u32) -> Result<Self, IsaError> {
        let opcode = word & 0x7F;
        if opcode != LUTMM_OPCODE {
            return Err(IsaError::BadOpcode(opcode));
        }
        let ql_code = ((word >> 12) & 0x7) as u8;
        let ql = QuantLevel::from_ql_code(ql_code).ok_or(IsaError::BadQl(ql_code))?;
        LutMm1k::new(
            ((word >> 27) & 0x1F) as u8,
            ((word >> 25) & 0x3) as u8,
            ((word >> 20) & 0x1F) as u8,
            ((word >> 15) & 0x1F) as u8,
            ql,
            ((word >> 7) & 0x1F) as u8,
        )
    }
}

impl std::fmt::Display for LutMm1k {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "lutmm_1k loc={} sc={} rw=x{} ri=x{} ql={} rd=x{}",
            self.loc, self.sc, self.rw, self.ri, self.ql, self.rd
        )
    }
}

/// Emit the instruction sequence for a full `[1,K]×[K,N]` GEMV as tiles of
/// `lutmm_1k` (K, N multiples of 1024; paper: "larger GEMV operations can
/// be realized by repeating the lutmm_1k instruction").
pub fn emit_gemv(n_cols: usize, ql: QuantLevel, rw: u8, ri: u8, rd: u8) -> Result<Vec<LutMm1k>, IsaError> {
    assert!(n_cols % TILE_DIM == 0, "GEMV width must be a multiple of 1024");
    let tiles = n_cols / TILE_DIM;
    let sc = (tiles as f64).log2().ceil() as u8;
    assert!(sc < 4, "sc field supports widths up to 8192; wider GEMVs need multiple base addrs");
    (0..tiles)
        .map(|t| LutMm1k::new(t as u8, sc, rw, ri, ql, rd))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_exhaustive_fields() {
        for loc in 0..8u8 {
            for sc in 0..4u8 {
                if loc as usize >= (1 << sc) {
                    continue;
                }
                for &ql in &QuantLevel::ALL {
                    let i = LutMm1k::new(loc, sc, 31, 0, ql, 17).unwrap();
                    assert_eq!(LutMm1k::decode(i.encode()).unwrap(), i);
                }
            }
        }
    }

    #[test]
    fn paper_example_sc3_loc5() {
        // §IV-A: sc=3 → width 8192; loc=5 → columns 5120..6144.
        let i = LutMm1k::new(5, 3, 1, 2, QuantLevel::Q4, 3).unwrap();
        assert_eq!(i.full_width(), 8192);
        assert_eq!(i.tile_columns(), (5120, 6144));
    }

    #[test]
    fn bad_opcode_rejected() {
        assert_eq!(LutMm1k::decode(0x33), Err(IsaError::BadOpcode(0x33)));
    }

    #[test]
    fn bad_ql_rejected() {
        // Craft a word with ql=7.
        let w = (7u32 << 12) | LUTMM_OPCODE;
        assert_eq!(LutMm1k::decode(w), Err(IsaError::BadQl(7)));
    }

    #[test]
    fn loc_range_enforced() {
        // sc=0 → single tile, loc=1 invalid.
        assert!(matches!(
            LutMm1k::new(1, 0, 0, 0, QuantLevel::Q2, 0),
            Err(IsaError::LocOutOfRange { .. })
        ));
    }

    #[test]
    fn emit_gemv_covers_all_tiles() {
        let insts = emit_gemv(4096, QuantLevel::Q4, 1, 2, 3).unwrap();
        assert_eq!(insts.len(), 4);
        for (t, i) in insts.iter().enumerate() {
            assert_eq!(i.loc as usize, t);
            assert_eq!(i.full_width(), 4096);
            assert_eq!(i.tile_columns(), (t * 1024, (t + 1) * 1024));
        }
    }

    #[test]
    fn display_readable() {
        let i = LutMm1k::new(0, 0, 1, 2, QuantLevel::Q8, 3).unwrap();
        assert_eq!(i.to_string(), "lutmm_1k loc=0 sc=0 rw=x1 ri=x2 ql=Q8 rd=x3");
    }
}
