//! Memory-system architecture models (paper Table I).
//!
//! The evaluated system: 32 OOO cores at 3 GHz, a 32 MB shared LLC split
//! into 32 slices of 1 MB on an 8×8 mesh NoC (32 B/cycle links at 2 GHz),
//! and 8 channels of DDR4-3200. One C-SRAM array sits beside each slice.
//!
//! These models provide the *transfer-time* half of the pipeline simulator;
//! the compute half lives in `csram`/`lutgemv`.

pub mod cache;
pub mod dram;
pub mod hasher;
pub mod noc;

pub use cache::LlcConfig;
pub use dram::DramConfig;
pub use hasher::AddressHasher;
pub use noc::NocConfig;

/// Full system architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub cores: u32,
    pub clock_ghz: f64,
    pub llc: LlcConfig,
    pub noc: NocConfig,
    pub dram: DramConfig,
    /// C-SRAM arrays (Near-Data Processors), one per LLC slice.
    pub ndp_count: u32,
}

impl Default for SystemConfig {
    /// The paper's Table I configuration.
    fn default() -> Self {
        SystemConfig {
            cores: 32,
            clock_ghz: 3.0,
            llc: LlcConfig::default(),
            noc: NocConfig::default(),
            dram: DramConfig::default(),
            ndp_count: 32,
        }
    }
}

impl SystemConfig {
    /// Convert a cycle count at the system clock to seconds.
    pub fn cycles_to_secs(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e9)
    }

    /// Convert seconds to system-clock cycles (rounding to nearest).
    pub fn secs_to_cycles(&self, secs: f64) -> u64 {
        (secs * self.clock_ghz * 1e9).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let s = SystemConfig::default();
        assert_eq!(s.cores, 32);
        assert_eq!(s.llc.total_bytes(), 32 * 1024 * 1024);
        assert_eq!(s.llc.slices, 32);
        assert_eq!(s.ndp_count, 32);
    }

    #[test]
    fn cycle_second_roundtrip() {
        let s = SystemConfig::default();
        assert_eq!(s.secs_to_cycles(1.0), 3_000_000_000);
        assert!((s.cycles_to_secs(3_000_000_000) - 1.0).abs() < 1e-12);
        assert_eq!(s.secs_to_cycles(s.cycles_to_secs(12345)), 12345);
    }
}
