//! DRAM model (Table I: 8 channels of DDR4-3200).
//!
//! Token generation is memory-bound: the decisive quantity is sustained
//! sequential read bandwidth for streaming weight tensors into the LLC.
//! DDR4-3200 peaks at 25.6 GB/s per channel; sustained efficiency for the
//! streaming access pattern is ~80% (row-buffer-friendly, prefetched).

/// DRAM configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramConfig {
    pub channels: u32,
    /// MT/s per channel (DDR4-3200 → 3200).
    pub mt_per_sec: u64,
    /// Bus width per channel in bytes (DDR4 → 8).
    pub bus_bytes: u32,
    /// Sustained fraction of peak for streaming reads.
    pub efficiency: f64,
    /// First-access latency in nanoseconds (row activate + CAS).
    pub latency_ns: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            channels: 8,
            mt_per_sec: 3200,
            bus_bytes: 8,
            efficiency: 0.80,
            latency_ns: 90.0,
        }
    }
}

impl DramConfig {
    /// The SAIL system's DRAM, reading Table I's "8 channels 3200 MHz
    /// DDR4" as the DDR I/O *clock* (→ 6400 MT/s, 409.6 GB/s peak).
    ///
    /// Provenance note (EXPERIMENTS.md §Calibration): under the plain
    /// DDR4-3200-MT/s reading (204.8 GB/s peak) the paper's own Table II
    /// SAIL rows are unreachable — 7B-Q8 at 43.27 tok/s implies ≥310 GB/s
    /// of weight streaming. With the 6400 MT/s reading our first-
    /// principles pipeline lands within ~5% of Table II across Q2..Q8.
    pub fn sail_6400() -> Self {
        DramConfig { mt_per_sec: 6400, ..DramConfig::default() }
    }

    /// Peak bandwidth, bytes/sec.
    pub fn peak_bytes_per_sec(&self) -> f64 {
        self.channels as f64 * self.mt_per_sec as f64 * 1e6 * self.bus_bytes as f64
    }

    /// Sustained streaming bandwidth, bytes/sec.
    pub fn sustained_bytes_per_sec(&self) -> f64 {
        self.peak_bytes_per_sec() * self.efficiency
    }

    /// Seconds to stream `bytes` into the LLC.
    pub fn stream_secs(&self, bytes: u64) -> f64 {
        self.latency_ns * 1e-9 + bytes as f64 / self.sustained_bytes_per_sec()
    }

    /// System-clock cycles (at `clock_ghz`) to stream `bytes`.
    pub fn stream_cycles(&self, bytes: u64, clock_ghz: f64) -> u64 {
        (self.stream_secs(bytes) * clock_ghz * 1e9).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr4_3200_x8_peak() {
        let d = DramConfig::default();
        assert!((d.peak_bytes_per_sec() - 204.8e9).abs() < 1e6);
        assert!((d.sustained_bytes_per_sec() - 163.84e9).abs() < 1e6);
    }

    #[test]
    fn stream_time_monotone_in_bytes() {
        let d = DramConfig::default();
        let a = d.stream_secs(1 << 20);
        let b = d.stream_secs(1 << 24);
        assert!(b > a);
        // 16 MiB at ~164 GB/s ≈ 102 µs.
        assert!((b - 102e-6).abs() < 10e-6, "b={b}");
    }

    #[test]
    fn cycles_conversion() {
        let d = DramConfig::default();
        let bytes = 1u64 << 20;
        let c = d.stream_cycles(bytes, 3.0);
        let expect = (d.stream_secs(bytes) * 3e9).ceil() as u64;
        assert_eq!(c, expect);
        assert!(c > 0);
    }
}
