//! Network-on-Chip model (Table I: 32 B links, 1-cycle hop, 8×8 mesh at
//! 2 GHz — the ARM CMN-600 configuration).
//!
//! The NoC carries (a) DRAM→slice weight fills, (b) DFM input broadcasts to
//! C-SRAMs, and (c) result vectors back to the requesting core. SAIL's key
//! bandwidth argument (Fig 3) is that only `[1,N]` result vectors cross the
//! NoC instead of `[N,N]` weight tensors.

/// Mesh NoC configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocConfig {
    pub mesh_x: u32,
    pub mesh_y: u32,
    /// Link (flit) width in bytes.
    pub flit_bytes: u32,
    /// Router traversal latency per hop, in NoC cycles.
    pub hop_cycles: u64,
    /// NoC clock (GHz) — 2 GHz vs the 3 GHz core clock.
    pub clock_ghz: f64,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig { mesh_x: 8, mesh_y: 8, flit_bytes: 32, hop_cycles: 1, clock_ghz: 2.0 }
    }
}

/// Node coordinate on the mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Node {
    pub x: u32,
    pub y: u32,
}

impl NocConfig {
    pub fn nodes(&self) -> u32 {
        self.mesh_x * self.mesh_y
    }

    /// Position of node index i (row-major).
    pub fn node(&self, i: u32) -> Node {
        assert!(i < self.nodes());
        Node { x: i % self.mesh_x, y: i / self.mesh_x }
    }

    /// Manhattan hop count between two node indices (XY routing).
    pub fn hops(&self, a: u32, b: u32) -> u32 {
        let (pa, pb) = (self.node(a), self.node(b));
        pa.x.abs_diff(pb.x) + pa.y.abs_diff(pb.y)
    }

    /// NoC cycles for a unicast message of `bytes` between nodes `a` and
    /// `b`: head latency (hops) + serialization (flits), wormhole-routed.
    pub fn unicast_cycles(&self, a: u32, b: u32, bytes: u64) -> u64 {
        let flits = (bytes + self.flit_bytes as u64 - 1) / self.flit_bytes as u64;
        self.hops(a, b) as u64 * self.hop_cycles + flits.max(1)
    }

    /// NoC cycles for a broadcast of `bytes` from node `src` to all slices
    /// (the DFM input broadcast). Tree broadcast: head latency is the max
    /// hop distance, serialization paid once per link (flits).
    pub fn broadcast_cycles(&self, src: u32, bytes: u64) -> u64 {
        let max_hops = (0..self.nodes()).map(|n| self.hops(src, n)).max().unwrap_or(0);
        let flits = (bytes + self.flit_bytes as u64 - 1) / self.flit_bytes as u64;
        max_hops as u64 * self.hop_cycles + flits.max(1)
    }

    /// Convert NoC cycles to seconds.
    pub fn cycles_to_secs(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e9)
    }

    /// Bisection bandwidth in bytes/sec — the aggregate ceiling the
    /// pipeline simulator enforces on simultaneous fills.
    pub fn bisection_bytes_per_sec(&self) -> f64 {
        // 8 links across the bisection × 32 B/cycle × 2 GHz.
        self.mesh_y as f64 * self.flit_bytes as f64 * self.clock_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_geometry() {
        let n = NocConfig::default();
        assert_eq!(n.nodes(), 64);
        assert_eq!(n.node(0), Node { x: 0, y: 0 });
        assert_eq!(n.node(63), Node { x: 7, y: 7 });
        assert_eq!(n.hops(0, 63), 14);
        assert_eq!(n.hops(7, 56), 14);
        assert_eq!(n.hops(5, 5), 0);
    }

    #[test]
    fn unicast_latency_components() {
        let n = NocConfig::default();
        // 64 B = 2 flits, 1 hop → 3 cycles.
        assert_eq!(n.unicast_cycles(0, 1, 64), 3);
        // zero-byte message still costs a head flit.
        assert_eq!(n.unicast_cycles(0, 1, 0), 2);
    }

    #[test]
    fn broadcast_bounded_by_diameter() {
        let n = NocConfig::default();
        // From a corner: diameter 14 hops + serialization.
        let c = n.broadcast_cycles(0, 1024);
        assert_eq!(c, 14 + 32);
        // From the center the head latency shrinks.
        assert!(n.broadcast_cycles(27, 1024) < c);
    }

    #[test]
    fn result_vs_weight_traffic_asymmetry() {
        // Fig 3's argument: moving a [1,4096] f32 result (16 KB) is ~3
        // orders cheaper than a [4096,4096] Q4 weight tile (8 MB).
        let n = NocConfig::default();
        let result = n.unicast_cycles(0, 63, 16 * 1024);
        let weights = n.unicast_cycles(0, 63, 8 * 1024 * 1024);
        assert!(weights > result * 400, "{weights} vs {result}");
    }

    #[test]
    fn bisection_bandwidth() {
        let n = NocConfig::default();
        assert!((n.bisection_bytes_per_sec() - 8.0 * 32.0 * 2e9).abs() < 1.0);
    }
}
