//! LLC slice geometry and the ping-pong partition (paper §III-A, Fig 4).
//!
//! The LLC is the staging buffer between DRAM and the C-SRAMs. SAIL splits
//! it into two halves used as a ping-pong buffer: while half A receives the
//! next weight tile from DRAM, the C-SRAMs read the current tile from half
//! B; roles swap each phase. This module models capacity and the
//! slice-internal bandwidth ("the internal bandwidth among LLC slices is
//! often underutilized") that makes C-SRAM fills cheap.

/// Shared-LLC configuration (Table I: 32 MB, 16-way, 58-cycle load-to-use,
/// 32 slices; 64 B lines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LlcConfig {
    pub slices: u32,
    pub slice_bytes: u64,
    pub line_bytes: u32,
    pub latency_cycles: u64,
    pub ways: u32,
    /// Slice-internal bandwidth to the adjacent C-SRAM: one full line per
    /// cycle per slice (the "very high data bandwidth to C-SRAM").
    pub internal_line_per_cycle: bool,
}

impl Default for LlcConfig {
    fn default() -> Self {
        LlcConfig {
            slices: 32,
            slice_bytes: 1024 * 1024,
            line_bytes: 64,
            latency_cycles: 58,
            ways: 16,
            internal_line_per_cycle: true,
        }
    }
}

impl LlcConfig {
    pub fn total_bytes(&self) -> u64 {
        self.slices as u64 * self.slice_bytes
    }

    /// Capacity of one ping-pong half across all slices.
    pub fn half_bytes(&self) -> u64 {
        self.total_bytes() / 2
    }

    /// Cycles to move `bytes` from a slice into its adjacent C-SRAM over
    /// the internal path (line-wide, one line per cycle per slice; the
    /// transfer is striped across all slices holding the tile).
    pub fn internal_transfer_cycles(&self, bytes: u64, slices_used: u32) -> u64 {
        assert!(slices_used >= 1 && slices_used <= self.slices);
        let lines = (bytes + self.line_bytes as u64 - 1) / self.line_bytes as u64;
        let per_slice = (lines + slices_used as u64 - 1) / slices_used as u64;
        per_slice + self.latency_cycles
    }

    /// External (NoC-side) bandwidth in bytes/cycle for a single slice —
    /// the bottleneck prior near-cache designs hit (paper §II-B point 3).
    pub fn external_bytes_per_cycle(&self) -> u64 {
        32 // one NoC flit
    }

    /// Does a weight tile of `bytes` fit in one ping-pong half?
    pub fn tile_fits_half(&self, bytes: u64) -> bool {
        bytes <= self.half_bytes()
    }
}

/// The ping-pong buffer state machine. The simulator drives `swap()` each
/// phase; the invariant — a half is never simultaneously written (DRAM
/// fill) and read (C-SRAM drain) — is enforced here and property-tested.
#[derive(Debug, Clone)]
pub struct PingPong {
    /// Which half DRAM currently writes into (0 or 1).
    write_half: u8,
    /// In-flight markers used to detect double-booking.
    writing: bool,
    reading: bool,
}

impl Default for PingPong {
    fn default() -> Self {
        Self::new()
    }
}

impl PingPong {
    pub fn new() -> Self {
        PingPong { write_half: 0, writing: false, reading: false }
    }

    pub fn write_half(&self) -> u8 {
        self.write_half
    }

    pub fn read_half(&self) -> u8 {
        1 - self.write_half
    }

    /// Begin the concurrent (fill, drain) phase.
    pub fn begin_phase(&mut self) {
        assert!(!self.writing && !self.reading, "phase already active");
        self.writing = true;
        self.reading = true;
    }

    /// Complete both sides and swap roles.
    pub fn end_phase_and_swap(&mut self) {
        assert!(self.writing && self.reading, "no active phase");
        self.writing = false;
        self.reading = false;
        self.write_half = 1 - self.write_half;
    }

    /// True while a phase is active.
    pub fn phase_active(&self) -> bool {
        self.writing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_table1() {
        let c = LlcConfig::default();
        assert_eq!(c.total_bytes(), 32 << 20);
        assert_eq!(c.half_bytes(), 16 << 20);
        assert_eq!(c.latency_cycles, 58);
    }

    #[test]
    fn internal_transfer_scales_with_slices() {
        let c = LlcConfig::default();
        let one = c.internal_transfer_cycles(1 << 20, 1);
        let all = c.internal_transfer_cycles(1 << 20, 32);
        assert!(one > all * 20, "striping must give ~32x: {one} vs {all}");
        // 1 MiB over 32 slices = 512 lines/slice + 58 latency.
        assert_eq!(all, 512 + 58);
    }

    #[test]
    fn q4_7b_layer_tile_fits_half() {
        // A 4096×4096 Q4 tile = 8 MiB < 16 MiB half. (Tensor-level
        // scheduling stages one layer's tensor at a time.)
        let c = LlcConfig::default();
        let tile = 4096u64 * 4096 / 2;
        assert!(c.tile_fits_half(tile));
    }

    #[test]
    fn pingpong_alternates() {
        let mut pp = PingPong::new();
        assert_eq!(pp.write_half(), 0);
        assert_eq!(pp.read_half(), 1);
        pp.begin_phase();
        pp.end_phase_and_swap();
        assert_eq!(pp.write_half(), 1);
        assert_eq!(pp.read_half(), 0);
        pp.begin_phase();
        pp.end_phase_and_swap();
        assert_eq!(pp.write_half(), 0);
    }

    #[test]
    #[should_panic(expected = "phase already active")]
    fn double_booking_detected() {
        let mut pp = PingPong::new();
        pp.begin_phase();
        pp.begin_phase();
    }

    #[test]
    fn halves_never_overlap() {
        let mut pp = PingPong::new();
        for _ in 0..100 {
            pp.begin_phase();
            assert_ne!(pp.write_half(), pp.read_half());
            pp.end_phase_and_swap();
        }
    }
}
