//! Address hasher (paper §IV-C).
//!
//! SAIL requires weights evenly distributed across cache slices so every
//! C-SRAM builds LUTs from its *nearest* data slice. Following the hasher
//! of US-7290116 cited by the paper: the lowest 9 bits of the address are
//! retained (512 B contiguity granularity) while the remaining bits are
//! scrambled into the slice index. The scramble is an XOR-fold of the
//! upper address bits — deterministic, invertible within a set, and
//! uniform for both sequential and strided streams.

/// Slice-interleaving address hasher.
#[derive(Debug, Clone, Copy)]
pub struct AddressHasher {
    /// log2(number of slices).
    slice_bits: u32,
    /// Contiguity granularity (paper: 512 B → 9 bits kept).
    pub granularity_bits: u32,
}

impl AddressHasher {
    /// `slices` must be a power of two (32 in the evaluated system).
    pub fn new(slices: u32) -> Self {
        assert!(slices.is_power_of_two(), "slice count must be a power of two");
        AddressHasher { slice_bits: slices.trailing_zeros(), granularity_bits: 9 }
    }

    pub fn slices(&self) -> u32 {
        1 << self.slice_bits
    }

    /// Map a physical address to a slice index. Bits [8:0] never affect
    /// the result (512 B blocks stay whole); all higher bits are XOR-folded
    /// so any stride ≥ 512 B distributes uniformly.
    pub fn slice_of(&self, addr: u64) -> u32 {
        if self.slice_bits == 0 {
            return 0;
        }
        let mut x = addr >> self.granularity_bits;
        // xor-fold the block number down to slice_bits, mixing with a
        // multiplicative scramble first so low-entropy strides spread.
        x = x.wrapping_mul(0x9E3779B97F4A7C15);
        let mut folded = 0u64;
        let mut v = x;
        while v != 0 {
            folded ^= v & ((1 << self.slice_bits) - 1);
            v >>= self.slice_bits;
        }
        folded as u32
    }

    /// Distribute a contiguous buffer `[base, base+len)` into per-slice
    /// byte counts — used by the simulator to check even weight spread.
    pub fn distribution(&self, base: u64, len: u64) -> Vec<u64> {
        let g = 1u64 << self.granularity_bits;
        let mut counts = vec![0u64; self.slices() as usize];
        let mut addr = base;
        let end = base + len;
        while addr < end {
            let block_end = (addr | (g - 1)) + 1;
            let take = block_end.min(end) - addr;
            counts[self.slice_of(addr) as usize] += take;
            addr = block_end;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn granularity_preserved() {
        let h = AddressHasher::new(32);
        let mut p = Prng::new(13);
        for _ in 0..1000 {
            let base = p.next_u64() & !0x1FF;
            let s = h.slice_of(base);
            for off in [0u64, 1, 63, 255, 511] {
                assert_eq!(h.slice_of(base + off), s, "offset {off} changed slice");
            }
        }
    }

    #[test]
    fn sequential_stream_is_uniform() {
        let h = AddressHasher::new(32);
        // An 8 MiB weight tensor: 16384 512-B blocks over 32 slices.
        let counts = h.distribution(0x4000_0000, 8 << 20);
        let expect = (8 << 20) / 32;
        for (s, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect as f64).abs() / expect as f64;
            assert!(dev < 0.15, "slice {s}: {c} vs {expect} ({dev:.2})");
        }
    }

    #[test]
    fn large_stride_still_uniform() {
        // Row-strided access (stride 16 KiB) must not alias to few slices.
        let h = AddressHasher::new(32);
        let mut counts = vec![0u64; 32];
        for i in 0..4096u64 {
            counts[h.slice_of(0x1000_0000 + i * 16384) as usize] += 1;
        }
        let expect = 4096 / 32;
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect as f64 * 0.5 && (c as f64) < expect as f64 * 1.6,
                "slice {s}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn distribution_conserves_bytes() {
        let h = AddressHasher::new(8);
        let counts = h.distribution(12345, 1_000_000);
        assert_eq!(counts.iter().sum::<u64>(), 1_000_000);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn pow2_enforced() {
        AddressHasher::new(12);
    }
}
