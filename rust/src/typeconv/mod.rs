//! In-memory parallel type conversion (paper Algorithm 1, §III-E).
//!
//! Converts an n-bit signed integer (n ≤ 25) to an IEEE-754 single-precision
//! float using only the logical operations available to bitline in-SRAM
//! computing — the simulation here mirrors the algorithm line by line and
//! counts logical ops, so the cycle model can charge the paper's published
//! cost of `3n²/2 + 39(n−1)` cycles (`O(n²/2 + 13(n−1))` logical ops).
//!
//! The algorithm operates on a sign bit plus an (n−1)-bit magnitude
//! (line 12 copies `a_{n-1}` straight into the IEEE sign bit, and the
//! mantissa path multiplies the remaining bits as an unsigned value), i.e.
//! sign-magnitude. [`int_to_f32`] accepts a two's-complement integer and
//! performs the sign-magnitude fold first, as the RCU would when loading.
//! Exceptional cases (zero) are detected with a wired-NOR zero flag — the
//! paper's algorithm leaves zero implicit; hardware gates the result to
//! +0.0. NaN/subnormals cannot arise from integer inputs.
//!
//! Because the C-SRAM computes bit-serially *across* a 512-bit row, one
//! invocation converts one element per column: a whole row of elements
//! converts in the same `3n²/2 + 39(n−1)` cycles. [`batch_cycles`] exposes
//! that parallelism to the pipeline simulator.

/// Maximum supported input width (paper: n ≤ 25; at n = 25 the n−2 = 23
/// magnitude bits exactly fill the f32 mantissa).
pub const MAX_BITS: u32 = 25;

/// Result of a conversion, including the logical-op count the in-SRAM
/// execution would incur (used to validate the cycle formula).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvResult {
    /// IEEE-754 bit pattern of the converted value.
    pub bits: u32,
    /// Logical (AND/OR/XOR/shift-step) operations executed.
    pub logic_ops: u64,
}

/// Cycles for one in-SRAM conversion wave (paper §III-E):
/// `3n²/2 + 39(n−1)`. Every column of the wave converts in parallel.
pub const fn cycle_cost(n: u32) -> u64 {
    let n = n as u64;
    (3 * n * n) / 2 + 39 * (n - 1)
}

/// Upper bound on logical ops for *this* implementation.
///
/// The paper states `O(n²/2 + 13(n−1))`; its Algorithm 1 listing keeps a
/// 5-bit exponent accumulator and writes only `r[27:23]`, which cannot
/// represent the biased exponent 127+p ≥ 126 — a known inconsistency in the
/// published pseudocode. Bit-exact IEEE-754 output needs the full 8-bit
/// exponent path, which raises the linear constant (8-bit ripple adds in
/// the popcount loop) but not the quadratic term. Our bound:
/// `n²/2 + 29(n−1) + 18`. The *cycle* model charged by the simulator stays
/// the paper's published `3n²/2 + 39(n−1)` (see [`cycle_cost`]).
pub const fn op_bound(n: u32) -> u64 {
    let n = n as u64;
    (n * n) / 2 + 29 * (n - 1) + 18
}

/// Cycles to convert `count` elements with `columns` bit-serial columns
/// available per C-SRAM array and `arrays` arrays operating in parallel.
pub fn batch_cycles(n: u32, count: usize, columns: usize, arrays: usize) -> u64 {
    assert!(columns > 0 && arrays > 0);
    let per_wave = columns * arrays;
    let waves = (count + per_wave - 1) / per_wave;
    waves as u64 * cycle_cost(n)
}

/// Convert a two's-complement `n`-bit signed integer to f32, simulating
/// Algorithm 1 bit-by-bit. Returns the IEEE bits and the logical-op count.
///
/// Panics if `a` is not representable in `n` bits or `n` is out of range.
pub fn int_to_f32_traced(a: i32, n: u32) -> ConvResult {
    assert!((2..=MAX_BITS).contains(&n), "n must be in 2..=25");
    let lo = -(1i64 << (n - 1));
    let hi = (1i64 << (n - 1)) - 1;
    assert!((a as i64) >= lo && (a as i64) <= hi, "{a} not representable in {n} bits");

    let mut ops: u64 = 0;

    // Sign-magnitude fold (RCU pre-step): sign bit + (n−1)-bit magnitude.
    // Cost: one conditional bit-serial negate, ~n ops — charged below.
    let sign = (a < 0) as u32;
    let mag = a.unsigned_abs(); // fits in n−1 bits except a == lo (|lo| = 2^(n−1))
    ops += n as u64; // bit-serial negate / pass-through
    if mag >> (n - 1) != 0 {
        // |INT_MIN| of the n-bit domain: magnitude needs n bits. The paper's
        // sign-magnitude datapath cannot represent it; hardware saturates to
        // the largest magnitude, and so do we.
        let sat_mag = (1u32 << (n - 1)) - 1;
        return saturate_result(sign, sat_mag, n, ops);
    }

    // Zero detect (wired-NOR over the magnitude bits, 1 cycle).
    ops += 1;
    if mag == 0 {
        return ConvResult { bits: (sign << 31), logic_ops: ops };
    }

    // Lines 1–4: leading-one scan. D := D | a_i; c_i := c_i | D for
    // i = n−2 .. 0. After the loop C has ones from the leading-1 position
    // downward.
    let mut c: u32 = 0;
    let mut d: u32 = 0;
    for i in (0..n - 1).rev() {
        let a_i = (mag >> i) & 1;
        d |= a_i;
        c |= d << i;
        ops += 2;
    }

    // Lines 5–11: popcount(C) via a 5-bit ripple accumulator (Sum), then
    // Sum += 126 to bias. (n−1) iterations × 5-bit inner loop, 3 ops each.
    let mut sum: u32 = 0;
    for i in 0..n - 1 {
        let mut carry = (c >> i) & 1;
        // 8-bit accumulator: the paper's listing uses 5 bits (s_4..s_0),
        // but biased exponents up to 150 need 8 — see `op_bound` docs.
        for j in 0..8 {
            let s_j = (sum >> j) & 1;
            let c1 = s_j & carry;
            let s_new = s_j ^ carry;
            sum = (sum & !(1 << j)) | (s_new << j);
            carry = c1;
            ops += 3;
        }
    }
    sum += 126; // line 11 — bit-serial add of a constant, ~8 ops
    ops += 8;

    // Line 12: sign bit.
    let mut r: u32 = sign << 31;
    ops += 1;

    // Lines 13–15: biased exponent into r[30:23]. (The paper writes
    // r_23..r_27 for its 5-bit Sum; a full f32 exponent is 8 bits.)
    r |= (sum & 0xFF) << 23;
    ops += 8;

    // Line 16: C := BitReverse(C+1) << 1 — builds 2^k where k is the number
    // of leading zeros of the magnitude (bit-serial: increment + reverse).
    let p = 31 - mag.leading_zeros(); // leading-one position (< n−1)
    let k = (n - 2) - p; // leading zeros in the (n−1)-bit magnitude field
    let c_rev = 1u32 << k;
    ops += (n - 1) as u64; // increment + routed reverse

    // Line 17: A := A * C — align mantissa. Bit-serial multiply is the
    // quadratic term of the cycle cost. Here C is a power of two, so the
    // product is exact and fits in n−1 bits of fraction + hidden one.
    let aligned = mag << k;
    debug_assert_eq!(aligned >> (n - 2), 1, "hidden one must land at bit n−2");
    ops += ((n as u64) * (n as u64)) / 2; // bit-serial shift-add multiply
    let _ = c_rev;

    // Lines 18–20: drop the hidden one, left-justify the remaining n−2
    // magnitude bits at the top of the 23-bit mantissa field.
    let frac = aligned & ((1 << (n - 2)) - 1); // remove hidden 1
    let mant = if n - 2 <= 23 { frac << (23 - (n - 2)) } else { frac >> ((n - 2) - 23) };
    r |= mant;
    ops += (n - 2) as u64;

    ConvResult { bits: r, logic_ops: ops }
}

fn saturate_result(sign: u32, mag: u32, n: u32, ops: u64) -> ConvResult {
    let v = mag as f32;
    let bits = v.to_bits() | (sign << 31);
    let _ = n;
    ConvResult { bits, logic_ops: ops }
}

/// Convenience wrapper returning the f32 value.
pub fn int_to_f32(a: i32, n: u32) -> f32 {
    f32::from_bits(int_to_f32_traced(a, n).bits)
}

/// The reverse direction (paper footnote: "straightforward"): f32 → n-bit
/// signed integer with round-to-nearest-even, saturating. This is what the
/// C-SRAM applies when the CPU hands re-quantized activations back.
pub fn f32_to_int(x: f32, n: u32) -> i32 {
    assert!((2..=MAX_BITS).contains(&n));
    let hi = ((1i64 << (n - 1)) - 1) as f32;
    let lo = -(1i64 << (n - 1)) as f32;
    let r = x.clamp(lo, hi);
    // round half to even, like the vector engine's FCVT.
    let f = r.floor();
    let d = r - f;
    let q = if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    };
    q as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Prng};

    #[test]
    fn exhaustive_small_widths() {
        // Bit-exact against hardware `as f32` for every representable value
        // at n ≤ 16 (excluding the unsaturatable INT_MIN case, checked
        // separately).
        for n in 2..=16u32 {
            let lo = -(1i32 << (n - 1)) + 1;
            let hi = (1i32 << (n - 1)) - 1;
            for a in lo..=hi {
                let got = int_to_f32_traced(a, n);
                let want = (a as f32).to_bits();
                assert_eq!(
                    got.bits, want,
                    "n={n} a={a}: got {:#010x} want {want:#010x}",
                    got.bits
                );
            }
        }
    }

    #[test]
    fn randomized_wide_widths() {
        propcheck::check(
            "typeconv-wide",
            propcheck::Config { cases: 400, seed: 21 },
            |p, _| {
                let n = p.usize_in(17, 26) as u32;
                let a = p.signed_bits(n - 1) as i32; // avoid INT_MIN saturation
                (n, a)
            },
            |&(n, a)| {
                let got = int_to_f32_traced(a, n).bits;
                let want = (a as f32).to_bits();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("n={n} a={a}: {got:#010x} != {want:#010x}"))
                }
            },
        );
    }

    #[test]
    fn n25_is_exact_because_mantissa_fits() {
        // n = 25 → 23 magnitude bits below the hidden one: still exact.
        for a in [(1 << 24) - 1, 1 << 23, 0xAAAAAA, -((1 << 24) - 1)] {
            assert_eq!(int_to_f32_traced(a, 25).bits, (a as f32).to_bits(), "a={a}");
        }
    }

    #[test]
    fn zero_and_signs() {
        assert_eq!(int_to_f32(0, 8), 0.0);
        assert_eq!(int_to_f32_traced(0, 8).bits, 0); // +0.0 exactly
        assert_eq!(int_to_f32(-1, 8), -1.0);
        assert_eq!(int_to_f32(1, 2), 1.0);
        assert_eq!(int_to_f32(-1, 2), -1.0);
    }

    #[test]
    fn int_min_saturates() {
        // -2^(n-1) has no sign-magnitude representation in n bits; the
        // datapath saturates to -(2^(n-1)-1).
        let r = int_to_f32(-128, 8);
        assert_eq!(r, -127.0);
    }

    #[test]
    fn op_count_within_published_bound() {
        for n in 2..=25u32 {
            let worst = (1i32 << (n - 1)) - 1;
            let r = int_to_f32_traced(worst, n);
            assert!(
                r.logic_ops <= op_bound(n),
                "n={n}: ops {} exceeds bound {}",
                r.logic_ops,
                op_bound(n)
            );
        }
    }

    #[test]
    fn cycle_formula_matches_paper() {
        assert_eq!(cycle_cost(8), 3 * 64 / 2 + 39 * 7);
        assert_eq!(cycle_cost(25), 3 * 625 / 2 + 39 * 24);
    }

    #[test]
    fn batch_parallelism() {
        // 512 columns × 2 arrays = 1024 elements per wave.
        assert_eq!(batch_cycles(8, 1024, 512, 2), cycle_cost(8));
        assert_eq!(batch_cycles(8, 1025, 512, 2), 2 * cycle_cost(8));
        assert_eq!(batch_cycles(8, 1, 512, 2), cycle_cost(8));
    }

    #[test]
    fn f32_to_int_roundtrip() {
        let mut p = Prng::new(5);
        for _ in 0..1000 {
            let n = p.usize_in(2, 26) as u32;
            let a = p.signed_bits(n - 1) as i32;
            assert_eq!(f32_to_int(int_to_f32(a, n), n), a, "n={n} a={a}");
        }
    }

    #[test]
    fn f32_to_int_saturates_and_rounds_to_even() {
        assert_eq!(f32_to_int(1e9, 8), 127);
        assert_eq!(f32_to_int(-1e9, 8), -128);
        assert_eq!(f32_to_int(2.5, 8), 2); // ties to even
        assert_eq!(f32_to_int(3.5, 8), 4);
        assert_eq!(f32_to_int(-2.5, 8), -2);
    }
}
