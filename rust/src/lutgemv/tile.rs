//! Column-tile kernel for the LUT-GEMV execution backend.
//!
//! The engine splits the N output columns into contiguous tiles; each tile
//! is computed by the crate-internal `run_tile` with all of its mutable
//! state in a private `TileScratch`, so the hot
//! `columns × groups × chunks × planes × batch` loop is allocation-free
//! and tiles can run concurrently on the [`crate::runtime::WorkerPool`]
//! with nothing shared but read-only inputs. Scratch (and the tile output
//! buffers) live in a per-node [`ScratchArena`] and are recycled across
//! calls, so steady-state GEMV reuses every large buffer instead of
//! reallocating per tile — and on a NUMA-placed engine a tile's scratch
//! checkout, weight reads, and output buffer all stay on the node whose
//! worker runs the tile.
//!
//! Per scale group the kernel picks one of two accumulation paths:
//! the lane-parallel `i32` kernels in [`super::planes`] when the per-group
//! range proof ([`super::planes::group_fits_i32`]) shows no intermediate
//! sum can leave `i32`, else the full-width `i64` kernels. Both reduce the
//! same integers in the same order, so the choice is invisible in the
//! output — pinned down by `tests/plane_conformance.rs`.
//!
//! Determinism: a column's result depends only on the weights, the
//! precomputed activation bit patterns, and the per-column accumulation
//! order — all of which are identical no matter which worker executes the
//! tile — so tiled/threaded outputs are bit-identical to the serial ones
//! (property-tested in `tests/tiled_parity.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::engine::GemvStats;
use super::pattern::PatternReuseTable;
use super::planes;
use crate::csram::lut::Lut;
use crate::quant::QuantizedMatrix;

/// Flat row-major batch output: `value(bi, col) = data[bi * n + col]`.
///
/// Replaces the old `Vec<Vec<f32>>` shape: one allocation, reusable across
/// calls (`reset` keeps capacity), and contiguous per-request rows for the
/// serving layer to argmax over.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GemvOutput {
    data: Vec<f32>,
    batch: usize,
    n: usize,
}

impl GemvOutput {
    /// An empty output; the first `gemv_batch_into` sizes it.
    pub fn new() -> Self {
        GemvOutput::default()
    }

    /// Resize to `batch × n`, reusing the allocation. Contents are
    /// unspecified until the engine's tile scatter overwrites every
    /// element (which `gemv_batch_into` always does) — skipping the
    /// zero-fill keeps the per-iteration serving cost at exactly one
    /// logits-buffer write instead of two.
    pub fn reset(&mut self, batch: usize, n: usize) {
        self.batch = batch;
        self.n = n;
        self.data.resize(batch * n, 0.0);
    }

    /// Batch rows held.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Output width (N).
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Output row for batch item `bi`.
    pub fn row(&self, bi: usize) -> &[f32] {
        &self.data[bi * self.n..(bi + 1) * self.n]
    }

    /// The whole flat buffer, row-major.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub(crate) fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Copy out as the legacy nested shape (tests / diagnostics only).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        (0..self.batch).map(|bi| self.row(bi).to_vec()).collect()
    }
}

/// Read-only inputs shared by every tile of one `gemv_batch` call. `wt`
/// may be the engine's full matrix or one node's weight shard; `col_start`
/// / `col_end` (and the `group_abs_sums` index space) are always *local*
/// to `wt`'s rows — the dispatcher rebases global column ids before the
/// kernel ever sees them.
pub(crate) struct TileArgs<'a> {
    /// Transposed quantized weights (`[rows, K]` row-major): the full
    /// `[N, K]` matrix, or the owning node's contiguous row slice.
    pub wt: &'a QuantizedMatrix,
    /// Per-(local column, scale-group) `Σ|w|`,
    /// `[col * groups_per_row + g]` — precomputed at engine construction
    /// for the lane range proof.
    pub group_abs_sums: &'a [u64],
    pub nbw: u32,
    pub use_prt: bool,
    /// Disable the i32 lane path (reference/conformance knob).
    pub force_scalar_accum: bool,
    /// `patterns[(chunk * act_bits + plane) * batch + bi]`, precomputed
    /// once per call — patterns do not depend on the output column.
    pub patterns: &'a [u32],
    pub act_bits: usize,
    pub batch: usize,
    /// Per-batch-item activation scales.
    pub x_scales: &'a [f32],
    /// Column range `[col_start, col_end)` this tile owns.
    pub col_start: usize,
    pub col_end: usize,
}

/// Per-tile mutable state: one buffer set per concurrently-running tile,
/// recycled through the [`ScratchArena`] — nothing is allocated inside the
/// kernel loops, and nothing is allocated at all once the arena is warm.
#[derive(Debug)]
pub(crate) struct TileScratch {
    /// Unpacked basis weights of the current column (K values).
    wrow: Vec<i32>,
    /// Zero-padded basis for the current chunk (NBW values).
    basis: Vec<i64>,
    /// LUT entries for the current chunk (2^NBW subset sums).
    entries: Vec<i64>,
    /// The same entries narrowed to i32 for the lane path (valid only when
    /// the group's range proof holds).
    entries32: Vec<i32>,
    /// Per-batch-item i64 accumulator for the current scale group.
    acc: Vec<i64>,
    /// Per-batch-item i32 accumulator (lane path).
    acc32: Vec<i32>,
    /// PRT-resolved values for one plane (i64 path).
    vals: Vec<i64>,
    /// PRT-resolved values for one plane (lane path).
    vals32: Vec<i32>,
    /// This tile's Pattern Reuse Table (one per DFM in hardware; flushed on
    /// every LUT switch, so per-tile instances behave identically to a
    /// global one).
    prt: PatternReuseTable,
}

impl TileScratch {
    pub fn new(k: usize, nbw: u32, batch: usize, prt_capacity: usize) -> Self {
        let mut s = TileScratch {
            wrow: Vec::new(),
            basis: Vec::new(),
            entries: Vec::new(),
            entries32: Vec::new(),
            acc: Vec::new(),
            acc32: Vec::new(),
            vals: Vec::new(),
            vals32: Vec::new(),
            prt: PatternReuseTable::new(prt_capacity),
        };
        s.ensure(k, nbw, batch, prt_capacity);
        s
    }

    /// Resize every buffer for the given call shape, reusing capacity.
    /// The PRT is rebuilt only if the configured DFM capacity changed.
    pub fn ensure(&mut self, k: usize, nbw: u32, batch: usize, prt_capacity: usize) {
        let n_entries = 1usize << nbw;
        self.wrow.resize(k, 0);
        self.basis.resize(nbw as usize, 0);
        self.entries.resize(n_entries, 0);
        self.entries32.resize(n_entries, 0);
        self.acc.resize(batch, 0);
        self.acc32.resize(batch, 0);
        self.vals.resize(batch, 0);
        self.vals32.resize(batch, 0);
        if self.prt.capacity() != prt_capacity {
            self.prt = PatternReuseTable::new(prt_capacity);
        }
    }
}

/// Recycling pool for per-tile scratch and tile output buffers.
///
/// One arena per engine *shard* (one per node group on a NUMA-placed
/// engine, so checkout never crosses a socket): tile jobs check a scratch
/// out, run, and check it back in; tile outputs are checked out by jobs
/// and returned by the engine after scattering into the caller's
/// [`GemvOutput`]. The arena grows to the peak number of concurrently-live
/// buffers (≈ worker count for scratches, tiles-per-call for outputs) and
/// then stops allocating — the `*_created` counters let tests assert
/// steady-state reuse.
#[derive(Debug, Default)]
pub struct ScratchArena {
    scratches: Mutex<Vec<TileScratch>>,
    out_bufs: Mutex<Vec<Vec<f32>>>,
    scratches_created: AtomicU64,
    out_bufs_created: AtomicU64,
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena::default()
    }

    /// Total `TileScratch` instances ever created (not currently pooled —
    /// ever). Flat across calls ⇒ steady-state scratch reuse.
    pub fn scratches_created(&self) -> u64 {
        self.scratches_created.load(Ordering::Relaxed)
    }

    /// Total tile output buffers ever created.
    pub fn out_bufs_created(&self) -> u64 {
        self.out_bufs_created.load(Ordering::Relaxed)
    }

    /// Buffers currently checked in (scratches, out_bufs) — equals the
    /// created totals whenever no GEMV is in flight.
    pub fn pooled(&self) -> (usize, usize) {
        (self.scratches.lock().unwrap().len(), self.out_bufs.lock().unwrap().len())
    }

    /// Check a scratch out for one tile job. `faults` is the dispatching
    /// pool's armed fault schedule, if any: a scheduled `poison_scratch`
    /// tick panics *here*, at the arena boundary — inside the tile job,
    /// where the worker's catch-unwind turns it into a lost chunk for the
    /// pool's recovery ladder to heal (see `tests/fault_injection.rs`).
    pub(crate) fn checkout_scratch(
        &self,
        k: usize,
        nbw: u32,
        batch: usize,
        prt_capacity: usize,
        faults: Option<&crate::runtime::faults::FaultPlan>,
    ) -> TileScratch {
        if let Some(plan) = faults {
            if plan.poisoned_scratch() {
                panic!("injected fault: poisoned scratch checkout");
            }
        }
        let popped = self.scratches.lock().unwrap().pop();
        match popped {
            Some(mut s) => {
                s.ensure(k, nbw, batch, prt_capacity);
                s
            }
            None => {
                self.scratches_created.fetch_add(1, Ordering::Relaxed);
                TileScratch::new(k, nbw, batch, prt_capacity)
            }
        }
    }

    pub(crate) fn checkin_scratch(&self, s: TileScratch) {
        self.scratches.lock().unwrap().push(s);
    }

    pub(crate) fn checkout_out(&self, len: usize) -> Vec<f32> {
        let popped = self.out_bufs.lock().unwrap().pop();
        let mut buf = popped.unwrap_or_else(|| {
            self.out_bufs_created.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        });
        buf.resize(len, 0.0);
        buf
    }

    pub(crate) fn checkin_out(&self, buf: Vec<f32>) {
        self.out_bufs.lock().unwrap().push(buf);
    }
}

/// Compute output columns `[col_start, col_end)` for the whole batch into
/// `out` (`[batch, width]` row-major).
///
/// This is the former `LutGemvEngine::gemv_batch` column loop, restricted
/// to a tile: per column it unpacks the K basis weights once, then per
/// scale group builds each chunk's LUT and streams every activation
/// bit-plane of every batch item through it (the §III-C reuse that makes
/// batching effective). Each group's accumulation runs on the i32 lane
/// kernels when the range proof holds, else on the i64 kernels — same
/// integers, same order, bit-identical output either way.
pub(crate) fn run_tile(
    args: &TileArgs<'_>,
    scratch: &mut TileScratch,
    out: &mut [f32],
) -> GemvStats {
    let wt = args.wt;
    let k = wt.cols;
    let nbw = args.nbw as usize;
    let group = wt.group_size;
    let chunks_per_group = group.div_ceil(nbw);
    let groups = k / group;
    let batch = args.batch;
    let act_bits = args.act_bits;
    let width = args.col_end - args.col_start;
    debug_assert_eq!(out.len(), batch * width);
    debug_assert_eq!(scratch.wrow.len(), k);

    let mut stats = GemvStats::default();
    out.fill(0.0);

    for (j, col) in (args.col_start..args.col_end).enumerate() {
        // wt row `col` holds the K basis weights for output column `col`.
        wt.packed().unpack_range_into(col * k, &mut scratch.wrow);
        for g in 0..groups {
            let scale_w = wt.scale(col, g * group);
            let abs_sum = args.group_abs_sums[col * groups + g];
            let lane =
                !args.force_scalar_accum && planes::group_fits_i32(abs_sum, act_bits as u32);
            if lane {
                accumulate_group_i32(args, scratch, g, chunks_per_group, &mut stats);
                for (bi, (&a, &xs)) in scratch.acc32.iter().zip(args.x_scales).enumerate() {
                    out[bi * width + j] += a as f32 * scale_w * xs;
                }
            } else {
                accumulate_group_i64(args, scratch, g, chunks_per_group, &mut stats);
                for (bi, (&a, &xs)) in scratch.acc.iter().zip(args.x_scales).enumerate() {
                    out[bi * width + j] += a as f32 * scale_w * xs;
                }
            }
        }
    }
    stats
}

/// Build the current chunk's LUT into `scratch.entries` from the unpacked
/// weight row (zero-padded to NBW at the group tail).
#[inline]
fn build_chunk_lut(scratch: &mut TileScratch, start: usize, end: usize, nbw: u32) {
    scratch.basis.fill(0);
    for (i, kk) in (start..end).enumerate() {
        scratch.basis[i] = scratch.wrow[kk] as i64;
    }
    Lut::build_into(&scratch.basis, nbw, &mut scratch.entries);
}

/// One definition for both accumulation paths: the i32 arm narrows each
/// freshly-built LUT into `entries32` (sound under the range proof) and
/// runs the lane kernels on i32 scratch; the i64 arm runs the same logic
/// full-width. A single body keeps the PRT bookkeeping and plane
/// sign-handling — the bit-identity contract — in exactly one place.
macro_rules! accumulate_group {
    ($name:ident, $ty:ty, $entries:ident, $vals:ident, $acc:ident,
     $accum_patterns:path, $accum_values:path, narrow = $narrow:literal, $doc:literal) => {
        #[doc = $doc]
        #[allow(clippy::unnecessary_cast)] // `v as i64` in the i64 expansion
        fn $name(
            args: &TileArgs<'_>,
            scratch: &mut TileScratch,
            g: usize,
            chunks_per_group: usize,
            stats: &mut GemvStats,
        ) {
            let nbw = args.nbw as usize;
            let group = args.wt.group_size;
            let batch = args.batch;
            let act_bits = args.act_bits;
            scratch.$acc.fill(0);
            for c in 0..chunks_per_group {
                let start = g * group + c * nbw;
                let end = (start + nbw).min((g + 1) * group);
                build_chunk_lut(scratch, start, end, args.nbw);
                stats.luts_built += 1;
                if $narrow {
                    // Narrow the entries once per LUT; the range proof
                    // guarantees they fit (|entry| ≤ Σ|w| over the chunk).
                    for (e32, &e) in scratch.entries32.iter_mut().zip(&scratch.entries) {
                        *e32 = e as i32;
                    }
                }
                let chunk = g * chunks_per_group + c;
                let pat_base = chunk * act_bits * batch;
                if args.use_prt {
                    scratch.prt.flush(); // new LUT ⇒ stored results are stale
                    for plane in 0..act_bits {
                        let pats = &args.patterns
                            [pat_base + plane * batch..pat_base + (plane + 1) * batch];
                        for (slot, &pat) in scratch.$vals.iter_mut().zip(pats) {
                            let v = match scratch.prt.lookup(pat) {
                                Some(hit) => {
                                    stats.prt_hits += 1;
                                    hit
                                }
                                None => {
                                    let v = scratch.entries[pat as usize];
                                    stats.lut_reads += 1;
                                    scratch.prt.insert(pat, v);
                                    v
                                }
                            };
                            *slot = v as $ty;
                        }
                        $accum_values(
                            &scratch.$vals,
                            plane as u32,
                            plane == act_bits - 1,
                            &mut scratch.$acc,
                        );
                    }
                } else {
                    for plane in 0..act_bits {
                        let pats = &args.patterns
                            [pat_base + plane * batch..pat_base + (plane + 1) * batch];
                        $accum_patterns(
                            &scratch.$entries,
                            pats,
                            plane as u32,
                            plane == act_bits - 1,
                            &mut scratch.$acc,
                        );
                    }
                    stats.lut_reads += (act_bits * batch) as u64;
                }
            }
        }
    };
}

accumulate_group!(
    accumulate_group_i32, i32, entries32, vals32, acc32,
    planes::accum_patterns_i32, planes::accum_values_i32, narrow = true,
    "Accumulate one scale group on the i32 lane path. Caller has proven \
     (via `planes::group_fits_i32`) that no intermediate sum can leave `i32`."
);

accumulate_group!(
    accumulate_group_i64, i64, entries, vals, acc,
    planes::accum_patterns_i64, planes::accum_values_i64, narrow = false,
    "Accumulate one scale group on the full-width i64 path (range-proof \
     fallback and the `force_scalar_accum` reference)."
);
