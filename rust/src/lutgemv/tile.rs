//! Column-tile kernel for the LUT-GEMV execution backend.
//!
//! The engine splits the N output columns into contiguous tiles; each tile
//! is computed by [`run_tile`] with all of its mutable state in a
//! [`TileScratch`], so the hot `columns × groups × chunks × planes × batch`
//! loop is allocation-free and tiles can run concurrently on the
//! [`crate::runtime::WorkerPool`] with nothing shared but read-only inputs.
//!
//! Determinism: a column's result depends only on the weights, the
//! precomputed activation bit patterns, and the per-column accumulation
//! order — all of which are identical no matter which worker executes the
//! tile — so tiled/threaded outputs are bit-identical to the serial ones
//! (property-tested in `tests/tiled_parity.rs`).

use super::engine::GemvStats;
use super::pattern::PatternReuseTable;
use crate::csram::lut::Lut;
use crate::quant::QuantizedMatrix;

/// Flat row-major batch output: `value(bi, col) = data[bi * n + col]`.
///
/// Replaces the old `Vec<Vec<f32>>` shape: one allocation, reusable across
/// calls (`reset` keeps capacity), and contiguous per-request rows for the
/// serving layer to argmax over.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GemvOutput {
    data: Vec<f32>,
    batch: usize,
    n: usize,
}

impl GemvOutput {
    /// An empty output; the first `gemv_batch_into` sizes it.
    pub fn new() -> Self {
        GemvOutput::default()
    }

    /// Resize to `batch × n`, reusing the allocation. Contents are
    /// unspecified until the engine's tile scatter overwrites every
    /// element (which `gemv_batch_into` always does) — skipping the
    /// zero-fill keeps the per-iteration serving cost at exactly one
    /// logits-buffer write instead of two.
    pub fn reset(&mut self, batch: usize, n: usize) {
        self.batch = batch;
        self.n = n;
        self.data.resize(batch * n, 0.0);
    }

    /// Batch rows held.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Output width (N).
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Output row for batch item `bi`.
    pub fn row(&self, bi: usize) -> &[f32] {
        &self.data[bi * self.n..(bi + 1) * self.n]
    }

    /// The whole flat buffer, row-major.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub(crate) fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Copy out as the legacy nested shape (tests / diagnostics only).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        (0..self.batch).map(|bi| self.row(bi).to_vec()).collect()
    }
}

/// Read-only inputs shared by every tile of one `gemv_batch` call.
pub(crate) struct TileArgs<'a> {
    /// Transposed quantized weights (`[N, K]` row-major).
    pub wt: &'a QuantizedMatrix,
    pub nbw: u32,
    pub use_prt: bool,
    /// `patterns[(chunk * act_bits + plane) * batch + bi]`, precomputed
    /// once per call — patterns do not depend on the output column.
    pub patterns: &'a [u32],
    pub act_bits: usize,
    pub batch: usize,
    /// Per-batch-item activation scales.
    pub x_scales: &'a [f32],
    /// Column range `[col_start, col_end)` this tile owns.
    pub col_start: usize,
    pub col_end: usize,
}

/// Per-tile mutable state: one allocation set per tile, none inside the
/// kernel loops.
pub(crate) struct TileScratch {
    /// Unpacked basis weights of the current column (K values).
    wrow: Vec<i32>,
    /// Zero-padded basis for the current chunk (NBW values).
    basis: Vec<i64>,
    /// LUT entries for the current chunk (2^NBW subset sums).
    entries: Vec<i64>,
    /// Per-batch-item integer accumulator for the current scale group.
    acc: Vec<i64>,
    /// Tile output, `[batch, width]` row-major.
    out: Vec<f32>,
    /// This tile's Pattern Reuse Table (one per DFM in hardware; flushed on
    /// every LUT switch, so per-tile instances behave identically to a
    /// global one).
    prt: PatternReuseTable,
}

impl TileScratch {
    pub fn new(k: usize, nbw: u32, batch: usize, width: usize) -> Self {
        TileScratch {
            wrow: vec![0i32; k],
            basis: vec![0i64; nbw as usize],
            entries: vec![0i64; 1usize << nbw],
            acc: vec![0i64; batch],
            out: vec![0.0f32; batch * width],
            prt: PatternReuseTable::new(32),
        }
    }

    /// Surrender the tile output buffer.
    pub fn into_out(self) -> Vec<f32> {
        self.out
    }
}

/// Compute output columns `[col_start, col_end)` for the whole batch.
///
/// This is the former `LutGemvEngine::gemv_batch` column loop, restricted
/// to a tile: per column it unpacks the K basis weights once, then per
/// scale group builds each chunk's LUT and streams every activation
/// bit-plane of every batch item through it (the §III-C reuse that makes
/// batching effective). Results land in `scratch.out` (`[batch, width]`).
pub(crate) fn run_tile(args: &TileArgs<'_>, scratch: &mut TileScratch) -> GemvStats {
    let wt = args.wt;
    let k = wt.cols;
    let nbw = args.nbw as usize;
    let group = wt.group_size;
    let chunks_per_group = group.div_ceil(nbw);
    let groups = k / group;
    let batch = args.batch;
    let act_bits = args.act_bits;
    let width = args.col_end - args.col_start;
    debug_assert_eq!(scratch.out.len(), batch * width);
    debug_assert_eq!(scratch.wrow.len(), k);

    let mut stats = GemvStats::default();
    scratch.out.fill(0.0);

    for (j, col) in (args.col_start..args.col_end).enumerate() {
        // wt row `col` holds the K basis weights for output column `col`.
        wt.packed().unpack_range_into(col * k, &mut scratch.wrow);
        for g in 0..groups {
            let scale_w = wt.scale(col, g * group);
            scratch.acc.iter_mut().for_each(|a| *a = 0);
            for c in 0..chunks_per_group {
                let start = g * group + c * nbw;
                let end = (start + nbw).min((g + 1) * group);
                // Basis weights (zero-padded to NBW at the group tail).
                scratch.basis.iter_mut().for_each(|b| *b = 0);
                for (i, kk) in (start..end).enumerate() {
                    scratch.basis[i] = scratch.wrow[kk] as i64;
                }
                Lut::build_into(&scratch.basis, args.nbw, &mut scratch.entries);
                stats.luts_built += 1;
                let chunk = g * chunks_per_group + c;
                let pat_base = chunk * act_bits * batch;
                if args.use_prt {
                    scratch.prt.flush(); // new LUT ⇒ stored results are stale
                    for plane in 0..act_bits {
                        for bi in 0..batch {
                            let pat = args.patterns[pat_base + plane * batch + bi];
                            let v = match scratch.prt.lookup(pat) {
                                Some(hit) => {
                                    stats.prt_hits += 1;
                                    hit
                                }
                                None => {
                                    let v = scratch.entries[pat as usize];
                                    stats.lut_reads += 1;
                                    scratch.prt.insert(pat, v);
                                    v
                                }
                            };
                            if plane == act_bits - 1 {
                                scratch.acc[bi] -= v << plane;
                            } else {
                                scratch.acc[bi] += v << plane;
                            }
                        }
                    }
                } else {
                    for plane in 0..act_bits {
                        let neg = plane == act_bits - 1;
                        for bi in 0..batch {
                            let pat = args.patterns[pat_base + plane * batch + bi];
                            let v = scratch.entries[pat as usize];
                            if neg {
                                scratch.acc[bi] -= v << plane;
                            } else {
                                scratch.acc[bi] += v << plane;
                            }
                        }
                    }
                    stats.lut_reads += (act_bits * batch) as u64;
                }
            }
            for bi in 0..batch {
                scratch.out[bi * width + j] +=
                    scratch.acc[bi] as f32 * scale_w * args.x_scales[bi];
            }
        }
    }
    stats
}
