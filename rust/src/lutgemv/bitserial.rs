//! Bit-serial in-SRAM GEMV — the Neural Cache baseline (paper [21]/[22],
//! evaluated as "NC" in Figs 1 and 12).
//!
//! Neural Cache computes each multiply-accumulate bit-serially in the SRAM
//! array: an n-bit multiply costs `n² + 5n − 2` cycles and an add `n + 1`
//! (identical peripheral assumptions as SAIL's C-SRAM — the comparison
//! isolates LUT-GEMV vs bit-serial *algorithms*, matching the paper's
//! "Neural Cache architecture is based on the same design as SAIL, with
//! LUT-GEMV replaced by the bit-serial computing method … and the
//! in-memory type conversion algorithm excluded").
//!
//! Key structural differences from LUT-GEMV:
//! - no per-batch reuse: every (item, element) multiply is paid in full;
//! - cost scales with the *product* structure of operand widths (the
//!   quadratic multiply), not with table reads;
//! - type conversion must round-trip to the CPU vector units.

use crate::csram::bitline::{add_cycles, mult_cycles};
use crate::quant::QuantLevel;
use crate::util::ceil_div;

/// Cycle model for bit-serial (Neural-Cache-style) GEMV.
#[derive(Debug, Clone, Copy)]
pub struct BitSerialModel {
    pub level: QuantLevel,
    pub act_bits: u32,
    pub arrays: u32,
    pub cols_per_array: u32,
    /// LLC slice access latency for streaming weight rows in.
    pub llc_access_cycles: u64,
}

impl BitSerialModel {
    pub fn prototype(level: QuantLevel) -> Self {
        BitSerialModel {
            level,
            act_bits: 8,
            arrays: 2,
            cols_per_array: 512,
            llc_access_cycles: 58,
        }
    }

    /// Bit-serial multiply operand width: the array multiplies the w-bit
    /// weight by the a-bit activation; the serial cost is governed by the
    /// wider operand (the narrower is zero-extended in the array).
    fn mul_bits(&self) -> u32 {
        self.level.bits().max(self.act_bits)
    }

    fn acc_bits(&self) -> u32 {
        24
    }

    /// Total cycles for a `[1,K]×[K,N]` GEMV over batch `b`.
    ///
    /// Each array computes its 512 output columns in parallel; the K
    /// reduction is sequential: per element, stream the weight row in
    /// (amortized across columns), multiply, accumulate. Nothing amortizes
    /// across the batch.
    pub fn tile_cycles(&self, k: usize, n: usize, b: usize) -> u64 {
        assert!(b >= 1);
        let passes = ceil_div(n, (self.arrays * self.cols_per_array) as usize) as u64;
        let per_mac = mult_cycles(self.mul_bits()) + add_cycles(self.acc_bits());
        // Weight loading: one slice access per chunk of rows; the weights
        // for one k-index across 512 columns arrive as level.bits() planes.
        let load_per_k = self.level.bits() as u64 + self.llc_access_cycles / 64;
        passes * (k as u64) * (load_per_k + b as u64 * per_mac)
    }

    /// Cycles per batch item.
    pub fn cycles_per_item(&self, k: usize, n: usize, b: usize) -> f64 {
        self.tile_cycles(k, n, b) as f64 / b as f64
    }
}

/// Fig 1's headline quantity: efficiency gain of LUT-based over bit-serial
/// computing at a given precision and batch size (same array substrate).
pub fn lut_vs_bitserial_gain(level: QuantLevel, nbw: u32, batch: usize) -> f64 {
    let lut = super::cycles::GemvCycleModel {
        in_memory_typeconv: false, // isolate the GEMV algorithms
        ..super::cycles::GemvCycleModel::prototype(level, nbw)
    };
    let bs = BitSerialModel::prototype(level);
    bs.cycles_per_item(1024, 1024, batch) / lut.cycles_per_item(1024, 1024, batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_batch_amortization() {
        let m = BitSerialModel::prototype(QuantLevel::Q4);
        let c1 = m.cycles_per_item(1024, 1024, 1);
        let c8 = m.cycles_per_item(1024, 1024, 8);
        // Per-item cost is (nearly) flat: only weight loading amortizes.
        assert!((c8 - c1).abs() / c1 < 0.20, "c1={c1} c8={c8}");
    }

    #[test]
    fn lut_wins_and_gain_grows_with_batch() {
        // Fig 1: LUT-based computing beats bit-serial, more so at batch.
        for level in [QuantLevel::Q2, QuantLevel::Q3, QuantLevel::Q4] {
            let g1 = lut_vs_bitserial_gain(level, 4, 1);
            let g8 = lut_vs_bitserial_gain(level, 4, 8);
            let g32 = lut_vs_bitserial_gain(level, 4, 32);
            assert!(g8 > 1.0, "{level}: LUT must win at batch 8 (gain {g8})");
            assert!(g8 > g1, "{level}: gain must grow 1→8 ({g1} → {g8})");
            assert!(g32 >= g8 * 0.95, "{level}: gain must not collapse at 32");
        }
    }

    #[test]
    fn gain_larger_at_lower_precision() {
        // Fig 1: the dashed lines order 2-bit > 3-bit > 4-bit.
        let g2 = lut_vs_bitserial_gain(QuantLevel::Q2, 4, 8);
        let g3 = lut_vs_bitserial_gain(QuantLevel::Q3, 4, 8);
        let g4 = lut_vs_bitserial_gain(QuantLevel::Q4, 4, 8);
        assert!(g2 > g3 && g3 > g4, "g2={g2} g3={g3} g4={g4}");
    }

    #[test]
    fn quadratic_multiply_dominates() {
        let m = BitSerialModel::prototype(QuantLevel::Q8);
        // One MAC at 8 bits: 102 + 25 cycles; K=1024 of them.
        let c = m.tile_cycles(1024, 1024, 1);
        assert!(c >= 1024 * (102 + 25), "c={c}");
    }
}
