//! LUT-based GEMV — the paper's core computational contribution (§II-C,
//! §III).
//!
//! A `[1,K]×[K,N]` GEMV over group-quantized weights is computed as:
//! activations are chunked into groups of NBW consecutive elements *within
//! each quantization scale group* (LUT entries are integer subset sums, so
//! every basis weight in one LUT must share a scale); for each (chunk,
//! output-column) pair the C-SRAM holds the 2^NBW subset sums of the chunk's
//! weights; activation bits stream LSB→MSB and each bit-plane's NBW-bit
//! pattern indexes the LUT, with the fetched entry shift-added into a
//! per-scale-group integer accumulator. Group sums are then dequantized
//! (weight scale × activation scale) and reduced into the f32 output.
//!
//! - [`engine`]: the exact functional implementation (bit-exact against the
//!   naive integer dot product — the repository's core correctness anchor,
//!   mirrored by the Pallas kernel on the Python side). Execution is tiled
//!   and thread-parallel: column tiles fan out over the persistent
//!   [`crate::runtime::WorkerPool`], with outputs/stats bit-identical at
//!   every thread count. On NUMA hosts the engine is *placed*: each node
//!   group owns a first-touch copy of its contiguous column shard of the
//!   weights and tiles are routed to the owning node's pinned workers
//!   ([`LutGemvEngine::with_pool`]) — again invisible in the output,
//!   because a column's integer accumulation order never depends on where
//!   it runs;
//! - [`tile`]: the per-tile kernel, its arena-recycled scratch
//!   ([`tile::ScratchArena`], one arena per node so checkout never crosses
//!   a socket), and the flat row-major batch-output buffer
//!   ([`tile::GemvOutput`]) the serving loop reuses;
//! - [`planes`]: the lane-parallel i32 plane-accumulation kernels and the
//!   per-group range proof that makes narrowing from i64 provably exact
//!   (`|entry| ≤ Σ|w|` per chunk, partial sums ≤ `Σ|w|·(2^act_bits−1)`;
//!   i64 fallback whenever the bound does not fit `i32`);
//! - [`pattern`]: the Pattern Reuse Table (§III-D) that short-circuits
//!   repeated activation bit patterns (O(1) generation-counter flush);
//! - [`cycles`]: the C-SRAM cycle model for a tile GEMV, the quantity the
//!   pipeline simulator and the design-space benches consume;
//! - [`bitserial`]: the Neural-Cache-style bit-serial GEMV cycle model used
//!   as the PIM baseline (Fig 1, Fig 12).

pub mod bitserial;
pub mod cycles;
pub mod engine;
pub mod pattern;
pub mod planes;
pub mod tile;

pub use cycles::{GemvCycleModel, GemvCycles};
pub use engine::{GemvStats, LutGemvEngine};
pub use pattern::PatternReuseTable;
pub use tile::{GemvOutput, ScratchArena};
