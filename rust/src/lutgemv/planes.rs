//! Lane-parallel plane accumulation for the LUT-GEMV tile kernel.
//!
//! The `planes × batch` inner loop of the tile kernel (`run_tile` in
//! [`super::tile`]) spends its
//! time doing `acc[bi] ± (lut_entry << plane)` integer adds. The paper's
//! §III-C batching argument assumes this loop runs at vector-unit speed;
//! with `i64` accumulators the compiler emits at most 2-wide SIMD, so this
//! module provides the same accumulation over **`i32` accumulators in
//! fixed-width lanes** ([`LANES`]) — a shape LLVM auto-vectorizes to
//! 4/8/16-wide integer adds on SSE/AVX/NEON — plus the i64 scalar kernels
//! the engine falls back to when narrowing is not provably safe.
//!
//! # Range proof
//!
//! Narrowing to `i32` is only sound if no intermediate accumulator value
//! can leave the `i32` range. The engine proves this **per scale group**
//! from the actual weights before entering the kernel:
//!
//! * every LUT entry is a subset sum of one chunk's basis weights, so
//!   `|entry| ≤ Σ|w|` over that chunk;
//! * each plane contributes `±(entry << plane)` with `plane < act_bits`,
//!   so one chunk's total contribution is bounded by
//!   `Σ|w|_chunk × (2^act_bits − 1)`;
//! * summing over a group's chunks, every partial sum of the group
//!   accumulator is bounded by `Σ|w|_group × (2^act_bits − 1)`.
//!
//! [`group_fits_i32`] checks that bound against `i32::MAX`. When it holds,
//! every intermediate value fits `i32`, so the i32 and i64 accumulations
//! compute the *same integer* and the final `acc as f32 × scales` output is
//! bit-identical — property-tested against the forced-i64 path in
//! `tests/plane_conformance.rs`, including shapes that sit exactly on the
//! bound. When it fails (it takes a ~66K-element Q8 scale group at 8-bit
//! activations to get there), the engine silently uses the i64 kernels.

/// Accumulator lane width. Eight `i32` lanes fill one AVX2 register (or two
/// NEON/SSE registers); the kernels below are written as fixed-`LANES`
/// blocks over slices so the autovectorizer can prove the trip count.
pub const LANES: usize = 8;

/// Largest per-group `Σ|w|` for which i32 accumulation is provably safe at
/// `act_bits`-bit activations (see the module docs for the derivation).
#[inline]
pub fn i32_safe_abs_weight_sum(act_bits: u32) -> u64 {
    debug_assert!((1..=8).contains(&act_bits));
    i32::MAX as u64 / ((1u64 << act_bits) - 1)
}

/// The per-group range proof: `true` iff a scale group whose basis weights
/// have absolute sum `abs_weight_sum` can be accumulated in `i32` without
/// any intermediate overflow, for `act_bits`-bit activations.
#[inline]
pub fn group_fits_i32(abs_weight_sum: u64, act_bits: u32) -> bool {
    abs_weight_sum <= i32_safe_abs_weight_sum(act_bits)
}

/// Absolute sum of a group's basis weights — the quantity the range proof
/// consumes, computed from the unpacked weight row.
#[inline]
pub fn abs_weight_sum(group: &[i32]) -> u64 {
    group.iter().map(|&w| w.unsigned_abs() as u64).sum()
}

/// One definition for both accumulator widths — the lane blocking, sign
/// handling, and tail logic live in exactly one place, so the i32 and i64
/// paths cannot drift apart (the bit-identity contract depends on them
/// reducing identically).
macro_rules! lane_kernels {
    ($pat_fn:ident, $val_fn:ident, $ty:ty) => {
        #[doc = concat!(
            "`acc[bi] ± (entries[patterns[bi]] << shift)` across the batch, `",
            stringify!($ty),
            "` lanes. `negate` selects the sign plane (two's-complement MSB weight)."
        )]
        #[inline]
        pub(crate) fn $pat_fn(
            entries: &[$ty],
            patterns: &[u32],
            shift: u32,
            negate: bool,
            acc: &mut [$ty],
        ) {
            debug_assert_eq!(patterns.len(), acc.len());
            let sign: $ty = if negate { -1 } else { 1 };
            let main = acc.len() - acc.len() % LANES;
            let (acc_main, acc_tail) = acc.split_at_mut(main);
            let (pat_main, pat_tail) = patterns.split_at(main);
            for (a, p) in acc_main.chunks_exact_mut(LANES).zip(pat_main.chunks_exact(LANES)) {
                for (ai, &pi) in a.iter_mut().zip(p) {
                    *ai += sign * (entries[pi as usize] << shift);
                }
            }
            for (ai, &pi) in acc_tail.iter_mut().zip(pat_tail) {
                *ai += sign * (entries[pi as usize] << shift);
            }
        }

        #[doc = concat!(
            "`acc[bi] ± (values[bi] << shift)` across the batch, `",
            stringify!($ty),
            "` lanes — the plane kernel for values already resolved through the PRT."
        )]
        #[inline]
        pub(crate) fn $val_fn(values: &[$ty], shift: u32, negate: bool, acc: &mut [$ty]) {
            debug_assert_eq!(values.len(), acc.len());
            let sign: $ty = if negate { -1 } else { 1 };
            let main = acc.len() - acc.len() % LANES;
            let (acc_main, acc_tail) = acc.split_at_mut(main);
            let (val_main, val_tail) = values.split_at(main);
            for (a, v) in acc_main.chunks_exact_mut(LANES).zip(val_main.chunks_exact(LANES)) {
                for (ai, &vi) in a.iter_mut().zip(v) {
                    *ai += sign * (vi << shift);
                }
            }
            for (ai, &vi) in acc_tail.iter_mut().zip(val_tail) {
                *ai += sign * (vi << shift);
            }
        }
    };
}

lane_kernels!(accum_patterns_i32, accum_values_i32, i32);
lane_kernels!(accum_patterns_i64, accum_values_i64, i64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn naive_patterns_i64(
        entries: &[i64],
        patterns: &[u32],
        shift: u32,
        negate: bool,
        acc: &mut [i64],
    ) {
        for (a, &p) in acc.iter_mut().zip(patterns) {
            let v = entries[p as usize] << shift;
            if negate {
                *a -= v;
            } else {
                *a += v;
            }
        }
    }

    #[test]
    fn lane_kernels_match_naive_all_batch_sizes() {
        let mut prng = Prng::new(17);
        let nbw = 4u32;
        for batch in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 32, 33] {
            let entries64: Vec<i64> = (0..1 << nbw).map(|_| prng.signed_bits(12)).collect();
            let entries32: Vec<i32> = entries64.iter().map(|&e| e as i32).collect();
            let patterns: Vec<u32> =
                (0..batch).map(|_| prng.gen_range(1 << nbw) as u32).collect();
            let init: Vec<i64> = (0..batch).map(|_| prng.signed_bits(16)).collect();
            // Value-kernel inputs: the same entries, pre-resolved.
            let vals64: Vec<i64> = patterns.iter().map(|&p| entries64[p as usize]).collect();
            let vals32: Vec<i32> = vals64.iter().map(|&v| v as i32).collect();
            for shift in [0u32, 3, 7] {
                for negate in [false, true] {
                    let mut want: Vec<i64> = init.clone();
                    naive_patterns_i64(&entries64, &patterns, shift, negate, &mut want);

                    let mut got64: Vec<i64> = init.clone();
                    accum_patterns_i64(&entries64, &patterns, shift, negate, &mut got64);
                    assert_eq!(got64, want, "i64 patterns b{batch} s{shift} n{negate}");

                    let mut got32: Vec<i32> = init.iter().map(|&a| a as i32).collect();
                    accum_patterns_i32(&entries32, &patterns, shift, negate, &mut got32);
                    let got32w: Vec<i64> = got32.iter().map(|&a| a as i64).collect();
                    assert_eq!(got32w, want, "i32 patterns b{batch} s{shift} n{negate}");

                    let mut gv64: Vec<i64> = init.clone();
                    accum_values_i64(&vals64, shift, negate, &mut gv64);
                    assert_eq!(gv64, want, "i64 values b{batch}");
                    let mut gv32: Vec<i32> = init.iter().map(|&a| a as i32).collect();
                    accum_values_i32(&vals32, shift, negate, &mut gv32);
                    let gv32w: Vec<i64> = gv32.iter().map(|&a| a as i64).collect();
                    assert_eq!(gv32w, want, "i32 values b{batch}");
                }
            }
        }
    }

    #[test]
    fn range_proof_boundary_is_exact() {
        for act_bits in [1u32, 2, 4, 8] {
            let limit = i32_safe_abs_weight_sum(act_bits);
            // The limit itself is safe, one past it is not.
            assert!(group_fits_i32(limit, act_bits), "act_bits={act_bits}");
            assert!(!group_fits_i32(limit + 1, act_bits), "act_bits={act_bits}");
            // The proof bound really does keep the worst case inside i32.
            assert!(limit * ((1u64 << act_bits) - 1) <= i32::MAX as u64);
            assert!((limit + 1) * ((1u64 << act_bits) - 1) > i32::MAX as u64);
        }
        // 8-bit activations: (2^31 - 1) / 255.
        assert_eq!(i32_safe_abs_weight_sum(8), 8_421_504);
    }

    #[test]
    fn abs_weight_sum_handles_i32_min() {
        assert_eq!(abs_weight_sum(&[i32::MIN, -1, 2]), (1u64 << 31) + 3);
        assert_eq!(abs_weight_sum(&[]), 0);
    }

    #[test]
    fn accumulation_at_proof_boundary_does_not_overflow_i32() {
        // One chunk whose entries reach Σ|w| = limit, all 8 planes additive
        // except the sign plane: the running i32 accumulator touches the
        // proof bound without wrapping.
        let act_bits = 8u32;
        let limit = i32_safe_abs_weight_sum(act_bits) as i32;
        let entries32 = vec![0i32, limit];
        let patterns = vec![1u32; 4];
        let mut acc = vec![0i32; 4];
        for plane in 0..act_bits {
            accum_patterns_i32(&entries32, &patterns, plane, plane == act_bits - 1, &mut acc);
        }
        // Σ_{p<7} limit·2^p − limit·2^7 = limit·(127 − 128) = −limit.
        assert!(acc.iter().all(|&a| a == -limit));
    }
}
