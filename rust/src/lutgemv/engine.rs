//! Exact functional LUT-GEMV engine.
//!
//! This is the numerical ground truth for the whole repository: the Pallas
//! kernel (python/compile/kernels/lut_gemv.py), the runtime artifacts, and
//! the cycle models all describe *this* computation. The engine's output is
//! bit-identical to the naive quantized dot product [`reference_gemv`],
//! because both reduce the same integers in the same per-group order and
//! only then apply float scales.
//!
//! Two's-complement bit-serial handling: for 8-bit activations the bit-plane
//! weight of plane b is `2^b` for b < 7 and `−2^7` for the sign plane, so
//! the engine adds the low planes' lookups and subtracts the sign plane's.

use crate::quant::{QuantizedMatrix, QuantizedVector};
use crate::csram::lut::Lut;

/// Counters the engine reports so cycle models and the PRT can be validated
/// against the functional execution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GemvStats {
    /// LUTs constructed (chunk × column tiles).
    pub luts_built: u64,
    /// LUT reads performed (after PRT bypasses).
    pub lut_reads: u64,
    /// LUT reads avoided by the Pattern Reuse Table.
    pub prt_hits: u64,
}

/// The LUT-GEMV engine for one weight matrix.
///
/// Weights are `[K, N]` (GEMV computes `y[1,N] = x[1,K] · W[K,N]`), group-
/// quantized along K — note this means a scale group spans *rows* of W for
/// a fixed output column, matching how llama.cpp stores the transposed
/// projection matrices.
pub struct LutGemvEngine {
    /// Quantized weights, stored transposed (`[N, K]` row-major) so that an
    /// output column's basis weights are contiguous — the layout the
    /// address hasher stripes across cache slices.
    wt: QuantizedMatrix,
    nbw: u32,
    /// Enable the Pattern Reuse Table (§III-D).
    pub use_prt: bool,
}

impl LutGemvEngine {
    /// Build from a transposed quantized matrix (`wt` is `[N, K]`).
    /// `nbw` must not exceed the scale group size.
    pub fn new(wt: QuantizedMatrix, nbw: u32) -> Self {
        assert!((1..=8).contains(&nbw));
        assert!(
            nbw as usize <= wt.group_size,
            "NBW {} exceeds scale group {}",
            nbw,
            wt.group_size
        );
        LutGemvEngine { wt, nbw, use_prt: false }
    }

    pub fn n(&self) -> usize {
        self.wt.rows
    }

    pub fn k(&self) -> usize {
        self.wt.cols
    }

    pub fn nbw(&self) -> u32 {
        self.nbw
    }

    pub fn weights(&self) -> &QuantizedMatrix {
        &self.wt
    }

    /// Compute `y = x · W` for a batch of activation vectors, exactly.
    /// Returns (outputs, stats). LUTs are built once per (column, chunk)
    /// and reused across the whole batch — the amortization that makes
    /// batching effective (§III-C).
    ///
    /// Hot-path notes (§Perf): activation bit patterns depend only on
    /// (chunk, plane, batch item) — *not* on the output column — so they
    /// are extracted once up front instead of N times; the column loop
    /// unpacks weight codes and builds LUT entries into reusable buffers
    /// (no allocation inside the N×chunks loop). This took the engine
    /// from ~2.1e7 to >1e8 MACs/s.
    pub fn gemv_batch(&self, xs: &[QuantizedVector]) -> (Vec<Vec<f32>>, GemvStats) {
        let k = self.k();
        let n = self.n();
        for x in xs {
            assert_eq!(x.len(), k, "activation length mismatch");
        }
        let mut stats = GemvStats::default();
        let nbw = self.nbw as usize;
        let group = self.wt.group_size;
        let chunks_per_group = (group + nbw - 1) / nbw;
        let groups = k / group;
        let n_chunks = groups * chunks_per_group;
        let act_bits = xs.first().map(|x| x.bits as usize).unwrap_or(8);

        // Pattern table: patterns[(chunk * act_bits + plane) * batch + bi].
        let batch = xs.len();
        let mut patterns = vec![0u32; n_chunks * act_bits * batch];
        for (ci, chunk) in (0..n_chunks).enumerate() {
            let g = chunk / chunks_per_group;
            let c = chunk % chunks_per_group;
            let start = g * group + c * nbw;
            for plane in 0..act_bits {
                for (bi, x) in xs.iter().enumerate() {
                    patterns[(ci * act_bits + plane) * batch + bi] =
                        x.pattern(start, self.nbw, plane as u32);
                }
            }
        }

        let mut out = vec![vec![0.0f32; n]; batch];
        let mut wrow = vec![0i32; k];
        let mut basis = vec![0i64; nbw];
        let mut entries = vec![0i64; 1usize << nbw];
        let mut acc = vec![0i64; batch];
        let mut prt = super::pattern::PatternReuseTable::new(32);

        for col in 0..n {
            // wt row `col` holds the K basis weights for output column col.
            self.wt.packed().unpack_range_into(col * k, &mut wrow);
            for g in 0..groups {
                let scale_w = self.wt.scale(col, g * group);
                acc.iter_mut().for_each(|a| *a = 0);
                for c in 0..chunks_per_group {
                    let start = g * group + c * nbw;
                    let end = (start + nbw).min((g + 1) * group);
                    // Basis weights (zero-padded to NBW at the group tail).
                    basis.iter_mut().for_each(|b| *b = 0);
                    for (i, kk) in (start..end).enumerate() {
                        basis[i] = wrow[kk] as i64;
                    }
                    Lut::build_into(&basis, self.nbw, &mut entries);
                    stats.luts_built += 1;
                    let chunk = g * chunks_per_group + c;
                    let pat_base = chunk * act_bits * batch;
                    if self.use_prt {
                        prt.flush(); // new LUT ⇒ stored results are stale
                        for plane in 0..act_bits {
                            for bi in 0..batch {
                                let pat = patterns[pat_base + plane * batch + bi];
                                let v = match prt.lookup(pat) {
                                    Some(hit) => {
                                        stats.prt_hits += 1;
                                        hit
                                    }
                                    None => {
                                        let v = entries[pat as usize];
                                        stats.lut_reads += 1;
                                        prt.insert(pat, v);
                                        v
                                    }
                                };
                                if plane == act_bits - 1 {
                                    acc[bi] -= v << plane;
                                } else {
                                    acc[bi] += v << plane;
                                }
                            }
                        }
                    } else {
                        for plane in 0..act_bits {
                            let neg = plane == act_bits - 1;
                            for bi in 0..batch {
                                let pat = patterns[pat_base + plane * batch + bi];
                                let v = entries[pat as usize];
                                if neg {
                                    acc[bi] -= v << plane;
                                } else {
                                    acc[bi] += v << plane;
                                }
                            }
                        }
                        stats.lut_reads += (act_bits * batch) as u64;
                    }
                }
                for (bi, x) in xs.iter().enumerate() {
                    out[bi][col] += acc[bi] as f32 * scale_w * x.scale;
                }
            }
        }
        (out, stats)
    }

    /// Single-vector convenience wrapper.
    pub fn gemv(&self, x: &QuantizedVector) -> Vec<f32> {
        self.gemv_batch(std::slice::from_ref(x)).0.remove(0)
    }
}

/// The naive reference: dequantize-free integer dot product per scale
/// group, then scale — the semantics llama.cpp's quantized kernels use and
/// the oracle the LUT path must match bit-for-bit.
pub fn reference_gemv(wt: &QuantizedMatrix, x: &QuantizedVector) -> Vec<f32> {
    assert_eq!(x.len(), wt.cols);
    let group = wt.group_size;
    let groups = wt.cols / group;
    (0..wt.rows)
        .map(|col| {
            let mut y = 0.0f32;
            for g in 0..groups {
                let mut acc = 0i64;
                for kk in g * group..(g + 1) * group {
                    acc += wt.q(col, kk) as i64 * x.q[kk] as i64;
                }
                y += acc as f32 * wt.scale(col, g * group) * x.scale;
            }
            y
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantLevel;
    use crate::util::{propcheck, Prng};

    fn random_setup(
        prng: &mut Prng,
        n: usize,
        k: usize,
        level: QuantLevel,
        group: usize,
    ) -> (QuantizedMatrix, Vec<QuantizedVector>) {
        let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, n, k, level, group);
        let batch = prng.usize_in(1, 5);
        let xs = (0..batch)
            .map(|_| {
                let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
                QuantizedVector::quantize(&x)
            })
            .collect();
        (wt, xs)
    }

    #[test]
    fn matches_reference_bit_exactly_all_levels() {
        let mut prng = Prng::new(101);
        for level in QuantLevel::ALL {
            for nbw in [1u32, 2, 3, 4] {
                let (wt, xs) = random_setup(&mut prng, 8, 64, level, 32);
                let eng = LutGemvEngine::new(wt, nbw);
                let (ys, _) = eng.gemv_batch(&xs);
                for (x, y) in xs.iter().zip(ys.iter()) {
                    let want = reference_gemv(eng.weights(), x);
                    assert_eq!(y, &want, "level={level} nbw={nbw}");
                }
            }
        }
    }

    #[test]
    fn property_exactness_random_shapes() {
        propcheck::check(
            "lut-gemv-exact",
            propcheck::Config { cases: 60, seed: 103 },
            |p, _| {
                let level = QuantLevel::ALL[p.usize_in(0, 6)];
                let nbw = p.usize_in(1, 5) as u32;
                let group = [8usize, 16, 32][p.usize_in(0, 3)];
                let k = group * p.usize_in(1, 4);
                let n = p.usize_in(1, 12);
                let seed = p.next_u64();
                (level, nbw, group, k, n, seed)
            },
            |&(level, nbw, group, k, n, seed)| {
                let mut prng = Prng::new(seed);
                let (wt, xs) = random_setup(&mut prng, n, k, level, group);
                let eng = LutGemvEngine::new(wt, nbw);
                let (ys, _) = eng.gemv_batch(&xs);
                for (x, y) in xs.iter().zip(ys.iter()) {
                    let want = reference_gemv(eng.weights(), x);
                    if y != &want {
                        return Err(format!("mismatch at level={level} nbw={nbw}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prt_does_not_change_results() {
        let mut prng = Prng::new(105);
        let (wt, xs) = random_setup(&mut prng, 6, 64, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 3);
        let (plain, s0) = eng.gemv_batch(&xs);
        eng.use_prt = true;
        let (with_prt, s1) = eng.gemv_batch(&xs);
        assert_eq!(plain, with_prt);
        assert_eq!(s0.prt_hits, 0);
        assert!(s1.prt_hits > 0, "PRT never hit: {s1:?}");
        // Every access is either a read or a hit; totals match.
        assert_eq!(s0.lut_reads, s1.lut_reads + s1.prt_hits);
    }

    #[test]
    fn lut_build_count_amortized_over_batch() {
        let mut prng = Prng::new(107);
        let k = 64;
        let group = 32;
        let nbw = 4u32;
        let w: Vec<f32> = (0..4 * k).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, 4, k, QuantLevel::Q4, group);
        let eng = LutGemvEngine::new(wt, nbw);
        let x1: Vec<QuantizedVector> = (0..1)
            .map(|_| QuantizedVector::quantize(&vec![0.5; k]))
            .collect();
        let x8: Vec<QuantizedVector> = (0..8)
            .map(|_| QuantizedVector::quantize(&vec![0.5; k]))
            .collect();
        let (_, s1) = eng.gemv_batch(&x1);
        let (_, s8) = eng.gemv_batch(&x8);
        // Same LUT count regardless of batch (reuse), 8x the reads.
        assert_eq!(s1.luts_built, s8.luts_built);
        assert_eq!(s8.lut_reads, 8 * s1.lut_reads);
        // chunks = K/NBW × N = 16 × 4.
        assert_eq!(s1.luts_built, 64);
    }

    #[test]
    fn nbw_not_dividing_group_still_exact() {
        // group 32, NBW 3 → 11 chunks per group with a 2-wide tail.
        let mut prng = Prng::new(109);
        let (wt, xs) = random_setup(&mut prng, 5, 96, QuantLevel::Q5, 32);
        let eng = LutGemvEngine::new(wt, 3);
        let (ys, _) = eng.gemv_batch(&xs);
        for (x, y) in xs.iter().zip(ys.iter()) {
            assert_eq!(y, &reference_gemv(eng.weights(), x));
        }
    }

    #[test]
    fn extreme_activation_values_exact() {
        // int8 sign plane (−128..127 boundaries) must be handled exactly.
        let k = 32;
        let w: Vec<f32> = (0..k).map(|i| (i as f32 - 16.0) / 8.0).collect();
        let wt = QuantizedMatrix::quantize(&w, 1, k, QuantLevel::Q8, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let mut q = vec![0i8; k];
        q[0] = -127;
        q[1] = 127;
        q[2] = -1;
        q[3] = 1;
        let x = QuantizedVector { q, scale: 0.33, bits: 8 };
        assert_eq!(eng.gemv(&x), reference_gemv(eng.weights(), &x));
    }

    #[test]
    #[should_panic(expected = "NBW 8 exceeds scale group 4")]
    fn nbw_gt_group_rejected() {
        let w = vec![0.0f32; 8];
        let wt = QuantizedMatrix::quantize(&w, 2, 4, QuantLevel::Q4, 4);
        let _ = LutGemvEngine::new(wt, 8);
    }
}
