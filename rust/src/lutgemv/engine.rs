//! Exact functional LUT-GEMV engine — tiled, thread-parallel execution
//! backend.
//!
//! This is the numerical ground truth for the whole repository: the Pallas
//! kernel (python/compile/kernels/lut_gemv.py), the runtime artifacts, and
//! the cycle models all describe *this* computation. The engine's output is
//! bit-identical to the naive quantized dot product [`reference_gemv`],
//! because both reduce the same integers in the same per-group order and
//! only then apply float scales.
//!
//! Execution model (§III-C, 16 thread-pipelines in the paper's figures):
//! the N output columns are cut into [`LutGemvEngine::tile_cols`]-wide
//! tiles; each tile runs the allocation-free kernel in
//! [`super::tile`] with arena-recycled scratch, fanned out across a
//! persistent [`crate::runtime::WorkerPool`]. Because every column's
//! integer accumulation order is fixed and float scaling happens per
//! column, outputs and [`GemvStats`] are bit-identical at every thread
//! count — parallelism is an execution detail, not a numerics change.
//!
//! NUMA placement (the software analogue of the paper's premise that the
//! win comes from keeping weight traffic next to the compute): an engine
//! built with [`LutGemvEngine::with_pool`] splits its output columns into
//! one contiguous *shard per node group* of the pool's placement, gives
//! each node a first-touch copy of exactly the `[N, K]` weight rows (and
//! range-proof sums, and scratch arena) its tiles read, and routes every
//! tile job to the owning node's pinned workers. Shard copies are
//! integer-identical to the master matrix and each column's computation is
//! independent, so placement, like threading, is invisible in the output
//! — pinned by `tests/numa_placement.rs`. Engines built with
//! [`LutGemvEngine::new`] (or any engine on a single-node host /
//! `SAIL_NUMA=off`) keep one shard sharing the master weights, which is
//! exactly the pre-NUMA layout with zero copies.
//!
//! Within each scale group the kernel accumulates on the lane-parallel
//! `i32` path of [`super::planes`] whenever the per-group range proof
//! holds (it always does for realistic shapes), falling back to `i64`
//! otherwise — also invisible in the output, by construction and by the
//! conformance suite (`tests/plane_conformance.rs`).
//!
//! Two's-complement bit-serial handling: for 8-bit activations the bit-plane
//! weight of plane b is `2^b` for b < 7 and `−2^7` for the sign plane, so
//! the engine adds the low planes' lookups and subtracts the sign plane's.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::planes;
use super::tile::{run_tile, GemvOutput, ScratchArena, TileArgs};
use crate::quant::{QuantizedMatrix, QuantizedVector};
use crate::runtime::faults::FaultPlan;
use crate::runtime::reclaim::{ReclaimDomain, ReclaimStats};
use crate::runtime::WorkerPool;

/// Counters the engine reports so cycle models and the PRT can be validated
/// against the functional execution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GemvStats {
    /// LUTs constructed (chunk × column tiles).
    pub luts_built: u64,
    /// LUT reads performed (after PRT bypasses).
    pub lut_reads: u64,
    /// LUT reads avoided by the Pattern Reuse Table.
    pub prt_hits: u64,
}

impl std::ops::AddAssign for GemvStats {
    fn add_assign(&mut self, rhs: GemvStats) {
        self.luts_built += rhs.luts_built;
        self.lut_reads += rhs.lut_reads;
        self.prt_hits += rhs.prt_hits;
    }
}

/// The LUT-GEMV engine for one weight matrix.
///
/// Weights are `[K, N]` (GEMV computes `y[1,N] = x[1,K] · W[K,N]`), group-
/// quantized along K — note this means a scale group spans *rows* of W for
/// a fixed output column, matching how llama.cpp stores the transposed
/// projection matrices.
pub struct LutGemvEngine {
    /// The live weight snapshot: master matrix plus per-node shards,
    /// swapped atomically by [`publish_weights`](Self::publish_weights).
    /// Readers clone the two inner `Arc`s once per call under this lock
    /// (two refcount bumps — the lock is never held across the dispatch),
    /// so a publish never blocks on or races with in-flight GEMVs.
    snap: Mutex<WeightSnapshot>,
    /// Output columns (`wt.rows`) — immutable across swaps, cached so the
    /// hot path and shape checks never take the snapshot lock.
    n: usize,
    /// Activation length (`wt.cols`) — immutable across swaps.
    k: usize,
    /// Scale group size — immutable across swaps (publish re-validates).
    group_size: usize,
    nbw: u32,
    /// Enable the Pattern Reuse Table (§III-D).
    pub use_prt: bool,
    /// PRT entries per DFM (paper: 32). Tunable so DFM sizing experiments
    /// — and the generational-reclaim tests at capacity 1–2 — run on the
    /// real engine path.
    pub prt_capacity: usize,
    /// Disable the lane-parallel i32 accumulation and force the i64
    /// scalar path everywhere — the reference side of the conformance
    /// suite and the "before" side of the §Perf lane benches.
    pub force_scalar_accum: bool,
    /// Output columns per tile handed to one worker. The default (64)
    /// keeps a tile's scratch (K×i32 weight row + LUT + accumulators)
    /// L1-resident while giving the pool enough tiles to balance; tests
    /// shrink it to force multi-tile execution on tiny matrices. Tiles
    /// never straddle a shard boundary (each shard tiles independently).
    pub tile_cols: usize,
    /// Deferred-reclamation domain for retired snapshots: every GEMV pins
    /// it for the call's duration, and `publish_weights` retires the old
    /// snapshot through it — so the observable [`reclaim_stats`]
    /// (Self::reclaim_stats) counters prove retired shards are dropped
    /// only after the last in-flight reader, and never leak.
    domain: Arc<ReclaimDomain>,
    /// Recycled per-call pattern/scale/tile buffers, recovered from the
    /// call context after every dispatch. A small stack (not a single
    /// slot) so concurrent `gemv_batch_into` calls on one shared engine
    /// each get a reusable set instead of racing for one and dropping the
    /// loser's.
    call_buffers: Mutex<Vec<CallBuffers>>,
}

/// One generation of the engine's weights: the master `[N, K]` matrix
/// (the reference oracle) plus the per-node shards the hot path reads.
/// Swapped as a unit by [`LutGemvEngine::publish_weights`]; the retired
/// generation is handed to the engine's [`ReclaimDomain`] and dropped
/// only after every GEMV pinned before the swap has finished.
struct WeightSnapshot {
    /// Quantized weights, stored transposed (`[N, K]` row-major) so that an
    /// output column's basis weights are contiguous — the layout the
    /// address hasher stripes across cache slices. `Arc`-held because tile
    /// jobs on persistent pool workers share it without borrowing.
    wt: Arc<QuantizedMatrix>,
    /// Per-node weight shards: contiguous column ranges, each with its own
    /// weight slice, range-proof sums, and scratch arena — single entry
    /// (sharing the master `Arc`s, no copy) for unplaced engines.
    shards: Arc<Vec<NodeShard>>,
}

/// One node group's slice of the engine: the output columns
/// `[col_start, col_end)`, their weights/range-proof sums (exact copies of
/// the master's rows — bit-identical GEMV by construction), and a scratch
/// arena whose buffers live on the owning node, so tile-job checkout never
/// crosses a socket.
struct NodeShard {
    col_start: usize,
    col_end: usize,
    wt: Arc<QuantizedMatrix>,
    /// Per-(local column, scale-group) `Σ|w|`, `[col * groups_per_row + g]`
    /// — the lane range-proof input, precomputed at construction.
    group_abs_sums: Arc<Vec<u64>>,
    arena: Arc<ScratchArena>,
}

/// One tile of one call: which shard owns it and its *global* column
/// range (`tile_job` rebases to shard-local indices).
#[derive(Debug, Clone, Copy)]
struct TileDesc {
    shard: usize,
    col_start: usize,
    col_end: usize,
}

#[derive(Default)]
struct CallBuffers {
    patterns: Vec<u32>,
    x_scales: Vec<f32>,
    tiles: Vec<TileDesc>,
}

/// Default column-tile width (see [`LutGemvEngine::tile_cols`]).
pub const DEFAULT_TILE_COLS: usize = 64;

/// Default Pattern Reuse Table capacity (paper §III-D: 32 entries per DFM).
pub const DEFAULT_PRT_CAPACITY: usize = 32;

/// Everything one `gemv_batch_into` call shares with its tile jobs. Owned
/// (`'static`) so jobs can run on persistent pool workers without
/// borrowing from the caller; the big buffers inside are recycled — the
/// engine recovers them via `Arc::try_unwrap` once every tile reported.
struct GemvCall {
    shards: Arc<Vec<NodeShard>>,
    nbw: u32,
    use_prt: bool,
    prt_capacity: usize,
    force_scalar_accum: bool,
    patterns: Vec<u32>,
    x_scales: Vec<f32>,
    tiles: Vec<TileDesc>,
    act_bits: usize,
    batch: usize,
    k: usize,
    /// The dispatching pool's armed fault schedule, if any — tile jobs
    /// consult it for injected stalls and poisoned scratch checkouts
    /// (`None`, the fault-free fast path, costs one atomic load per call).
    faults: Option<Arc<FaultPlan>>,
}

/// One tile's report back to the dispatcher. The output buffer returns to
/// the owning shard's arena after the engine scatters it.
struct TileReport {
    shard: usize,
    col_start: usize,
    col_end: usize,
    out: Vec<f32>,
    stats: GemvStats,
}

/// The per-tile job body (stateless; all inputs come through the call
/// context, as the persistent pool requires). Reads only the owning
/// shard's weights and arena — on a placed engine everything this touches
/// per iteration, except the small shared pattern table, is node-local.
fn tile_job(call: &GemvCall, t: usize) -> TileReport {
    let desc = call.tiles[t];
    let shard = &call.shards[desc.shard];
    let width = desc.col_end - desc.col_start;
    let faults = call.faults.as_deref();
    if let Some(d) = faults.and_then(|p| p.slow_tile()) {
        // Injected stall: the tile computes correctly, just late — this
        // exercises the dispatcher's heal-poll path without losing work.
        std::thread::sleep(d);
    }
    let mut scratch =
        shard.arena.checkout_scratch(call.k, call.nbw, call.batch, call.prt_capacity, faults);
    let mut out = shard.arena.checkout_out(call.batch * width);
    let args = TileArgs {
        wt: &shard.wt,
        group_abs_sums: &shard.group_abs_sums,
        nbw: call.nbw,
        use_prt: call.use_prt,
        force_scalar_accum: call.force_scalar_accum,
        patterns: &call.patterns,
        act_bits: call.act_bits,
        batch: call.batch,
        x_scales: &call.x_scales,
        col_start: desc.col_start - shard.col_start,
        col_end: desc.col_end - shard.col_start,
    };
    let stats = run_tile(&args, &mut scratch, &mut out);
    shard.arena.checkin_scratch(scratch);
    TileReport { shard: desc.shard, col_start: desc.col_start, col_end: desc.col_end, out, stats }
}

/// Context of the first-touch shard build: each node builds its own slice
/// on one of its own (pinned) workers, so the copied pages are allocated
/// on that node under the kernel's first-touch policy.
struct ShardBuild {
    wt: Arc<QuantizedMatrix>,
    group_abs_sums: Arc<Vec<u64>>,
    ranges: Vec<(usize, usize)>,
}

fn build_shard(ctx: &ShardBuild, i: usize) -> NodeShard {
    let (r0, r1) = ctx.ranges[i];
    let gpr = ctx.wt.groups_per_row();
    NodeShard {
        col_start: r0,
        col_end: r1,
        wt: Arc::new(ctx.wt.slice_rows(r0, r1)),
        group_abs_sums: Arc::new(ctx.group_abs_sums[r0 * gpr..r1 * gpr].to_vec()),
        arena: Arc::new(ScratchArena::new()),
    }
}

impl LutGemvEngine {
    /// Build from a transposed quantized matrix (`wt` is `[N, K]`).
    /// `nbw` must not exceed the scale group size.
    ///
    /// The engine has a single weight shard sharing the master matrix (no
    /// copies) — correct on any pool, NUMA-local on none. Use
    /// [`with_pool`](LutGemvEngine::with_pool) to place the weights for a
    /// specific pool.
    ///
    /// ```
    /// use sail::lutgemv::LutGemvEngine;
    /// use sail::quant::{QuantLevel, QuantizedMatrix};
    ///
    /// let w = vec![0.5f32; 8 * 16]; // 8 output columns, K = 16
    /// let wt = QuantizedMatrix::quantize(&w, 8, 16, QuantLevel::Q4, 16);
    /// let eng = LutGemvEngine::new(wt, 4);
    /// assert_eq!((eng.n(), eng.k(), eng.nbw()), (8, 16, 4));
    /// ```
    pub fn new(wt: QuantizedMatrix, nbw: u32) -> Self {
        Self::check_shape(&wt, nbw);
        let (n, k, group_size) = (wt.rows, wt.cols, wt.group_size);
        LutGemvEngine {
            snap: Mutex::new(Self::build_snapshot(wt, None)),
            n,
            k,
            group_size,
            nbw,
            use_prt: false,
            prt_capacity: DEFAULT_PRT_CAPACITY,
            force_scalar_accum: false,
            tile_cols: DEFAULT_TILE_COLS,
            domain: Arc::new(ReclaimDomain::new()),
            call_buffers: Mutex::new(Vec::new()),
        }
    }

    /// Build an engine *placed for* `pool`: output columns are split into
    /// one contiguous shard per node group of the pool's placement
    /// (proportional to worker counts), and each node's workers build
    /// their own first-touch copy of exactly the weight rows they will
    /// serve. Dispatching on `pool` then routes every tile to the node
    /// that owns its weights.
    ///
    /// On a single-group pool (serial, `SAIL_NUMA=off`, or a single-node
    /// host) this is identical to [`new`](LutGemvEngine::new): one shard,
    /// zero copies. An engine placed for one pool may still be dispatched
    /// on a differently-shaped pool — outputs stay bit-identical, the
    /// dispatch just falls back to unrouted (locality-blind) fan-out.
    pub fn with_pool(wt: QuantizedMatrix, nbw: u32, pool: &WorkerPool) -> Self {
        let eng = Self::new(wt, nbw);
        let placed = {
            let snap = eng.snap.lock().unwrap();
            Self::build_snapshot_for_pool(&snap.wt, &snap.shards[0].group_abs_sums, pool)
        };
        if let Some(placed) = placed {
            *eng.snap.lock().unwrap() = placed;
        }
        eng
    }

    /// One snapshot with a single shard sharing the master `Arc`s (the
    /// unplaced / single-node layout, zero copies). `abs_sums` lets a
    /// publish reuse sums already computed for shape validation.
    fn build_snapshot(wt: QuantizedMatrix, abs_sums: Option<Vec<u64>>) -> WeightSnapshot {
        let wt = Arc::new(wt);
        let group_abs_sums =
            Arc::new(abs_sums.unwrap_or_else(|| Self::compute_abs_sums(&wt)));
        let shard = NodeShard {
            col_start: 0,
            col_end: wt.rows,
            wt: Arc::clone(&wt),
            group_abs_sums,
            arena: Arc::new(ScratchArena::new()),
        };
        WeightSnapshot { wt, shards: Arc::new(vec![shard]) }
    }

    /// Multi-shard snapshot placed for `pool` (first-touch copies built on
    /// the owning nodes' workers), or `None` when the pool has a single
    /// node group and the unplaced snapshot is already the right layout.
    fn build_snapshot_for_pool(
        wt: &Arc<QuantizedMatrix>,
        group_abs_sums: &Arc<Vec<u64>>,
        pool: &WorkerPool,
    ) -> Option<WeightSnapshot> {
        let ranges = pool.placement().shard_ranges(wt.rows);
        if ranges.len() <= 1 {
            return None;
        }
        let ctx = Arc::new(ShardBuild {
            wt: Arc::clone(wt),
            group_abs_sums: Arc::clone(group_abs_sums),
            ranges,
        });
        let n = ctx.ranges.len();
        // Routed so shard i is built (first-touched) on node i.
        let shards = pool.run_ctx_routed(&ctx, n, |_, i| i, build_shard);
        Some(WeightSnapshot { wt: Arc::clone(wt), shards: Arc::new(shards) })
    }

    /// Publish a new weight matrix under live traffic: build its shards
    /// (placed for `pool`, like [`with_pool`](Self::with_pool)), swap the
    /// live snapshot, and retire the old one through the engine's
    /// [`ReclaimDomain`]. In-flight GEMVs that pinned the old snapshot
    /// finish on it bit-identically; calls entering after the swap read
    /// the new weights. The retired shards are dropped — observably, via
    /// [`reclaim_stats`](Self::reclaim_stats) — once the last pre-swap
    /// reader is gone.
    ///
    /// The new matrix must match the engine's immutable shape contract
    /// (`[N, K]`, same scale group size) — logits width, activation
    /// length, and chunk geometry must not change under a live serving
    /// loop. Tunables (`use_prt`, `tile_cols`, …) are engine state, not
    /// snapshot state, and are unaffected.
    pub fn publish_weights(&self, wt: QuantizedMatrix, pool: &WorkerPool) -> Result<()> {
        if wt.rows != self.n || wt.cols != self.k {
            bail!(
                "weight swap shape mismatch: engine serves [{}, {}], got [{}, {}]",
                self.n,
                self.k,
                wt.rows,
                wt.cols
            );
        }
        if wt.group_size != self.group_size {
            bail!(
                "weight swap group mismatch: engine group {}, got {}",
                self.group_size,
                wt.group_size
            );
        }
        Self::check_shape(&wt, self.nbw);
        // Build the full new snapshot *before* taking the snapshot lock:
        // the expensive part (abs sums + first-touch shard copies) runs
        // concurrently with in-flight GEMVs on the old weights.
        let mut next = Self::build_snapshot(wt, None);
        if let Some(placed) =
            Self::build_snapshot_for_pool(&next.wt, &next.shards[0].group_abs_sums, pool)
        {
            next = placed;
        }
        let old = std::mem::replace(&mut *self.snap.lock().unwrap(), next);
        // Swap happened first, so readers pinning from here on can only
        // see the new snapshot; retire makes the old one collectable once
        // every earlier pin is released.
        self.domain.retire(Box::new(old));
        self.domain.collect();
        Ok(())
    }

    /// Counters of the engine's snapshot reclamation (see
    /// [`ReclaimDomain`]): how many snapshots were retired by weight
    /// swaps, how many have been dropped, and how many await a grace
    /// period behind in-flight GEMVs.
    pub fn reclaim_stats(&self) -> ReclaimStats {
        self.domain.stats()
    }

    fn check_shape(wt: &QuantizedMatrix, nbw: u32) {
        assert!((1..=8).contains(&nbw));
        assert!(
            nbw as usize <= wt.group_size,
            "NBW {} exceeds scale group {}",
            nbw,
            wt.group_size
        );
    }

    /// One O(N·K) pass at construction: per-(col, group) `Σ|w|` for the
    /// lane range proof, so the hot loop only compares against it.
    fn compute_abs_sums(wt: &QuantizedMatrix) -> Vec<u64> {
        let groups_per_row = wt.cols / wt.group_size;
        let mut group_abs_sums = vec![0u64; wt.rows * groups_per_row];
        let mut row = vec![0i32; wt.cols];
        for r in 0..wt.rows {
            wt.packed().unpack_range_into(r * wt.cols, &mut row);
            for g in 0..groups_per_row {
                group_abs_sums[r * groups_per_row + g] =
                    planes::abs_weight_sum(&row[g * wt.group_size..(g + 1) * wt.group_size]);
            }
        }
        group_abs_sums
    }

    /// Number of weight shards (node groups this engine was placed for;
    /// 1 when unplaced).
    pub fn shard_count(&self) -> usize {
        self.snap.lock().unwrap().shards.len()
    }

    /// The shard column boundaries, `(col_start, col_end)` per shard —
    /// observability for placement tests and the perf bench.
    pub fn shard_bounds(&self) -> Vec<(usize, usize)> {
        let snap = self.snap.lock().unwrap();
        snap.shards.iter().map(|s| (s.col_start, s.col_end)).collect()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn nbw(&self) -> u32 {
        self.nbw
    }

    /// The *current* master weight matrix (the reference oracle). Returns
    /// a clone of the snapshot's `Arc`: a concurrent
    /// [`publish_weights`](Self::publish_weights) swaps what future calls
    /// see, but never invalidates a matrix already handed out.
    pub fn weights(&self) -> Arc<QuantizedMatrix> {
        Arc::clone(&self.snap.lock().unwrap().wt)
    }

    /// The scratch/output recycling arena of the *first* shard of the
    /// current snapshot (tests assert steady-state buffer reuse through
    /// its counters; unplaced engines have exactly one shard, so this is
    /// *the* arena for them). Placed engines keep one arena per node so
    /// checkout never crosses a socket. Arenas belong to a snapshot and
    /// are retired with it on a weight swap.
    pub fn scratch_arena(&self) -> Arc<ScratchArena> {
        Arc::clone(&self.snap.lock().unwrap().shards[0].arena)
    }

    /// Compute `y = x · W` for a batch of activation vectors, exactly,
    /// into a caller-owned [`GemvOutput`] (reused across calls: the serving
    /// loop never reallocates the logits buffer). Column tiles fan out
    /// across `pool`; outputs and stats are bit-identical at every thread
    /// count (each column's accumulation order is fixed, tile results are
    /// scattered in tile order, and stats are commutatively summed u64s).
    ///
    /// LUTs are built once per (column, chunk) and reused across the whole
    /// batch — the amortization that makes batching effective (§III-C).
    ///
    /// Hot-path notes (§Perf): activation bit patterns depend only on
    /// (chunk, plane, batch item) — *not* on the output column — so they
    /// are extracted once up front instead of N times; each group
    /// accumulates on the i32 lane kernels when its range proof holds
    /// (`super::planes`); tile scratch and tile outputs are recycled
    /// through the engine's per-node [`ScratchArena`]s, and the
    /// pattern/scale buffers are recovered from the call context after
    /// every dispatch — so a steady-state call reuses every large buffer
    /// it touches.
    ///
    /// # Errors
    ///
    /// A dead pool worker is *not* an error — the pool heals it and
    /// re-executes the lost tiles inline, bit-identically. `Err` means a
    /// tile's own computation failed even on the inline retry (a
    /// [`PoolError`](crate::runtime::PoolError) naming the tile and
    /// node); the engine and its buffers remain usable, and the serving
    /// layer maps the failure to a per-request typed finish instead of a
    /// process abort.
    ///
    /// ```
    /// use sail::lutgemv::{GemvOutput, LutGemvEngine};
    /// use sail::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
    /// use sail::runtime::WorkerPool;
    ///
    /// let w: Vec<f32> = (0..8 * 16).map(|i| (i as f32 - 64.0) / 64.0).collect();
    /// let wt = QuantizedMatrix::quantize(&w, 8, 16, QuantLevel::Q4, 16);
    /// let eng = LutGemvEngine::new(wt, 4);
    /// let x = QuantizedVector::quantize(&[0.5f32; 16]);
    ///
    /// // The same output buffer is reused across calls and pools…
    /// let mut out = GemvOutput::new();
    /// let serial = WorkerPool::serial();
    /// let stats = eng.gemv_batch_into(&[x.clone(), x.clone()], &serial, &mut out).unwrap();
    /// assert_eq!((out.batch(), out.n()), (2, 8));
    /// let first = out.row(0).to_vec();
    ///
    /// // …and a threaded pool produces bit-identical results and stats.
    /// let pool = WorkerPool::new(2);
    /// let stats2 = eng.gemv_batch_into(&[x.clone(), x], &pool, &mut out).unwrap();
    /// assert_eq!(out.row(0), first.as_slice());
    /// assert_eq!(stats, stats2);
    /// ```
    pub fn gemv_batch_into(
        &self,
        xs: &[QuantizedVector],
        pool: &WorkerPool,
        out: &mut GemvOutput,
    ) -> Result<GemvStats> {
        let k = self.k();
        let n = self.n();
        let batch = xs.len();
        out.reset(batch, n);
        if batch == 0 {
            // Nothing to compute: do not walk columns or build LUTs for
            // zero activations.
            return Ok(GemvStats::default());
        }
        for x in xs {
            assert_eq!(x.len(), k, "activation length mismatch");
        }
        let act_bits = xs[0].bits as usize;
        assert!(
            (1..=8).contains(&act_bits),
            "activation width {act_bits} outside the bit-serial range"
        );
        for x in xs {
            assert_eq!(x.bits as usize, act_bits, "mixed activation widths in one batch");
        }

        // Pin the reclaim domain for the whole call, then take one clone
        // of the snapshot's shard list: a concurrent `publish_weights`
        // cannot reclaim these shards until the guard drops, and this call
        // computes entirely on the generation it pinned — bit-identical to
        // a call with no swap in flight.
        let _reclaim_pin = self.domain.pin();
        let shards = Arc::clone(&self.snap.lock().unwrap().shards);

        let nbw = self.nbw as usize;
        let group = self.group_size;
        let chunks_per_group = group.div_ceil(nbw);
        let groups = k / group;
        let n_chunks = groups * chunks_per_group;

        // Pattern table: patterns[(chunk * act_bits + plane) * batch + bi].
        // The buffers come from (and return to) the recycled call storage.
        let CallBuffers { mut patterns, mut x_scales, mut tiles } =
            self.call_buffers.lock().unwrap().pop().unwrap_or_default();
        patterns.resize(n_chunks * act_bits * batch, 0);
        for chunk in 0..n_chunks {
            let g = chunk / chunks_per_group;
            let c = chunk % chunks_per_group;
            let start = g * group + c * nbw;
            for plane in 0..act_bits {
                for (bi, x) in xs.iter().enumerate() {
                    patterns[(chunk * act_bits + plane) * batch + bi] =
                        x.pattern(start, self.nbw, plane as u32);
                }
            }
        }
        x_scales.clear();
        x_scales.extend(xs.iter().map(|x| x.scale));

        // Cut each shard's column range into tiles (tiles never straddle a
        // shard boundary, so every tile has exactly one home node).
        let tile_cols = self.tile_cols.max(1);
        tiles.clear();
        for (si, shard) in shards.iter().enumerate() {
            let mut c = shard.col_start;
            while c < shard.col_end {
                let e = (c + tile_cols).min(shard.col_end);
                tiles.push(TileDesc { shard: si, col_start: c, col_end: e });
                c = e;
            }
        }
        let n_tiles = tiles.len();
        let ctx = Arc::new(GemvCall {
            shards: Arc::clone(&shards),
            nbw: self.nbw,
            use_prt: self.use_prt,
            prt_capacity: self.prt_capacity.max(1),
            force_scalar_accum: self.force_scalar_accum,
            patterns,
            x_scales,
            tiles,
            act_bits,
            batch,
            k,
            faults: pool.fault_plan(),
        });
        // Route tiles to their weight shard's node when the engine was
        // placed for this pool's shape; otherwise (unplaced engine, or a
        // pool with a different group count) fall back to locality-blind
        // fan-out — same results either way.
        let dispatched = if shards.len() > 1 && shards.len() == pool.nodes() {
            pool.try_run_ctx_routed(&ctx, n_tiles, |call, t| call.tiles[t].shard, tile_job)
        } else {
            pool.try_run_ctx(&ctx, n_tiles, tile_job)
        };
        let reports = match dispatched {
            Ok(r) => r,
            Err(e) => {
                // Completed tiles' output buffers died with the error (the
                // arena re-creates them next call — counter noise, not a
                // leak), but the big pattern/scale buffers are recoverable:
                // every job clone is gone by the time the pool reports.
                if let Ok(call) = Arc::try_unwrap(ctx) {
                    self.call_buffers.lock().unwrap().push(CallBuffers {
                        patterns: call.patterns,
                        x_scales: call.x_scales,
                        tiles: call.tiles,
                    });
                }
                return Err(e.into());
            }
        };

        // Scatter tile outputs into the flat buffer and sum stats, in tile
        // order (deterministic; the sums are order-independent anyway),
        // returning each tile buffer to its shard's arena once copied.
        let mut stats = GemvStats::default();
        let data = out.data_mut();
        for report in reports {
            stats += report.stats;
            let width = report.col_end - report.col_start;
            for bi in 0..batch {
                data[bi * n + report.col_start..bi * n + report.col_end]
                    .copy_from_slice(&report.out[bi * width..(bi + 1) * width]);
            }
            shards[report.shard].arena.checkin_out(report.out);
        }

        // Every tile job dropped its context clone before reporting, so
        // the unwrap is deterministic and the call buffers are recovered
        // for the next dispatch.
        if let Ok(call) = Arc::try_unwrap(ctx) {
            let bufs = CallBuffers {
                patterns: call.patterns,
                x_scales: call.x_scales,
                tiles: call.tiles,
            };
            self.call_buffers.lock().unwrap().push(bufs);
        }
        Ok(stats)
    }

    /// Serial convenience wrapper: allocate a fresh output and run on the
    /// caller's thread. This is the serial reference the tiled/threaded
    /// path is property-tested against. Infallible: the private serial
    /// pool never carries a fault plan, so a failure here is a real
    /// kernel bug and stays loud.
    pub fn gemv_batch(&self, xs: &[QuantizedVector]) -> (GemvOutput, GemvStats) {
        let mut out = GemvOutput::new();
        let stats = self
            .gemv_batch_into(xs, &WorkerPool::serial(), &mut out)
            .expect("serial GEMV cannot fail");
        (out, stats)
    }

    /// Single-vector convenience wrapper.
    pub fn gemv(&self, x: &QuantizedVector) -> Vec<f32> {
        let (out, _) = self.gemv_batch(std::slice::from_ref(x));
        out.row(0).to_vec()
    }
}

/// The naive reference: dequantize-free integer dot product per scale
/// group, then scale — the semantics llama.cpp's quantized kernels use and
/// the oracle the LUT path must match bit-for-bit.
pub fn reference_gemv(wt: &QuantizedMatrix, x: &QuantizedVector) -> Vec<f32> {
    assert_eq!(x.len(), wt.cols);
    let group = wt.group_size;
    let groups = wt.cols / group;
    (0..wt.rows)
        .map(|col| {
            let mut y = 0.0f32;
            for g in 0..groups {
                let mut acc = 0i64;
                for kk in g * group..(g + 1) * group {
                    acc += wt.q(col, kk) as i64 * x.q[kk] as i64;
                }
                y += acc as f32 * wt.scale(col, g * group) * x.scale;
            }
            y
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantLevel;
    use crate::util::{propcheck, Prng};

    fn random_setup(
        prng: &mut Prng,
        n: usize,
        k: usize,
        level: QuantLevel,
        group: usize,
    ) -> (QuantizedMatrix, Vec<QuantizedVector>) {
        let w: Vec<f32> = (0..n * k).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, n, k, level, group);
        let batch = prng.usize_in(1, 5);
        let xs = (0..batch)
            .map(|_| {
                let x: Vec<f32> = (0..k).map(|_| prng.normal() as f32).collect();
                QuantizedVector::quantize(&x)
            })
            .collect();
        (wt, xs)
    }

    #[test]
    fn matches_reference_bit_exactly_all_levels() {
        let mut prng = Prng::new(101);
        for level in QuantLevel::ALL {
            for nbw in [1u32, 2, 3, 4] {
                let (wt, xs) = random_setup(&mut prng, 8, 64, level, 32);
                let eng = LutGemvEngine::new(wt, nbw);
                let (ys, _) = eng.gemv_batch(&xs);
                for (bi, x) in xs.iter().enumerate() {
                    let want = reference_gemv(&eng.weights(), x);
                    assert_eq!(ys.row(bi), want.as_slice(), "level={level} nbw={nbw}");
                }
            }
        }
    }

    #[test]
    fn property_exactness_random_shapes() {
        propcheck::check(
            "lut-gemv-exact",
            propcheck::Config { cases: 60, seed: 103 },
            |p, _| {
                let level = QuantLevel::ALL[p.usize_in(0, 6)];
                let nbw = p.usize_in(1, 5) as u32;
                let group = [8usize, 16, 32][p.usize_in(0, 3)];
                let k = group * p.usize_in(1, 4);
                let n = p.usize_in(1, 12);
                let seed = p.next_u64();
                (level, nbw, group, k, n, seed)
            },
            |&(level, nbw, group, k, n, seed)| {
                let mut prng = Prng::new(seed);
                let (wt, xs) = random_setup(&mut prng, n, k, level, group);
                let eng = LutGemvEngine::new(wt, nbw);
                let (ys, _) = eng.gemv_batch(&xs);
                for (bi, x) in xs.iter().enumerate() {
                    let want = reference_gemv(&eng.weights(), x);
                    if ys.row(bi) != want.as_slice() {
                        return Err(format!("mismatch at level={level} nbw={nbw}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prt_does_not_change_results() {
        let mut prng = Prng::new(105);
        let (wt, xs) = random_setup(&mut prng, 6, 64, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 3);
        let (plain, s0) = eng.gemv_batch(&xs);
        eng.use_prt = true;
        let (with_prt, s1) = eng.gemv_batch(&xs);
        assert_eq!(plain, with_prt);
        assert_eq!(s0.prt_hits, 0);
        assert!(s1.prt_hits > 0, "PRT never hit: {s1:?}");
        // Every access is either a read or a hit; totals match.
        assert_eq!(s0.lut_reads, s1.lut_reads + s1.prt_hits);
    }

    #[test]
    fn tiny_prt_capacities_stay_exact_and_consistent() {
        // DFM sizing is tunable; capacities 1 and 2 exercise LRU eviction
        // and generational reclaim on the real engine path (a 1-entry PRT
        // evicts on every distinct pattern and reclaims on every flush).
        let mut prng = Prng::new(117);
        let (wt, xs) = random_setup(&mut prng, 6, 64, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 3);
        let (plain, s0) = eng.gemv_batch(&xs);
        eng.use_prt = true;
        let mut hit_counts = Vec::new();
        for capacity in [1usize, 2, 32] {
            eng.prt_capacity = capacity;
            let (ys, s) = eng.gemv_batch(&xs);
            assert_eq!(ys, plain, "capacity={capacity} changed results");
            assert_eq!(s.lut_reads + s.prt_hits, s0.lut_reads, "capacity={capacity} lost");
            hit_counts.push(s.prt_hits);
        }
        // A larger table can only hit more (same access stream, LRU).
        assert!(hit_counts[0] <= hit_counts[2], "hits: {hit_counts:?}");
    }

    #[test]
    fn lut_build_count_amortized_over_batch() {
        let mut prng = Prng::new(107);
        let k = 64;
        let group = 32;
        let nbw = 4u32;
        let w: Vec<f32> = (0..4 * k).map(|_| prng.normal() as f32).collect();
        let wt = QuantizedMatrix::quantize(&w, 4, k, QuantLevel::Q4, group);
        let eng = LutGemvEngine::new(wt, nbw);
        let x1: Vec<QuantizedVector> = (0..1)
            .map(|_| QuantizedVector::quantize(&vec![0.5; k]))
            .collect();
        let x8: Vec<QuantizedVector> = (0..8)
            .map(|_| QuantizedVector::quantize(&vec![0.5; k]))
            .collect();
        let (_, s1) = eng.gemv_batch(&x1);
        let (_, s8) = eng.gemv_batch(&x8);
        // Same LUT count regardless of batch (reuse), 8x the reads.
        assert_eq!(s1.luts_built, s8.luts_built);
        assert_eq!(s8.lut_reads, 8 * s1.lut_reads);
        // chunks = K/NBW × N = 16 × 4.
        assert_eq!(s1.luts_built, 64);
    }

    #[test]
    fn nbw_not_dividing_group_still_exact() {
        // group 32, NBW 3 → 11 chunks per group with a 2-wide tail.
        let mut prng = Prng::new(109);
        let (wt, xs) = random_setup(&mut prng, 5, 96, QuantLevel::Q5, 32);
        let eng = LutGemvEngine::new(wt, 3);
        let (ys, _) = eng.gemv_batch(&xs);
        for (bi, x) in xs.iter().enumerate() {
            assert_eq!(ys.row(bi), reference_gemv(&eng.weights(), x).as_slice());
        }
    }

    #[test]
    fn extreme_activation_values_exact() {
        // int8 sign plane (−128..127 boundaries) must be handled exactly.
        let k = 32;
        let w: Vec<f32> = (0..k).map(|i| (i as f32 - 16.0) / 8.0).collect();
        let wt = QuantizedMatrix::quantize(&w, 1, k, QuantLevel::Q8, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let mut q = vec![0i8; k];
        q[0] = -127;
        q[1] = 127;
        q[2] = -1;
        q[3] = 1;
        let x = QuantizedVector { q, scale: 0.33, bits: 8 };
        assert_eq!(eng.gemv(&x), reference_gemv(&eng.weights(), &x));
    }

    #[test]
    fn empty_batch_early_returns() {
        let mut prng = Prng::new(111);
        let (wt, _) = random_setup(&mut prng, 16, 64, QuantLevel::Q4, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let (out, stats) = eng.gemv_batch(&[]);
        assert_eq!(out.batch(), 0);
        assert!(out.as_slice().is_empty());
        // No columns walked, no LUTs built for zero activations.
        assert_eq!(stats, GemvStats::default());
    }

    #[test]
    fn output_buffer_is_reusable_across_calls() {
        let mut prng = Prng::new(113);
        let (wt, xs) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let (wt2, xs2) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let eng2 = LutGemvEngine::new(wt2, 4);
        let pool = WorkerPool::serial();
        let mut out = GemvOutput::new();
        eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        let first = out.clone();
        // A second call with different shapes must fully overwrite.
        eng2.gemv_batch_into(&xs2, &pool, &mut out).unwrap();
        assert_eq!(out.batch(), xs2.len());
        eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        assert_eq!(out, first, "stale data leaked through buffer reuse");
    }

    #[test]
    fn tiled_threaded_matches_serial_bit_exactly() {
        let mut prng = Prng::new(115);
        let (wt, xs) = random_setup(&mut prng, 37, 96, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 4);
        eng.tile_cols = 5; // force ragged multi-tile execution
        let (serial, serial_stats) = eng.gemv_batch(&xs);
        for threads in [1usize, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut out = GemvOutput::new();
            let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
            assert_eq!(out, serial, "threads={threads}");
            assert_eq!(stats, serial_stats, "stats drift at threads={threads}");
        }
    }

    #[test]
    fn scratch_arena_reuses_buffers_after_warmup() {
        // Steady-state GEMV must not create new scratch or tile-output
        // buffers. On the serial pool checkout order is deterministic, so
        // the creation counters are exact: one scratch (checked out and
        // back in per tile) and one output buffer per tile (all live until
        // the final scatter). On a threaded pool the scratch count is
        // bounded by the number of chunk jobs.
        let mut prng = Prng::new(119);
        let (wt, xs) = random_setup(&mut prng, 40, 64, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 4);
        eng.tile_cols = 8; // 5 tiles per call
        let serial = WorkerPool::serial();
        let mut out = GemvOutput::new();
        let baseline = eng.gemv_batch_into(&xs, &serial, &mut out).unwrap();
        assert_eq!(eng.scratch_arena().scratches_created(), 1);
        assert_eq!(eng.scratch_arena().out_bufs_created(), 5);
        for _ in 0..10 {
            let stats = eng.gemv_batch_into(&xs, &serial, &mut out).unwrap();
            assert_eq!(stats, baseline);
        }
        assert_eq!(
            (eng.scratch_arena().scratches_created(), eng.scratch_arena().out_bufs_created()),
            (1, 5),
            "steady-state serial GEMV allocated fresh scratch"
        );
        // Threaded calls borrow from the same arena; at most one extra
        // scratch per concurrent chunk job (5 tiles / 4 workers → ≤ 3
        // chunks) and no new output buffers (5 are already pooled). After
        // every call each buffer is back in the arena.
        let pool = WorkerPool::new(4);
        for _ in 0..10 {
            let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
            assert_eq!(stats, baseline);
            let created = (
                eng.scratch_arena().scratches_created(),
                eng.scratch_arena().out_bufs_created(),
            );
            assert!(created.0 <= 3, "scratches over chunk-job bound: {created:?}");
            assert_eq!(created.1, 5, "threaded call allocated output buffers");
            let (scratches, outs) = eng.scratch_arena().pooled();
            assert_eq!((scratches as u64, outs as u64), created, "buffers leaked in flight");
        }
    }

    #[test]
    #[should_panic(expected = "NBW 8 exceeds scale group 4")]
    fn nbw_gt_group_rejected() {
        let w = vec![0.0f32; 8];
        let wt = QuantizedMatrix::quantize(&w, 2, 4, QuantLevel::Q4, 4);
        let _ = LutGemvEngine::new(wt, 8);
    }

    #[test]
    fn placed_engine_shards_match_pool_and_stay_exact() {
        use crate::runtime::topology::NumaPolicy;
        let mut prng = Prng::new(121);
        let (wt, xs) = random_setup(&mut prng, 37, 96, QuantLevel::Q4, 32);
        let reference = LutGemvEngine::new(wt.clone(), 4);
        let (want, want_stats) = reference.gemv_batch(&xs);

        // A fake 2-node pool: the engine must build 2 contiguous shards
        // covering [0, N) and produce bit-identical output/stats whether
        // dispatched on the placed pool, a plain pool, or serially.
        let pool = WorkerPool::with_policy(4, &NumaPolicy::Explicit(vec![vec![0], vec![1]]));
        let mut eng = LutGemvEngine::with_pool(wt, 4, &pool);
        eng.tile_cols = 5;
        assert_eq!(eng.shard_count(), 2);
        let bounds = eng.shard_bounds();
        assert_eq!(bounds.first().unwrap().0, 0);
        assert_eq!(bounds.last().unwrap().1, 37);
        assert_eq!(bounds[0].1, bounds[1].0, "shards must be contiguous");

        let mut out = GemvOutput::new();
        let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        assert_eq!(out, want, "placed+routed dispatch drifted");
        assert_eq!(stats, want_stats);
        for other in [WorkerPool::serial(), WorkerPool::with_policy(3, &NumaPolicy::Off)] {
            let stats = eng.gemv_batch_into(&xs, &other, &mut out).unwrap();
            assert_eq!(out, want, "fallback dispatch drifted");
            assert_eq!(stats, want_stats);
        }
    }

    #[test]
    fn injected_tile_faults_recover_bit_identically() {
        use crate::runtime::faults::{FaultKind, FaultPlan};
        let mut prng = Prng::new(125);
        let (wt, xs) = random_setup(&mut prng, 37, 96, QuantLevel::Q4, 32);
        let mut eng = LutGemvEngine::new(wt, 4);
        eng.tile_cols = 5; // 8 tiles per call
        let (want, want_stats) = eng.gemv_batch(&xs);
        let pool = WorkerPool::new(4);
        // A stalled tile plus a poisoned scratch checkout: the stall only
        // delays, the poison loses a chunk that the dispatcher re-executes
        // inline — output and stats must be bit-identical to fault-free.
        pool.arm_faults(Arc::new(
            FaultPlan::new(21)
                .with(FaultKind::SlowTile, 2)
                .with(FaultKind::PoisonScratch, 3),
        ));
        let mut out = GemvOutput::new();
        let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        pool.disarm_faults();
        assert_eq!(out, want, "faulted dispatch drifted from fault-free output");
        assert_eq!(stats, want_stats, "recovered chunk double- or under-counted stats");
        // The engine (and its recycled buffers) keep serving after faults.
        let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        assert_eq!(out, want);
        assert_eq!(stats, want_stats);
    }

    #[test]
    fn placed_engine_on_single_group_pool_makes_no_copies() {
        let mut prng = Prng::new(123);
        let (wt, xs) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let pool = WorkerPool::serial();
        let eng = LutGemvEngine::with_pool(wt, 4, &pool);
        assert_eq!(eng.shard_count(), 1);
        // Single shard shares the master matrix Arc — no slice was built.
        {
            let snap = eng.snap.lock().unwrap();
            assert!(Arc::ptr_eq(&snap.wt, &snap.shards[0].wt));
        }
        let (ys, _) = eng.gemv_batch(&xs);
        for (bi, x) in xs.iter().enumerate() {
            assert_eq!(ys.row(bi), reference_gemv(&eng.weights(), x).as_slice());
        }
    }

    #[test]
    fn published_weights_serve_new_matrix_and_reclaim_old() {
        use std::sync::Weak;
        let mut prng = Prng::new(127);
        let (wt_a, xs) = random_setup(&mut prng, 12, 64, QuantLevel::Q4, 32);
        let (wt_b, _) = random_setup(&mut prng, 12, 64, QuantLevel::Q4, 32);
        let eng = LutGemvEngine::new(wt_a, 4);
        let pool = WorkerPool::new(2);
        let want_a: Vec<Vec<f32>> =
            xs.iter().map(|x| reference_gemv(&eng.weights(), x)).collect();
        let old_weak: Weak<QuantizedMatrix> =
            Arc::downgrade(&eng.snap.lock().unwrap().wt);
        let mut out = GemvOutput::new();
        eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        for (bi, want) in want_a.iter().enumerate() {
            assert_eq!(out.row(bi), want.as_slice());
        }

        let oracle_b = LutGemvEngine::new(wt_b.clone(), 4);
        eng.publish_weights(wt_b, &pool).unwrap();
        eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        for (bi, x) in xs.iter().enumerate() {
            assert_eq!(
                out.row(bi),
                reference_gemv(&oracle_b.weights(), x).as_slice(),
                "post-swap GEMV not serving the new weights"
            );
        }
        // No reader was pinned across the swap → the old snapshot is gone.
        assert!(old_weak.upgrade().is_none(), "retired snapshot leaked");
        let s = eng.reclaim_stats();
        assert_eq!((s.retired, s.reclaimed, s.pending, s.active_pins), (1, 1, 0, 0));
    }

    #[test]
    fn publish_rejects_mismatched_shapes() {
        let mut prng = Prng::new(129);
        let (wt, _) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let eng = LutGemvEngine::new(wt, 4);
        let pool = WorkerPool::serial();
        let (wrong_n, _) = random_setup(&mut prng, 9, 64, QuantLevel::Q4, 32);
        assert!(eng.publish_weights(wrong_n, &pool).is_err());
        let (wrong_k, _) = random_setup(&mut prng, 8, 96, QuantLevel::Q4, 32);
        assert!(eng.publish_weights(wrong_k, &pool).is_err());
        let (wrong_group, _) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 16);
        assert!(eng.publish_weights(wrong_group, &pool).is_err());
        assert_eq!(eng.reclaim_stats().retired, 0, "failed publish must not swap");
    }

    #[test]
    fn in_flight_pin_defers_snapshot_reclaim() {
        let mut prng = Prng::new(131);
        let (wt_a, xs) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let (wt_b, _) = random_setup(&mut prng, 8, 64, QuantLevel::Q4, 32);
        let eng = LutGemvEngine::new(wt_a, 4);
        let pool = WorkerPool::serial();
        let old_weak = Arc::downgrade(&eng.snap.lock().unwrap().wt);
        let guard = eng.domain.pin(); // stands in for a GEMV mid-dispatch
        eng.publish_weights(wt_b, &pool).unwrap();
        assert!(old_weak.upgrade().is_some(), "grace period violated under pin");
        assert_eq!(eng.reclaim_stats().pending, 1);
        // Post-swap calls run on the new weights even while the old
        // generation's pin is alive — their own pins don't extend it.
        let (ys, _) = eng.gemv_batch(&xs);
        let oracle = eng.weights();
        for (bi, x) in xs.iter().enumerate() {
            assert_eq!(ys.row(bi), reference_gemv(&oracle, x).as_slice());
        }
        assert_eq!(eng.reclaim_stats().pending, 1);
        drop(guard);
        assert!(old_weak.upgrade().is_none(), "release did not reclaim");
        let s = eng.reclaim_stats();
        assert_eq!((s.retired, s.reclaimed, s.pending), (1, 1, 0));
    }

    #[test]
    fn publish_on_placed_pool_rebuilds_shards() {
        use crate::runtime::topology::NumaPolicy;
        let mut prng = Prng::new(133);
        let (wt_a, xs) = random_setup(&mut prng, 37, 96, QuantLevel::Q4, 32);
        let (wt_b, _) = random_setup(&mut prng, 37, 96, QuantLevel::Q4, 32);
        let pool = WorkerPool::with_policy(4, &NumaPolicy::Explicit(vec![vec![0], vec![1]]));
        let mut eng = LutGemvEngine::with_pool(wt_a, 4, &pool);
        eng.tile_cols = 5;
        let oracle = LutGemvEngine::new(wt_b.clone(), 4);
        let (want, want_stats) = oracle.gemv_batch(&xs);
        eng.publish_weights(wt_b, &pool).unwrap();
        assert_eq!(eng.shard_count(), 2, "publish lost the pool placement");
        let mut out = GemvOutput::new();
        let stats = eng.gemv_batch_into(&xs, &pool, &mut out).unwrap();
        assert_eq!(out, want, "post-swap placed dispatch drifted");
        assert_eq!(stats, want_stats);
    }
}
