//! Cycle model for LUT-GEMV on the C-SRAM substrate.
//!
//! This is the reproduction of the paper's hardcoded NDP timing model
//! (§V-A: "characterizing the cycle counts for key operations … these cycle
//! numbers … are then hardcoded into the NDP model"). All costs derive from
//! the published primitives:
//!
//! - bitline add: `n+1` cycles; LUT build: `2^NBW − NBW − 1` adds
//!   ([`crate::csram`]),
//! - one full-row C-SRAM read per cycle,
//! - LLC slice access latency 58 cycles (Table I),
//! - in-memory type conversion `3n²/2 + 39(n−1)` ([`crate::typeconv`]).
//!
//! Mapping (Fig 5, §V-I): a `[1,1024]×[1024,1024]` tile occupies two
//! 256×512 C-SRAM arrays — each array owns 512 output columns; for the
//! current activation chunk, every column holds that chunk's LUT for its
//! output, built in parallel and reused across (a) all activation
//! bit-planes and (b) every request in the batch.

use crate::csram::bitline::add_cycles;
use crate::csram::lut::Lut;
use crate::csram::transpose;
use crate::quant::QuantLevel;
use crate::typeconv;
use crate::util::ceil_div;

/// Per-phase cycle breakdown for one tile GEMV over a batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GemvCycles {
    /// LUT construction (once per weight tile, amortized over the batch).
    pub build: u64,
    /// Bit-serial activation streaming + accumulate (scales with batch).
    pub stream: u64,
    /// Cross-array partial-sum aggregation through the DFM adder tree.
    pub aggregate: u64,
    /// In-memory int→f32 conversion of the outputs.
    pub typeconv: u64,
}

impl GemvCycles {
    pub fn total(&self) -> u64 {
        self.build + self.stream + self.aggregate + self.typeconv
    }
}

/// Configuration of the cycle model.
#[derive(Debug, Clone, Copy)]
pub struct GemvCycleModel {
    pub nbw: u32,
    pub level: QuantLevel,
    /// Activation bit width streamed by the DFM (8 for int8 activations).
    pub act_bits: u32,
    /// Quantization scale-group size along K.
    pub group_size: usize,
    /// C-SRAM arrays cooperating on the tile.
    pub arrays: u32,
    /// Columns per array (512 in the prototype).
    pub cols_per_array: u32,
    /// LLC slice access latency for basis-weight fetches (Table I).
    pub llc_access_cycles: u64,
    /// Pattern Reuse Table enabled (§III-D)?
    pub use_prt: bool,
    /// Apply in-memory type conversion (vs shipping ints to the CPU)?
    pub in_memory_typeconv: bool,
}

impl GemvCycleModel {
    /// The paper's prototype configuration for one `lutmm_1k` tile.
    pub fn prototype(level: QuantLevel, nbw: u32) -> Self {
        GemvCycleModel {
            nbw,
            level,
            act_bits: 8,
            group_size: 32,
            arrays: 2,
            cols_per_array: 512,
            llc_access_cycles: 58,
            use_prt: false,
            in_memory_typeconv: true,
        }
    }

    /// Integer accumulator width: LUT entries grow by the in-group
    /// reduction (log2 of chunks/group · planes) — 24 bits covers every
    /// supported configuration (≤ 2^19 magnitude, see engine docs).
    pub fn acc_bits(&self) -> u32 {
        24
    }

    /// Number of NBW chunks for a K-length reduction.
    pub fn chunks(&self, k: usize) -> u64 {
        let per_group = ceil_div(self.group_size, self.nbw as usize);
        (ceil_div(k, self.group_size) * per_group) as u64
    }

    /// Cycles for one weight-tile LUT build phase (parallel across all
    /// columns of all arrays): per chunk, fetch basis rows from the slice,
    /// transpose in, then subset-sum adds.
    fn build_per_chunk(&self) -> u64 {
        let eb = Lut::entry_bits(self.level.bits(), self.nbw);
        self.llc_access_cycles
            + transpose::transpose_cycles(self.cols_per_array as usize, self.level.bits())
            + Lut::build_cycles(self.nbw, eb)
    }

    /// Streaming cost of one chunk for one batch item: `act_bits`
    /// bit-planes, each a LUT row-range read (`entry_bits` rows) plus a
    /// shift-add into the accumulator. PRT hits bypass the row read.
    fn stream_per_chunk_item(&self) -> u64 {
        let eb = Lut::entry_bits(self.level.bits(), self.nbw) as u64;
        let add = add_cycles(self.acc_bits());
        let lookups = self.act_bits as u64;
        if self.use_prt {
            // Within one LUT lifetime at most 2^NBW distinct patterns miss;
            // the expected hit fraction over `lookups` accesses follows the
            // measured ~17% pattern repetition (§III-D). A hit bypasses the
            // C-SRAM row read *and* the bit-serial accumulate: the PRT's own
            // 16-bit adder tree merges the stored result in ~5 cycles
            // (1 CAM match + 4 pipelined tree stages). 17% repetition ×
            // (1 − 5/31) ≈ the paper's 13.8% cycle reduction.
            const PRT_HIT_CYCLES: u64 = 5;
            let hit_rate = prt_expected_hit_rate(self.nbw, self.act_bits);
            let hits = (lookups as f64 * hit_rate).round() as u64;
            let misses = lookups - hits;
            misses * (eb + add) + hits * PRT_HIT_CYCLES
        } else {
            lookups * (eb + add)
        }
    }

    /// Column passes needed when N exceeds the parallel column capacity.
    pub fn passes(&self, n: usize) -> u64 {
        ceil_div(n, (self.arrays * self.cols_per_array) as usize) as u64
    }

    /// Full cycle breakdown for a `[1,K]×[K,N]` GEMV over batch `b`.
    pub fn tile(&self, k: usize, n: usize, b: usize) -> GemvCycles {
        assert!(b >= 1);
        let chunks = self.chunks(k);
        let passes = self.passes(n);
        let build = passes * chunks * self.build_per_chunk();
        let stream = passes * chunks * b as u64 * self.stream_per_chunk_item();
        // Partial-sum aggregation across cooperating arrays (binary adder
        // tree in the DFM), once per batch item per pass.
        let agg_levels = (self.arrays as f64).log2().ceil() as u64;
        let aggregate = passes * b as u64 * agg_levels * add_cycles(self.acc_bits());
        let typeconv = if self.in_memory_typeconv {
            // Convert N outputs per batch item; all arrays' columns work
            // in parallel.
            let per_item = typeconv::batch_cycles(
                self.acc_bits(),
                n,
                self.cols_per_array as usize,
                self.arrays as usize,
            );
            b as u64 * per_item
        } else {
            0
        };
        GemvCycles { build, stream, aggregate, typeconv }
    }

    /// Throughput-style summary: cycles per batch item for the tile.
    pub fn cycles_per_item(&self, k: usize, n: usize, b: usize) -> f64 {
        self.tile(k, n, b).total() as f64 / b as f64
    }
}

/// Expected PRT hit rate for an NBW-bit pattern stream.
///
/// Calibrated to the paper's measurement: "approximately 17% of input
/// activation patterns repeat within computation batches", yielding a
/// 13.8% cycle reduction. Narrow patterns repeat more (fewer distinct
/// values); the 17% anchor is NBW=4 at 8 activation bits.
pub fn prt_expected_hit_rate(nbw: u32, act_bits: u32) -> f64 {
    let base = 0.17f64;
    // Halving NBW squares the collision probability's complement roughly;
    // simple saturating model anchored at (4, 8).
    let nbw_factor = (4.0 / nbw as f64).sqrt();
    let bits_factor = (act_bits as f64 / 8.0).sqrt();
    (base * nbw_factor * bits_factor).min(0.95)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_counting() {
        let m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        // K=1024, group 32, NBW 4 → 32 groups × 8 chunks.
        assert_eq!(m.chunks(1024), 256);
        let m3 = GemvCycleModel::prototype(QuantLevel::Q4, 3);
        // group 32 / NBW 3 → 11 chunks per group (padded tail).
        assert_eq!(m3.chunks(1024), 32 * 11);
    }

    #[test]
    fn passes_scale_with_n() {
        let m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        assert_eq!(m.passes(1024), 1);
        assert_eq!(m.passes(1025), 2);
        assert_eq!(m.passes(4096), 4);
    }

    #[test]
    fn build_amortizes_with_batch() {
        let m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        let c1 = m.tile(1024, 1024, 1);
        let c8 = m.tile(1024, 1024, 8);
        assert_eq!(c1.build, c8.build, "build must not scale with batch");
        assert_eq!(c8.stream, 8 * c1.stream, "stream scales linearly");
        // Per-item cost strictly decreases with batch.
        assert!(m.cycles_per_item(1024, 1024, 8) < m.cycles_per_item(1024, 1024, 1));
        assert!(m.cycles_per_item(1024, 1024, 32) < m.cycles_per_item(1024, 1024, 8));
    }

    #[test]
    fn per_item_cost_plateaus_at_large_batch() {
        // Fig 6: "the cycle count drops substantially but plateaus beyond
        // about 7". Marginal improvement from 16→32 must be much smaller
        // than from 1→2.
        let m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        let d_small =
            m.cycles_per_item(1024, 1024, 1) - m.cycles_per_item(1024, 1024, 2);
        let d_large =
            m.cycles_per_item(1024, 1024, 16) - m.cycles_per_item(1024, 1024, 32);
        assert!(d_small > 10.0 * d_large, "{d_small} vs {d_large}");
    }

    #[test]
    fn small_nbw_rebuild_overhead_at_low_precision() {
        // §III-C: at 2-bit, NBW=2 suffers LUT-rebuild overhead vs NBW=4.
        let m2 = GemvCycleModel::prototype(QuantLevel::Q2, 2);
        let m4 = GemvCycleModel::prototype(QuantLevel::Q2, 4);
        let b = 24;
        assert!(
            m2.tile(1024, 1024, b).total() > m4.tile(1024, 1024, b).total(),
            "NBW=2 must be slower than NBW=4 at Q2 batch 24"
        );
    }

    #[test]
    fn lower_precision_is_faster_at_fixed_nbw() {
        // §III-C: batch 24, NBW=4: Q2 3.00M < Q4 4.87M cycles.
        let q2 = GemvCycleModel::prototype(QuantLevel::Q2, 4).tile(1024, 1024, 24);
        let q4 = GemvCycleModel::prototype(QuantLevel::Q4, 4).tile(1024, 1024, 24);
        assert!(q2.total() < q4.total());
    }

    #[test]
    fn large_nbw_hurts_small_batch() {
        // Fig 6: at batch 1–2 the LUT-creation overhead of a large NBW is
        // not amortized; a smaller NBW should win or tie.
        let small = GemvCycleModel::prototype(QuantLevel::Q8, 1);
        let large = GemvCycleModel::prototype(QuantLevel::Q8, 4);
        let c_small = small.tile(1024, 1024, 1).build;
        let c_large = large.tile(1024, 1024, 1).build;
        // Build cost per chunk is exponentially larger for NBW=4, but there
        // are 4x fewer chunks; net build must still be larger for NBW=4.
        assert!(c_large > c_small / 4, "{c_large} vs {c_small}");
    }

    #[test]
    fn prt_reduces_stream_cycles() {
        let mut m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        let plain = m.tile(1024, 1024, 8);
        m.use_prt = true;
        let prt = m.tile(1024, 1024, 8);
        assert!(prt.stream < plain.stream);
        assert_eq!(prt.build, plain.build);
        // §III-D: "reduces computation cycles by 13.8%" — the compute
        // (stream) reduction should be in that neighbourhood (10–20%).
        let reduction = 1.0 - prt.stream as f64 / plain.stream as f64;
        assert!((0.08..=0.25).contains(&reduction), "reduction={reduction}");
    }

    #[test]
    fn typeconv_in_memory_vs_off() {
        let mut m = GemvCycleModel::prototype(QuantLevel::Q4, 4);
        let with_tc = m.tile(1024, 1024, 4);
        m.in_memory_typeconv = false;
        let without = m.tile(1024, 1024, 4);
        assert!(with_tc.typeconv > 0);
        assert_eq!(without.typeconv, 0);
        assert_eq!(with_tc.stream, without.stream);
    }

    #[test]
    fn hit_rate_anchored_and_bounded() {
        assert!((prt_expected_hit_rate(4, 8) - 0.17).abs() < 1e-9);
        assert!(prt_expected_hit_rate(2, 8) > prt_expected_hit_rate(4, 8));
        for nbw in 1..=8 {
            for ab in [2, 4, 8] {
                let r = prt_expected_hit_rate(nbw, ab);
                assert!((0.0..=0.95).contains(&r));
            }
        }
    }
}
