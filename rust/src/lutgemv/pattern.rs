//! Pattern Reuse Table (paper §III-D).
//!
//! "Each Data Feeding Module contains a 32-entry fully-associative Pattern
//! Reuse Table. The PRT stores a 32-bit hash of the NBW-bit input pattern
//! along with the previous LUT result. On a PRT hit, the DFM bypasses the
//! C-SRAM access and reuses the stored result."
//!
//! The stored result is only valid while the *current* LUT is live — a
//! pattern maps to different subset sums under different weight chunks —
//! so the DFM flushes the PRT whenever the C-SRAM switches LUTs. (With
//! NBW ≤ 5 all 2^NBW patterns fit the 32 entries, so within one LUT's
//! lifetime every pattern misses at most once.)
//!
//! Hardware cost (paper): one PRT + its 16-bit adder tree ≈ 0.0012 mm²,
//! 0.25 mW in FreePDK-45; eight DFMs < 0.01 mm² total.

/// FNV-1a based 32-bit pattern hash — stands in for the paper's unspecified
/// 32-bit hash. With ≤ 8-bit patterns it is collision-free by construction,
/// which the tests verify.
#[inline]
pub fn pattern_hash(pattern: u32) -> u32 {
    let mut h: u32 = 0x811C9DC5;
    for byte in pattern.to_le_bytes() {
        h ^= byte as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// The tag actually stored/compared by the table. The engine's bit-serial
/// contract caps activation planes at NBW ≤ 8 bits, so every hot-loop
/// pattern is `< 256` — where the identity map is exactly as
/// collision-free as FNV-1a (both injective on 0..256, see the tests) at
/// zero hash work per lookup+insert, so on the engine's streams the
/// hit/miss/flush sequences, and therefore all counters, are
/// bit-identical to the FNV tags. Wider patterns (reachable through the
/// public API) still hash with FNV-1a, **forced into a disjoint tag
/// space** (bit 31 set; identity tags are < 2⁸): a wide pattern whose
/// hash happens to land below 256 can never phantom-hit a narrow
/// pattern's entry, which plain FNV-for-everything could not promise
/// either way.
#[inline]
fn tag_of(pattern: u32) -> u32 {
    if pattern < 256 {
        pattern
    } else {
        0x8000_0000 | pattern_hash(pattern)
    }
}

#[derive(Debug, Clone, Copy)]
struct PrtEntry {
    tag: u32,
    value: i64,
    /// LRU timestamp.
    stamp: u64,
    /// Generation the entry was written in; entries from an older
    /// generation are invalid (flushed) without having been cleared.
    generation: u64,
}

/// 32-entry fully-associative LRU table.
///
/// Flush is O(1): a generation counter is bumped and stale entries are
/// lazily treated as empty. The engine flushes on *every* LUT switch
/// (thousands per GEMV), so an O(capacity) wipe per flush would cost more
/// than the lookups it serves.
#[derive(Debug, Clone)]
pub struct PatternReuseTable {
    entries: Vec<Option<PrtEntry>>,
    clock: u64,
    generation: u64,
    hits: u64,
    misses: u64,
    flushes: u64,
}

impl PatternReuseTable {
    /// `capacity` is 32 in the paper's DFM.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        PatternReuseTable {
            entries: vec![None; capacity],
            clock: 0,
            generation: 0,
            hits: 0,
            misses: 0,
            flushes: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Look up a pattern; `Some(result)` bypasses the C-SRAM access.
    ///
    /// Hot-loop shape (this runs once per (chunk, plane, batch item) when
    /// the PRT is enabled): the tag is the identity of the pattern for
    /// the ≤ 8-bit patterns the engine feeds (no FNV rounds — see
    /// [`tag_of`]), and the scan does a single discriminant match per
    /// slot, short-circuiting the moment a live tag hits. Stale
    /// (pre-flush) entries encountered *before* the hit are reclaimed to
    /// `None` on the spot, so post-flush scans degrade to cheap
    /// discriminant checks instead of paying a tag compare per dead slot
    /// — the flush stays O(1) without pessimizing the lookups it serves.
    /// Hit/miss decisions (and so all counters) are bit-identical to the
    /// pre-fast-path table.
    pub fn lookup(&mut self, pattern: u32) -> Option<i64> {
        self.clock += 1;
        let tag = tag_of(pattern);
        let generation = self.generation;
        for slot in self.entries.iter_mut() {
            match slot {
                Some(e) if e.generation == generation => {
                    if e.tag == tag {
                        e.stamp = self.clock;
                        self.hits += 1;
                        return Some(e.value);
                    }
                }
                Some(_) => *slot = None, // lazy reclaim of a flushed entry
                None => {}
            }
        }
        self.misses += 1;
        None
    }

    /// Record the LUT result for a pattern (after a miss), evicting LRU.
    pub fn insert(&mut self, pattern: u32, value: i64) {
        self.clock += 1;
        let tag = tag_of(pattern);
        // Update in place if present (and live this generation).
        for e in self.entries.iter_mut().flatten() {
            if e.generation == self.generation && e.tag == tag {
                e.value = value;
                e.stamp = self.clock;
                return;
            }
        }
        // Never-used or stale (pre-flush) slot, else LRU victim among live
        // entries.
        let victim = self
            .entries
            .iter()
            .position(|e| match e {
                None => true,
                Some(entry) => entry.generation != self.generation,
            })
            .unwrap_or_else(|| {
                self.entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.as_ref().unwrap().stamp)
                    .map(|(i, _)| i)
                    .unwrap()
            });
        self.entries[victim] =
            Some(PrtEntry { tag, value, stamp: self.clock, generation: self.generation });
    }

    /// Invalidate everything — required on every LUT switch. O(1): bumps
    /// the generation counter; stale entries are reclaimed lazily by
    /// `insert`.
    pub fn flush(&mut self) {
        self.generation += 1;
        self.flushes += 1;
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hit rate over all lookups so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_collision_free_for_8bit_patterns() {
        let mut seen = std::collections::HashSet::new();
        for p in 0u32..256 {
            assert!(seen.insert(pattern_hash(p)), "collision at {p}");
        }
    }

    #[test]
    fn identity_tag_fast_path_matches_fnv_semantics() {
        // ≤ 8-bit patterns take the identity tag; both maps are injective
        // on that domain, so the fast path cannot change any hit/miss
        // decision there, and wide patterns live in a disjoint tag space
        // (bit 31) so they can never phantom-hit a narrow entry. Drive an
        // adversarial mixed stream (narrow + wide patterns, flushes,
        // evictions) against a straightforward reference model keyed by
        // the *pattern* and require identical hit/miss traces and
        // counters.
        let mut prt = PatternReuseTable::new(4);
        let mut model: Vec<(u32, i64)> = Vec::new(); // (pattern, value), LRU order
        let mut prng = crate::util::Prng::new(91);
        let (mut want_hits, mut want_misses) = (0u64, 0u64);
        for op in 0..4000 {
            // Mix narrow (identity-tag) and wide (FNV-tag) patterns.
            let pattern = if prng.gen_range(4) == 0 {
                0x1_0000 + prng.gen_range(64) as u32
            } else {
                prng.gen_range(256) as u32
            };
            match prng.gen_range(8) {
                0 => {
                    prt.flush();
                    model.clear();
                }
                _ => {
                    let got = prt.lookup(pattern);
                    let hit = model.iter().position(|&(p, _)| p == pattern);
                    match hit {
                        Some(i) => {
                            want_hits += 1;
                            let e = model.remove(i);
                            assert_eq!(got, Some(e.1), "op {op}: wrong value for {pattern:#x}");
                            model.push(e); // most-recently-used
                        }
                        None => {
                            want_misses += 1;
                            assert_eq!(got, None, "op {op}: phantom hit for {pattern:#x}");
                            if model.len() == 4 {
                                model.remove(0); // LRU eviction
                            }
                            model.push((pattern, op as i64));
                            prt.insert(pattern, op as i64);
                        }
                    }
                }
            }
        }
        assert_eq!((prt.hits(), prt.misses()), (want_hits, want_misses));
        assert!(want_hits > 100 && want_misses > 100, "stream did not exercise both paths");
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let mut prt = PatternReuseTable::new(32);
        assert_eq!(prt.lookup(0b1010), None);
        prt.insert(0b1010, 42);
        assert_eq!(prt.lookup(0b1010), Some(42));
        assert_eq!(prt.hits(), 1);
        assert_eq!(prt.misses(), 1);
    }

    #[test]
    fn flush_invalidates() {
        let mut prt = PatternReuseTable::new(32);
        prt.insert(1, 10);
        prt.flush();
        assert_eq!(prt.lookup(1), None);
    }

    #[test]
    fn lru_eviction_order() {
        let mut prt = PatternReuseTable::new(2);
        prt.insert(1, 10);
        prt.insert(2, 20);
        let _ = prt.lookup(1); // make 1 most-recent
        prt.insert(3, 30); // evicts 2
        assert_eq!(prt.lookup(1), Some(10));
        assert_eq!(prt.lookup(2), None);
        assert_eq!(prt.lookup(3), Some(30));
    }

    #[test]
    fn all_patterns_fit_for_nbw_le_5() {
        let mut prt = PatternReuseTable::new(32);
        for pat in 0u32..32 {
            assert_eq!(prt.lookup(pat), None);
            prt.insert(pat, pat as i64 * 3);
        }
        for pat in 0u32..32 {
            assert_eq!(prt.lookup(pat), Some(pat as i64 * 3), "pattern {pat} evicted");
        }
    }

    #[test]
    fn flush_is_generational_not_destructive() {
        // A flushed entry must behave exactly like an empty slot: miss on
        // lookup, and be reclaimed by insert *before* any live entry is
        // LRU-evicted.
        let mut prt = PatternReuseTable::new(2);
        prt.insert(1, 10);
        prt.insert(2, 20);
        prt.flush();
        assert_eq!(prt.lookup(1), None);
        assert_eq!(prt.lookup(2), None);
        // Both slots are stale; two inserts must fit without evicting each
        // other.
        prt.insert(3, 30);
        prt.insert(4, 40);
        assert_eq!(prt.lookup(3), Some(30));
        assert_eq!(prt.lookup(4), Some(40));
    }

    #[test]
    fn repeated_flushes_stay_consistent() {
        // The engine flushes once per LUT (thousands per GEMV); hammer the
        // generation path and check per-generation behaviour every time.
        let mut prt = PatternReuseTable::new(4);
        for gen in 0u32..1000 {
            prt.flush();
            for pat in 0..4u32 {
                assert_eq!(prt.lookup(pat), None, "gen {gen}: stale value survived flush");
                prt.insert(pat, (gen * 10 + pat) as i64);
            }
            for pat in 0..4u32 {
                assert_eq!(prt.lookup(pat), Some((gen * 10 + pat) as i64), "gen {gen}");
            }
        }
    }

    #[test]
    fn capacity_one_evicts_and_reclaims_generationally() {
        // A 1-entry DFM: every distinct pattern evicts the previous one,
        // and after a flush the single stale slot must be reclaimed by
        // insert rather than treated as live.
        let mut prt = PatternReuseTable::new(1);
        assert_eq!(prt.capacity(), 1);
        prt.insert(1, 10);
        assert_eq!(prt.lookup(1), Some(10));
        prt.insert(2, 20); // evicts 1 (only slot)
        assert_eq!(prt.lookup(1), None);
        assert_eq!(prt.lookup(2), Some(20));
        for gen in 0..100i64 {
            prt.flush();
            // Generational reclaim triggers on every round: the slot holds
            // a stale entry from the previous generation.
            assert_eq!(prt.lookup(7), None, "gen {gen}: stale value survived");
            prt.insert(7, gen);
            assert_eq!(prt.lookup(7), Some(gen), "gen {gen}");
        }
    }

    #[test]
    fn capacity_two_mixes_eviction_and_generational_reclaim() {
        let mut prt = PatternReuseTable::new(2);
        prt.insert(1, 10);
        prt.insert(2, 20);
        prt.flush();
        // One insert reclaims a stale slot; the other stale slot must
        // still read as empty, not as entry 1 or 2.
        prt.insert(3, 30);
        assert_eq!(prt.lookup(1), None);
        assert_eq!(prt.lookup(2), None);
        assert_eq!(prt.lookup(3), Some(30));
        // Fill the second (lazily reclaimed) slot, then force LRU among
        // the two live entries of this generation.
        prt.insert(4, 40);
        let _ = prt.lookup(3); // 3 most-recent
        prt.insert(5, 50); // evicts 4
        assert_eq!(prt.lookup(3), Some(30));
        assert_eq!(prt.lookup(4), None);
        assert_eq!(prt.lookup(5), Some(50));
    }

    #[test]
    fn insert_updates_in_place() {
        let mut prt = PatternReuseTable::new(4);
        prt.insert(7, 1);
        prt.insert(7, 2);
        assert_eq!(prt.lookup(7), Some(2));
        // No duplicate entries: capacity still allows 3 more distinct tags.
        prt.insert(8, 8);
        prt.insert(9, 9);
        prt.insert(10, 10);
        assert_eq!(prt.lookup(7), Some(2));
    }
}
