//! Pattern Reuse Table (paper §III-D).
//!
//! "Each Data Feeding Module contains a 32-entry fully-associative Pattern
//! Reuse Table. The PRT stores a 32-bit hash of the NBW-bit input pattern
//! along with the previous LUT result. On a PRT hit, the DFM bypasses the
//! C-SRAM access and reuses the stored result."
//!
//! The stored result is only valid while the *current* LUT is live — a
//! pattern maps to different subset sums under different weight chunks —
//! so the DFM flushes the PRT whenever the C-SRAM switches LUTs. (With
//! NBW ≤ 5 all 2^NBW patterns fit the 32 entries, so within one LUT's
//! lifetime every pattern misses at most once.)
//!
//! Hardware cost (paper): one PRT + its 16-bit adder tree ≈ 0.0012 mm²,
//! 0.25 mW in FreePDK-45; eight DFMs < 0.01 mm² total.

/// FNV-1a based 32-bit pattern hash — stands in for the paper's unspecified
/// 32-bit hash. With ≤ 8-bit patterns it is collision-free by construction,
/// which the tests verify.
#[inline]
pub fn pattern_hash(pattern: u32) -> u32 {
    let mut h: u32 = 0x811C9DC5;
    for byte in pattern.to_le_bytes() {
        h ^= byte as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[derive(Debug, Clone, Copy)]
struct PrtEntry {
    tag: u32,
    value: i64,
    /// LRU timestamp.
    stamp: u64,
}

/// 32-entry fully-associative LRU table.
#[derive(Debug, Clone)]
pub struct PatternReuseTable {
    entries: Vec<Option<PrtEntry>>,
    clock: u64,
    hits: u64,
    misses: u64,
    flushes: u64,
}

impl PatternReuseTable {
    /// `capacity` is 32 in the paper's DFM.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        PatternReuseTable {
            entries: vec![None; capacity],
            clock: 0,
            hits: 0,
            misses: 0,
            flushes: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Look up a pattern; `Some(result)` bypasses the C-SRAM access.
    pub fn lookup(&mut self, pattern: u32) -> Option<i64> {
        self.clock += 1;
        let tag = pattern_hash(pattern);
        for e in self.entries.iter_mut().flatten() {
            if e.tag == tag {
                e.stamp = self.clock;
                self.hits += 1;
                return Some(e.value);
            }
        }
        self.misses += 1;
        None
    }

    /// Record the LUT result for a pattern (after a miss), evicting LRU.
    pub fn insert(&mut self, pattern: u32, value: i64) {
        self.clock += 1;
        let tag = pattern_hash(pattern);
        // Update in place if present.
        for e in self.entries.iter_mut().flatten() {
            if e.tag == tag {
                e.value = value;
                e.stamp = self.clock;
                return;
            }
        }
        // Free slot, else LRU victim.
        let victim = self
            .entries
            .iter()
            .position(|e| e.is_none())
            .unwrap_or_else(|| {
                self.entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.as_ref().unwrap().stamp)
                    .map(|(i, _)| i)
                    .unwrap()
            });
        self.entries[victim] = Some(PrtEntry { tag, value, stamp: self.clock });
    }

    /// Invalidate everything — required on every LUT switch.
    pub fn flush(&mut self) {
        for e in self.entries.iter_mut() {
            *e = None;
        }
        self.flushes += 1;
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hit rate over all lookups so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_collision_free_for_8bit_patterns() {
        let mut seen = std::collections::HashSet::new();
        for p in 0u32..256 {
            assert!(seen.insert(pattern_hash(p)), "collision at {p}");
        }
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let mut prt = PatternReuseTable::new(32);
        assert_eq!(prt.lookup(0b1010), None);
        prt.insert(0b1010, 42);
        assert_eq!(prt.lookup(0b1010), Some(42));
        assert_eq!(prt.hits(), 1);
        assert_eq!(prt.misses(), 1);
    }

    #[test]
    fn flush_invalidates() {
        let mut prt = PatternReuseTable::new(32);
        prt.insert(1, 10);
        prt.flush();
        assert_eq!(prt.lookup(1), None);
    }

    #[test]
    fn lru_eviction_order() {
        let mut prt = PatternReuseTable::new(2);
        prt.insert(1, 10);
        prt.insert(2, 20);
        let _ = prt.lookup(1); // make 1 most-recent
        prt.insert(3, 30); // evicts 2
        assert_eq!(prt.lookup(1), Some(10));
        assert_eq!(prt.lookup(2), None);
        assert_eq!(prt.lookup(3), Some(30));
    }

    #[test]
    fn all_patterns_fit_for_nbw_le_5() {
        let mut prt = PatternReuseTable::new(32);
        for pat in 0u32..32 {
            assert_eq!(prt.lookup(pat), None);
            prt.insert(pat, pat as i64 * 3);
        }
        for pat in 0u32..32 {
            assert_eq!(prt.lookup(pat), Some(pat as i64 * 3), "pattern {pat} evicted");
        }
    }

    #[test]
    fn insert_updates_in_place() {
        let mut prt = PatternReuseTable::new(4);
        prt.insert(7, 1);
        prt.insert(7, 2);
        assert_eq!(prt.lookup(7), Some(2));
        // No duplicate entries: capacity still allows 3 more distinct tags.
        prt.insert(8, 8);
        prt.insert(9, 9);
        prt.insert(10, 10);
        assert_eq!(prt.lookup(7), Some(2));
    }
}
