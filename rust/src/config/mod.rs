//! Deployment configuration: everything a `sail` run needs, loadable from
//! a TOML file (`configs/*.toml`) with CLI overrides on top.
//!
//! Sections:
//! - `[model]`    — which model + quantization to serve/simulate,
//! - `[sail]`     — accelerator parameters (threads, NBW, PRT, in-memory
//!                  TC, KV precision, NUMA placement policy, prefill
//!                  chunk),
//! - `[serving]`  — batch slots, workload shape,
//! - `[arch.dram]`— memory-system overrides.

use anyhow::{anyhow, Result};
use std::path::Path;

use crate::arch::{DramConfig, SystemConfig};
use crate::model::{KvCacheSpec, ModelConfig};
use crate::quant::QuantLevel;
use crate::runtime::NumaPolicy;
use crate::sim::SailPerfModel;
use crate::util::toml::TomlDoc;

/// A complete run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub level: QuantLevel,
    pub threads: u32,
    pub nbw: u32,
    pub use_prt: bool,
    pub in_memory_typeconv: bool,
    pub kv_bits: u32,
    /// Worker placement policy for the execution pool (`sail.numa`:
    /// `"off"`, `"auto"`, or an explicit `node:cpulist;…` map — the
    /// `SAIL_NUMA` syntax). Consumed by `sail serve --engine lut
    /// --config FILE`, which builds the serving pool from
    /// `threads` + `numa`.
    pub numa: NumaPolicy,
    /// Most prompt tokens one serving slot consumes per batcher iteration
    /// (`sail.prefill_chunk`): 1 is token-at-a-time prefill-as-decode,
    /// larger values amortize each LUT build across the chunk. Token
    /// streams are bit-identical at every value; the `SAIL_PREFILL_CHUNK`
    /// environment override (applied by the serving drivers) wins over
    /// this field, mirroring `SAIL_NUMA`.
    pub prefill_chunk: usize,
    pub batch: usize,
    pub requests: usize,
    pub rate_per_sec: f64,
    pub dram_mt_per_sec: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: ModelConfig::llama2_7b(),
            level: QuantLevel::Q4,
            threads: 16,
            nbw: 4,
            use_prt: true,
            in_memory_typeconv: true,
            kv_bits: 8,
            numa: NumaPolicy::Auto,
            prefill_chunk: 16,
            batch: 8,
            requests: 16,
            rate_per_sec: 4.0,
            dram_mt_per_sec: 6400,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file; unknown model/quant names are errors,
    /// missing keys fall back to defaults.
    pub fn load(path: &Path) -> Result<RunConfig> {
        let doc = TomlDoc::load(path).map_err(|e| anyhow!(e))?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        let d = RunConfig::default();
        let model = match doc.str_or("model.name", "7b").to_lowercase().as_str() {
            "7b" | "llama2-7b" => ModelConfig::llama2_7b(),
            "13b" | "llama2-13b" => ModelConfig::llama2_13b(),
            "248m" | "tinymistral" => ModelConfig::tinymistral_248m(),
            "tiny" | "tiny-e2e" => ModelConfig::tiny_e2e(),
            other => return Err(anyhow!("unknown model.name '{other}'")),
        };
        let quant = doc.str_or("model.quant", "q4");
        let level =
            QuantLevel::parse(&quant).ok_or_else(|| anyhow!("bad model.quant '{quant}'"))?;
        let nbw = doc.usize_or("sail.nbw", d.nbw as usize) as u32;
        if !(1..=8).contains(&nbw) {
            return Err(anyhow!("sail.nbw must be 1..=8"));
        }
        // A present-but-malformed placement must be an error, not a silent
        // fall-back to auto (the run would be unpinned and nobody would
        // know why the NUMA numbers regressed) — including a present but
        // non-string value, which `str_or` would silently default.
        let numa = match doc.get("sail.numa") {
            None => NumaPolicy::Auto,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("sail.numa must be a string (\"off\"/\"auto\"/map)"))?;
                NumaPolicy::parse(s).map_err(|e| anyhow!("bad sail.numa: {e}"))?
            }
        };
        // Same strictness: a present-but-malformed chunk (0, or not an
        // integer) must be an error, not a silent fall-back — the run
        // would quietly serve unchunked and the prefill numbers would
        // regress with no visible cause.
        let prefill_chunk = match doc.get("sail.prefill_chunk") {
            None => d.prefill_chunk,
            Some(v) => match v.as_usize() {
                Some(n) if n >= 1 => n,
                _ => return Err(anyhow!("sail.prefill_chunk must be an integer ≥ 1")),
            },
        };
        Ok(RunConfig {
            model,
            level,
            threads: doc.usize_or("sail.threads", d.threads as usize) as u32,
            nbw,
            use_prt: doc.bool_or("sail.prt", d.use_prt),
            in_memory_typeconv: doc.bool_or("sail.in_memory_typeconv", d.in_memory_typeconv),
            kv_bits: doc.usize_or("sail.kv_bits", d.kv_bits as usize) as u32,
            numa,
            prefill_chunk,
            batch: doc.usize_or("serving.batch", d.batch),
            requests: doc.usize_or("serving.requests", d.requests),
            rate_per_sec: doc.f64_or("serving.rate", d.rate_per_sec),
            dram_mt_per_sec: doc.usize_or("arch.dram.mt_per_sec", d.dram_mt_per_sec as usize)
                as u64,
        })
    }

    /// Build the performance model this config describes.
    pub fn perf_model(&self) -> SailPerfModel {
        let mut system = SystemConfig::default();
        system.dram = DramConfig { mt_per_sec: self.dram_mt_per_sec, ..DramConfig::default() };
        SailPerfModel {
            system,
            level: self.level,
            nbw: self.nbw,
            group: 32,
            threads: self.threads,
            kv: if self.kv_bits <= 8 { KvCacheSpec::q8() } else { KvCacheSpec::fp16() },
            use_prt: self.use_prt,
            in_memory_typeconv: self.in_memory_typeconv,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml::TomlDoc;

    #[test]
    fn defaults_match_paper_config() {
        let c = RunConfig::default();
        let m = c.perf_model();
        assert_eq!(m.threads, 16);
        assert_eq!(m.nbw, 4);
        assert!(m.use_prt && m.in_memory_typeconv);
        assert_eq!(m.system.dram.mt_per_sec, 6400);
    }

    #[test]
    fn full_file_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
[model]
name = "13b"
quant = "q2"

[sail]
threads = 8
nbw = 2
prt = false
kv_bits = 16

[serving]
batch = 4
rate = 9.5

[arch.dram]
mt_per_sec = 3200
"#,
        )
        .unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.model.name, "Llama-2-13B");
        assert_eq!(c.level, QuantLevel::Q2);
        assert_eq!(c.threads, 8);
        assert_eq!(c.nbw, 2);
        assert!(!c.use_prt);
        assert_eq!(c.kv_bits, 16);
        assert_eq!(c.batch, 4);
        assert_eq!(c.rate_per_sec, 9.5);
        let pm = c.perf_model();
        assert_eq!(pm.system.dram.mt_per_sec, 3200);
        assert_eq!(pm.kv.bits, 16);
    }

    #[test]
    fn bad_values_rejected() {
        for bad in [
            "[model]\nname = \"70b\"",
            "[model]\nquant = \"q7\"",
            "[sail]\nnbw = 9",
            "[sail]\nnuma = \"1:0-3\"",
            "[sail]\nnuma = \"sideways\"",
            "[sail]\nnuma = 0",
            "[sail]\nprefill_chunk = 0",
            "[sail]\nprefill_chunk = \"wide\"",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(RunConfig::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn prefill_chunk_parses_and_defaults() {
        assert_eq!(RunConfig::default().prefill_chunk, 16);
        let doc = TomlDoc::parse("[sail]\nprefill_chunk = 1").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().prefill_chunk, 1);
        let doc = TomlDoc::parse("[sail]\nprefill_chunk = 64").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().prefill_chunk, 64);
        let doc = TomlDoc::parse("[model]\nname = \"7b\"").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().prefill_chunk, 16, "absent ⇒ default");
    }

    #[test]
    fn numa_policy_parses_and_defaults() {
        let c = RunConfig::default();
        assert_eq!(c.numa, NumaPolicy::Auto);
        for (text, want) in [
            ("[sail]\nnuma = \"off\"", NumaPolicy::Off),
            ("[sail]\nnuma = \"auto\"", NumaPolicy::Auto),
            (
                "[sail]\nnuma = \"0:0-1;1:2-3\"",
                NumaPolicy::Explicit(vec![vec![0, 1], vec![2, 3]]),
            ),
        ] {
            let doc = TomlDoc::parse(text).unwrap();
            assert_eq!(RunConfig::from_doc(&doc).unwrap().numa, want, "{text}");
        }
    }

    #[test]
    fn repo_config_files_parse() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        if !dir.exists() {
            return;
        }
        let mut n = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().map(|e| e == "toml").unwrap_or(false) {
                RunConfig::load(&p).unwrap_or_else(|e| panic!("{p:?}: {e}"));
                n += 1;
            }
        }
        assert!(n >= 3, "expected example configs, found {n}");
    }
}
