//! Multi-layer KV-cached transformer decode on the LUT-GEMV path.
//!
//! This is the generation-stage workload of the paper made concrete: a
//! deterministic llama-style decoder whose **every** weight product — the
//! Q/K/V/O projections, both SwiGLU FFN matrices and the down projection
//! of each layer, plus the output head — is one [`LutGemvEngine`] GEMV
//! dispatched on the shared [`WorkerPool`], exactly the iteration-level
//! tensor scheduling of §III-A. Per-token attention reads a real
//! slot-indexed KV store (fp16- or q8-backed per [`KvCacheSpec`], §III-B)
//! through the [`KvStore`] abstraction: the contiguous slab whose element
//! payload is allocated precisely as `KvCacheSpec::seq_bytes` accounts
//! it, or the paged pool ([`KvBackend`], `SAIL_KV=paged:<page_tokens>`)
//! whose per-slot page tables the same reads and ranged writes walk —
//! bit-identically, with copy-on-write prefix sharing underneath
//! ([`prefix_attach`](LutTransformer::prefix_attach)).
//!
//! The forward comes in two grains: token-at-a-time
//! ([`LutTransformer::step`], one [`DecodeItem`] per slot) and the
//! multi-row [`LutTransformer::step_runs`], where each slot submits a
//! [`DecodeRun`] of consecutive tokens — the **chunked prefill** path.
//! One iteration then runs every projection at effective batch
//! `Σ rows(run)`, so a T-token prompt chunked C-wide builds each weight
//! chunk's LUT `⌈T/C⌉` times instead of `T` times (the paper's high-data-
//! reuse argument applied along the sequence axis), while causal
//! attention inside the chunk keeps the result bit-identical to
//! sequential feeding.
//!
//! Weight precision is **per layer** ([`LayerSpec`]): the paper observes
//! that the optimal bit precision varies across layers, so the spec names
//! one `QuantLevel`/NBW pair per layer (and one for the head) instead of a
//! single global level.
//!
//! Determinism contract (the repo's core invariant, extended to the
//! multi-layer path and pinned by `tests/decode_serving.rs`):
//!
//! - the LUT-GEMV backend is bit-exact at every pool width, and all float
//!   math outside the GEMVs (embedding, RMSNorm, attention softmax, SwiGLU,
//!   residual adds) runs in a fixed sequential order per item — so token
//!   streams are **bit-identical at every pool width**;
//! - every per-item computation depends only on that item's slot state
//!   (its KV pane) and inputs, so **batched decode equals isolated
//!   decode** bit-for-bit.
//!
//! The token/position embedding is a stateless SplitMix64-style hash (no
//! learned table): history enters a token's computation *only* through the
//! KV cache, which is what makes the cache-read path load-bearing — if
//! attention stopped reading the cache, every step would collapse to a
//! function of (token, position) alone and the conformance tests would
//! catch it.
//!
//! NUMA: every projection engine is built with
//! [`LutGemvEngine::with_pool`], so on a multi-node pool each node owns a
//! first-touch copy of its column shard of all 7·L+1 projection matrices
//! and decode's per-token GEMV traffic stays socket-local. Token streams
//! are bit-identical across placement policies (`SAIL_NUMA=off` vs `auto`
//! vs any explicit map), pinned by `tests/numa_placement.rs`.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::kv::{KvBackend, KvCacheSpec, KvMetrics, KvRuntimeConfig, KvStore};
use super::ModelConfig;
use crate::lutgemv::engine::GemvStats;
use crate::lutgemv::{GemvOutput, LutGemvEngine};
use crate::quant::{QuantLevel, QuantizedMatrix, QuantizedVector};
use crate::runtime::{KvFault, WorkerPool};

/// Weight precision of one decoder layer (or of the output head): the
/// quantization level of its matrices and the NBW the LUT streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    pub level: QuantLevel,
    pub nbw: u32,
}

impl LayerSpec {
    pub fn new(level: QuantLevel, nbw: u32) -> Self {
        LayerSpec { level, nbw }
    }
}

/// Shape + precision spec of a decode model. One entry of `layer_specs`
/// per decoder layer — mixed per-layer precision is the intended use.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeSpec {
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (== heads for MHA, < heads for GQA; query head h attends
    /// through KV head `h / (heads / kv_heads)`).
    pub kv_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_context: usize,
    /// Scale-group size of every weight matrix (must divide `hidden` and
    /// `ffn`, the two GEMV reduction widths).
    pub group: usize,
    /// Per-layer weight precision; `layer_specs.len()` is the layer count.
    pub layer_specs: Vec<LayerSpec>,
    /// Output-head precision.
    pub head: LayerSpec,
    /// KV-cache storage precision.
    pub kv: KvCacheSpec,
}

impl DecodeSpec {
    /// A small mixed-precision spec for tests and demos: `layers` decoder
    /// layers cycling Q8/Q4/Q6 (NBW 4/4/2) — precision deliberately varies
    /// across layers.
    pub fn tiny(layers: usize, kv: KvCacheSpec) -> Self {
        let cycle = [
            LayerSpec::new(QuantLevel::Q8, 4),
            LayerSpec::new(QuantLevel::Q4, 4),
            LayerSpec::new(QuantLevel::Q6, 2),
        ];
        DecodeSpec {
            hidden: 32,
            heads: 4,
            kv_heads: 2,
            ffn: 64,
            vocab: 96,
            max_context: 24,
            group: 16,
            layer_specs: (0..layers).map(|l| cycle[l % cycle.len()]).collect(),
            head: LayerSpec::new(QuantLevel::Q4, 4),
            kv,
        }
    }

    /// Uniform precision across all layers and the head.
    pub fn uniform(mut self, level: QuantLevel, nbw: u32) -> Self {
        let spec = LayerSpec::new(level, nbw);
        for l in &mut self.layer_specs {
            *l = spec;
        }
        self.head = spec;
        self
    }

    pub fn layers(&self) -> usize {
        self.layer_specs.len()
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// KV vector width per token: kv_heads × head_dim.
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    /// The matching [`ModelConfig`], so the byte-accounting machinery
    /// (`KvCacheSpec::seq_bytes`, `kv_bytes_per_token`) applies to this
    /// model directly.
    pub fn to_model_config(&self) -> ModelConfig {
        ModelConfig {
            name: format!("lut-decode-{}L-h{}", self.layers(), self.hidden),
            hidden: self.hidden,
            layers: self.layers(),
            heads: self.heads,
            kv_heads: self.kv_heads,
            ffn: self.ffn,
            vocab: self.vocab,
            max_context: self.max_context,
        }
    }

    /// Check internal consistency; every constructor of [`LutTransformer`]
    /// calls this so malformed specs surface as `Err`, not panics deep in
    /// the quantizer.
    pub fn validate(&self) -> Result<()> {
        if self.layer_specs.is_empty() {
            bail!("decode spec has no layers");
        }
        if self.hidden == 0 || self.heads == 0 || self.hidden % self.heads != 0 {
            bail!("hidden {} must be a positive multiple of heads {}", self.hidden, self.heads);
        }
        if self.kv_heads == 0 || self.heads % self.kv_heads != 0 {
            bail!("heads {} must be a positive multiple of kv_heads {}", self.heads, self.kv_heads);
        }
        if self.group == 0 || self.hidden % self.group != 0 || self.ffn % self.group != 0 {
            bail!(
                "group {} must divide hidden {} and ffn {}",
                self.group,
                self.hidden,
                self.ffn
            );
        }
        if self.vocab == 0 || self.max_context == 0 {
            bail!("vocab and max_context must be positive");
        }
        for (l, s) in self.layer_specs.iter().chain(std::iter::once(&self.head)).enumerate() {
            if !(1..=8).contains(&s.nbw) || s.nbw as usize > self.group {
                bail!("layer {l}: NBW {} outside 1..=8 or exceeds group {}", s.nbw, self.group);
            }
        }
        Ok(())
    }
}

/// One decode-iteration work item: advance `slot` by feeding `token` at
/// KV position `pos`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeItem {
    pub slot: usize,
    pub token: i32,
    pub pos: usize,
}

/// One iteration's work for one slot in the multi-row
/// [`LutTransformer::step_runs`] form: feed `tokens[i]` at KV position
/// `start_pos + i` (a prefill chunk when longer than one token). Only the
/// run's **last** position produces a logits row — the interior rows
/// exist to write KV, exactly what sequential prefill does with its
/// discarded predictions.
#[derive(Debug, Clone, Copy)]
pub struct DecodeRun<'a> {
    pub slot: usize,
    pub tokens: &'a [i32],
    pub start_pos: usize,
}

/// Kernel counters of one layer, split per projection — the observability
/// that lets tests (and the perf bench) assert every projection actually
/// ran on the LUT path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerGemvStats {
    pub q: GemvStats,
    pub k: GemvStats,
    pub v: GemvStats,
    pub o: GemvStats,
    pub gate: GemvStats,
    pub up: GemvStats,
    pub down: GemvStats,
}

impl LayerGemvStats {
    /// Named view over the seven projections, in execution order.
    pub fn projections(&self) -> [(&'static str, GemvStats); 7] {
        [
            ("q", self.q),
            ("k", self.k),
            ("v", self.v),
            ("o", self.o),
            ("gate", self.gate),
            ("up", self.up),
            ("down", self.down),
        ]
    }

    /// Sum over the layer's projections.
    pub fn total(&self) -> GemvStats {
        let mut t = GemvStats::default();
        for (_, s) in self.projections() {
            t += s;
        }
        t
    }
}

/// Accumulated per-projection kernel counters across all steps.
///
/// Exactly-once accounting: a forward pass accumulates into a private
/// staging copy and commits here only when the whole pass succeeds, so a
/// failed iteration (e.g. an injected KV fault after layer 0 already ran
/// its Q/K/V GEMVs) contributes nothing and the batcher's solo retry is
/// counted once — not `k` partial layers plus a full retry.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// One entry per decoder layer.
    pub layers: Vec<LayerGemvStats>,
    /// The output head's counters.
    pub head: GemvStats,
    pub steps: u64,
    pub tokens: u64,
}

/// The float-valued weights of one decoder layer, pre-quantization.
struct LayerFloats {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
}

/// Seeded float weights of a decode model, generated **once** and shared
/// between a target and any draft derived from it ([`DraftSpec`]). The
/// PRNG stream depends only on the model dimensions and the seed — never
/// on the per-layer quant levels — so quantizing the same float set under
/// two specs yields a self-speculative pair whose divergence comes purely
/// from precision/depth reduction, not from different weights.
pub struct FloatWeights {
    hidden: usize,
    kv_dim: usize,
    ffn: usize,
    vocab: usize,
    layers: Vec<LayerFloats>,
    head: Vec<f32>,
}

impl FloatWeights {
    /// Draw the full weight set for `spec`'s dimensions from
    /// `Prng::new(seed)`, in the exact matrix order the seeded
    /// constructors have always used (per layer: Q, K, V, O, gate, up,
    /// down; then the head) — `LutTransformer::random*` models stay
    /// bit-identical to their pre-refactor selves.
    pub fn generate(spec: &DecodeSpec, seed: u64) -> FloatWeights {
        let h = spec.hidden;
        let kvd = spec.kv_dim();
        let mut prng = crate::util::Prng::new(seed);
        let mut draw =
            |n: usize, k: usize| -> Vec<f32> { (0..n * k).map(|_| prng.normal() as f32).collect() };
        let layers = (0..spec.layers())
            .map(|_| LayerFloats {
                wq: draw(h, h),
                wk: draw(kvd, h),
                wv: draw(kvd, h),
                wo: draw(h, h),
                w_gate: draw(spec.ffn, h),
                w_up: draw(spec.ffn, h),
                w_down: draw(h, spec.ffn),
            })
            .collect();
        let head = draw(spec.vocab, h);
        FloatWeights { hidden: h, kv_dim: kvd, ffn: spec.ffn, vocab: spec.vocab, layers, head }
    }

    /// Layer count of the generated set (a draft spec may use a prefix).
    pub fn layers(&self) -> usize {
        self.layers.len()
    }
}

/// Recipe for deriving a cheap *draft* model from a target spec for
/// self-speculative decoding: same dimensions, vocabulary, and KV
/// precision, but fewer effective weight bits and/or a truncated layer
/// stack. The draft re-quantizes the **same** [`FloatWeights`] the
/// target uses, so it is "the model, degraded" rather than a second
/// model — the paper-adjacent CPU speculation setup where draft cost
/// shrinks with bit width while the verify pass amortizes through the
/// multi-row `step_runs` forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DraftSpec {
    /// Re-quantize every kept layer (and the head) at this uniform
    /// level, applied only where it *lowers* the bits — a draft is never
    /// more precise than its target.
    pub bits: Option<QuantLevel>,
    /// Keep only the first `n` decoder layers of the target's stack.
    pub layers: Option<usize>,
}

impl DraftSpec {
    /// Derive the draft's [`DecodeSpec`] from the target's: truncate the
    /// layer stack, then lower per-layer levels. `Default::default()`
    /// (no reduction) is legal and yields a draft identical to the
    /// target — useful as the 100%-acceptance calibration point.
    pub fn from_target(&self, target: &DecodeSpec) -> Result<DecodeSpec> {
        let n = self.layers.unwrap_or(target.layers());
        if n == 0 || n > target.layers() {
            bail!("draft layer count {n} outside 1..={}", target.layers());
        }
        let mut spec = target.clone();
        spec.layer_specs.truncate(n);
        if let Some(level) = self.bits {
            for ls in spec.layer_specs.iter_mut().chain(std::iter::once(&mut spec.head)) {
                if level.bits() < ls.level.bits() {
                    ls.level = level;
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// One decoder layer's quantized weights, each its own LUT-GEMV engine.
struct LayerWeights {
    wq: LutGemvEngine,
    wk: LutGemvEngine,
    wv: LutGemvEngine,
    wo: LutGemvEngine,
    w_gate: LutGemvEngine,
    w_up: LutGemvEngine,
    w_down: LutGemvEngine,
}

/// The multi-layer KV-cached decode model. See the module docs for the
/// architecture and the determinism contract.
pub struct LutTransformer {
    spec: DecodeSpec,
    layers: Vec<LayerWeights>,
    head: LutGemvEngine,
    kv: KvBackend,
    pool: Arc<WorkerPool>,
    batch: usize,
    /// Per-projection kernel counters (public observability). Committed
    /// from `staged` only by forwards that complete successfully.
    pub stats: DecodeStats,
    /// In-flight counters of the current forward; discarded (overwritten
    /// at the next forward's start) when the pass fails mid-way.
    staged: DecodeStats,
    // Reused scratch (steady-state step does not grow or reallocate
    // these — including the quantized-activation buffers, whose int8 code
    // vectors recycle through `QuantizedVector::quantize_into`).
    x: Vec<f32>,
    xn: Vec<f32>,
    attn: Vec<f32>,
    mlp: Vec<f32>,
    scores: Vec<f32>,
    kbuf: Vec<f32>,
    vbuf: Vec<f32>,
    /// Gather buffer for the head projection's inputs: each run's last
    /// row of the residual stream (interior prefill rows predict
    /// nothing, so the head runs at batch = runs, not batch = rows).
    head_x: Vec<f32>,
    /// Quantized activations of width `hidden` (projection inputs).
    quant_h: Vec<QuantizedVector>,
    /// Quantized activations of width `ffn` (down-projection inputs).
    quant_f: Vec<QuantizedVector>,
    out_q: GemvOutput,
    out_k: GemvOutput,
    out_v: GemvOutput,
    out_g: GemvOutput,
    out_u: GemvOutput,
    out_m: GemvOutput,
    logits: GemvOutput,
}

/// Deterministic token/position embedding component `i` in `[-1, 1)`:
/// the shared [`crate::util::splitmix_embed`] hash (stateless, so it is
/// identical on every thread, at every batch size, and across pool
/// widths/placements).
fn embed(token: i32, position: usize, i: usize) -> f32 {
    crate::util::splitmix_embed(token, position as u64, i)
}

/// Row-wise RMS normalization (no learned gain): `y = x / rms(x)`.
/// Sequential per row, f64 mean-square — deterministic everywhere.
fn rmsnorm_rows(src: &[f32], dst: &mut Vec<f32>, width: usize) {
    dst.resize(src.len(), 0.0);
    for (srow, drow) in src.chunks_exact(width).zip(dst.chunks_exact_mut(width)) {
        let ms = srow.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / width as f64;
        let inv = (1.0 / (ms + 1e-6).sqrt()) as f32;
        for (d, &s) in drow.iter_mut().zip(srow) {
            *d = s * inv;
        }
    }
}

/// Re-quantize each `width`-wide row of `data` into `buf`, reusing both
/// the outer vector and every activation's int8 code buffer (no
/// steady-state allocation on the decode hot path).
fn requantize_rows(buf: &mut Vec<QuantizedVector>, data: &[f32], width: usize) {
    let n = data.len() / width;
    buf.truncate(n);
    while buf.len() < n {
        buf.push(QuantizedVector { q: Vec::new(), scale: 1.0, bits: 8 });
    }
    for (qv, row) in buf.iter_mut().zip(data.chunks_exact(width)) {
        qv.quantize_into(row);
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl LutTransformer {
    /// Build a model with seeded random weights: the same `(spec, seed)`
    /// gives the same model at any batch size and any pool width. The KV
    /// store layout comes from the `SAIL_KV` env
    /// ([`KvRuntimeConfig::from_env`]); token streams are bit-identical
    /// whichever store is selected.
    pub fn random(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<Self> {
        Self::random_with_kv(spec, seed, batch, pool, KvRuntimeConfig::from_env())
    }

    /// [`random`](Self::random) with an explicit KV store configuration
    /// (layout, prefix cache, page budget) instead of the `SAIL_KV` env —
    /// the constructor benches and the conformance matrix use to pin
    /// paged vs contiguous side by side in one process.
    pub fn random_with_kv(
        spec: DecodeSpec,
        seed: u64,
        batch: usize,
        pool: Arc<WorkerPool>,
        kv_cfg: KvRuntimeConfig,
    ) -> Result<Self> {
        let floats = FloatWeights::generate(&spec, seed);
        Self::from_floats(spec, &floats, batch, pool, kv_cfg)
    }

    /// Build a model by quantizing a pre-generated [`FloatWeights`] set
    /// under `spec` — the constructor both halves of a self-speculative
    /// pair share ([`DraftSpec::from_target`] derives the draft's spec,
    /// then target and draft each quantize the *same* floats).
    /// `spec.layers()` may be smaller than the float set's layer count (a
    /// layer-truncated draft quantizes the prefix of the stack);
    /// dimensions must match exactly.
    pub fn from_floats(
        spec: DecodeSpec,
        floats: &FloatWeights,
        batch: usize,
        pool: Arc<WorkerPool>,
        kv_cfg: KvRuntimeConfig,
    ) -> Result<Self> {
        spec.validate()?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        if spec.hidden != floats.hidden
            || spec.kv_dim() != floats.kv_dim
            || spec.ffn != floats.ffn
            || spec.vocab != floats.vocab
        {
            bail!(
                "spec dimensions (h {}, kv {}, ffn {}, vocab {}) do not match the float \
                 weight set (h {}, kv {}, ffn {}, vocab {})",
                spec.hidden,
                spec.kv_dim(),
                spec.ffn,
                spec.vocab,
                floats.hidden,
                floats.kv_dim,
                floats.ffn,
                floats.vocab
            );
        }
        if spec.layers() > floats.layers.len() {
            bail!(
                "spec wants {} layers but the float weight set has {}",
                spec.layers(),
                floats.layers.len()
            );
        }
        let h = spec.hidden;
        let kvd = spec.kv_dim();
        // Every projection engine is *placed* for the serving pool: its
        // weight shards are first-touch-copied onto the node groups whose
        // pinned workers will read them, so steady-state decode never
        // streams weights across a socket (a no-op single shard on
        // single-node pools). Weight values depend only on (spec, seed) —
        // placement changes where bytes live, never what they are.
        let gen = |w: &[f32], n: usize, k: usize, ls: LayerSpec| -> LutGemvEngine {
            LutGemvEngine::with_pool(
                QuantizedMatrix::quantize(w, n, k, ls.level, spec.group),
                ls.nbw,
                &pool,
            )
        };
        let layers: Vec<LayerWeights> = spec
            .layer_specs
            .iter()
            .zip(&floats.layers)
            .map(|(&ls, lf)| LayerWeights {
                wq: gen(&lf.wq, h, h, ls),
                wk: gen(&lf.wk, kvd, h, ls),
                wv: gen(&lf.wv, kvd, h, ls),
                wo: gen(&lf.wo, h, h, ls),
                w_gate: gen(&lf.w_gate, spec.ffn, h, ls),
                w_up: gen(&lf.w_up, spec.ffn, h, ls),
                w_down: gen(&lf.w_down, h, spec.ffn, ls),
            })
            .collect();
        let head = gen(&floats.head, spec.vocab, h, spec.head);
        let mut kv = KvBackend::build(kv_cfg, spec.kv, spec.layers(), batch, spec.max_context, kvd)?;
        // Interleave the paged pool's page frames across the placement's
        // node groups (round-robin, deterministic) — the PR-4 NUMA
        // follow-on. A no-op on the contiguous slab and on single-group
        // placements.
        if let KvBackend::Paged { store, .. } = &kv {
            let nodes = pool.placement().interleave_pages(store.pool_pages());
            kv.set_numa_interleave(nodes);
        }
        let stats = DecodeStats {
            layers: vec![LayerGemvStats::default(); spec.layers()],
            ..DecodeStats::default()
        };
        let staged = stats.clone();
        Ok(LutTransformer {
            spec,
            layers,
            head,
            kv,
            pool,
            batch,
            stats,
            staged,
            x: Vec::new(),
            xn: Vec::new(),
            attn: Vec::new(),
            mlp: Vec::new(),
            scores: Vec::new(),
            kbuf: Vec::new(),
            vbuf: Vec::new(),
            head_x: Vec::new(),
            quant_h: Vec::new(),
            quant_f: Vec::new(),
            out_q: GemvOutput::new(),
            out_k: GemvOutput::new(),
            out_v: GemvOutput::new(),
            out_g: GemvOutput::new(),
            out_u: GemvOutput::new(),
            out_m: GemvOutput::new(),
            logits: GemvOutput::new(),
        })
    }

    pub fn spec(&self) -> &DecodeSpec {
        &self.spec
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn kv(&self) -> &KvBackend {
        &self.kv
    }

    /// Paged-store observability (pool occupancy, COW copies, prefix hit
    /// counters); `None` on the contiguous slab.
    pub fn kv_metrics(&self) -> Option<KvMetrics> {
        self.kv.metrics()
    }

    /// Map the longest cached prefix of `feed` read-only into `slot`'s
    /// page table and return the feed index prefill should start from
    /// (0 = cold; the batcher seeds `fed`/`pos` with the split). Must be
    /// called on a freshly reset slot, before any token of the request
    /// runs — the shared span's tokens are then never fed, so no LUT is
    /// built for them ([`prefix_attach` is the "skip prefill
    /// entirely"](KvBackend::prefix_attach) path). Contiguous stores
    /// always return 0.
    pub fn prefix_attach(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        if slot >= self.batch {
            bail!("slot {slot} outside batch {}", self.batch);
        }
        self.kv.prefix_attach(slot, feed)
    }

    /// Publish `slot`'s completed prefill of `feed` into the prefix tree
    /// (see [`KvBackend::prefix_insert`]); a no-op on contiguous stores.
    pub fn prefix_insert(&mut self, slot: usize, feed: &[i32]) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} outside batch {}", self.batch);
        }
        self.kv.prefix_insert(slot, feed)
    }

    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Logits of the last [`step`](Self::step) /
    /// [`step_runs`](Self::step_runs): one row per item (resp. per run),
    /// in submission order.
    pub fn logits(&self) -> &GemvOutput {
        &self.logits
    }

    /// Clear one slot's KV panes (called on admission by the batcher).
    /// Also clears any latched injected KV-write fault on the slot — a
    /// faulted request's slot is fully healthy for the next admission.
    pub fn reset_slot(&mut self, slot: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} outside batch {}", self.batch);
        }
        if let Some(plan) = self.pool.fault_plan() {
            plan.kv_slot_reset(slot);
        }
        self.kv.reset_slot(slot);
        Ok(())
    }

    /// Roll back one slot's KV history tail — the speculative-decode
    /// rejection path. After a verify forward wrote positions up to
    /// `written`, positions `keep .. written` return to the never-written
    /// state on either store layout (zeroed slab range; unmapped +
    /// released pages with the free list restored in order — see
    /// [`KvStore::truncate_slot`]), so the store is indistinguishable
    /// from one that never saw the rejected tokens.
    pub fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} outside batch {}", self.batch);
        }
        self.kv.truncate_slot(slot, keep, written)
    }

    /// Advance every item by one token: run all layers (each projection a
    /// pooled LUT-GEMV, attention over the slot's KV pane including the
    /// token just written) and leave per-item logits in
    /// [`logits`](Self::logits).
    ///
    /// This is the single-token convenience form of
    /// [`step_runs`](Self::step_runs) (every item becomes a length-1
    /// run), kept because decode-time callers think in tokens.
    pub fn step(&mut self, items: &[DecodeItem]) -> Result<()> {
        let runs: Vec<DecodeRun> = items
            .iter()
            .map(|it| DecodeRun {
                slot: it.slot,
                tokens: std::slice::from_ref(&it.token),
                start_pos: it.pos,
            })
            .collect();
        self.step_runs(&runs)
    }

    /// Advance every run's slot by all of its tokens in one forward pass
    /// — the chunked-prefill tentpole. Every projection of every layer
    /// (and the head, at batch = runs) executes as **one**
    /// `gemv_batch_into` at effective batch `Σ rows(run)`, so each weight
    /// chunk's LUT is built once per iteration and read by every row,
    /// instead of being rebuilt per token as sequential prefill does.
    ///
    /// Causality inside a chunk: all rows' K/V are projected and written
    /// to the cache first, then row `i` (at position `p`) attends over
    /// cached positions `0..=p` — reading the slot's history *plus* the
    /// in-flight rows at earlier chunk positions, and never a later row.
    /// Because each row's float math is sequential per row and every
    /// GEMV row is independent of its batch neighbours, the result is
    /// **bit-identical** to feeding the same tokens one at a time
    /// (pinned by tests and `tests/prefill_chunking.rs`).
    ///
    /// Leaves one logits row per run (the run's last position) in
    /// [`logits`](Self::logits), in run order.
    pub fn step_runs(&mut self, runs: &[DecodeRun]) -> Result<()> {
        self.forward(runs, false)
    }

    /// [`step_runs`](Self::step_runs) with a logits row for **every** fed
    /// position, not just each run's last: logits row `i` is the
    /// next-token distribution after consuming the i-th row (run order,
    /// position order within a run), bit-identical to the row `step_runs`
    /// would have produced had the run stopped at that position (row-wise
    /// norm/quantize/GEMV are all independent per row). This is the
    /// speculative-decode *verify* forward: one multi-row pass prices a
    /// whole k-token draft at a single LUT build per weight chunk, and
    /// per-position argmax over these rows decides the accepted prefix.
    pub fn step_runs_all_logits(&mut self, runs: &[DecodeRun]) -> Result<()> {
        self.forward(runs, true)
    }

    fn forward(&mut self, runs: &[DecodeRun], all_logits: bool) -> Result<()> {
        let h = self.spec.hidden;
        let mut rows = 0usize;
        for r in runs {
            if r.slot >= self.batch {
                bail!("slot {} outside batch {}", r.slot, self.batch);
            }
            if r.tokens.is_empty() {
                bail!("empty token run for slot {}", r.slot);
            }
            if r.start_pos + r.tokens.len() > self.spec.max_context {
                bail!(
                    "positions {}..{} outside the {}-token context window (the batcher \
                     must finish the request with ContextFull first)",
                    r.start_pos,
                    r.start_pos + r.tokens.len(),
                    self.spec.max_context
                );
            }
            rows += r.tokens.len();
        }
        self.logits.reset(if all_logits { rows } else { runs.len() }, self.spec.vocab);
        if runs.is_empty() {
            return Ok(());
        }
        // Exactly-once stats: this forward accumulates into `staged` and
        // commits into `stats` only if every layer and the head succeed.
        // A pass that fails mid-way (KV fault at layer k) leaves `stats`
        // untouched, so the batcher's solo retry of the same run is
        // counted once instead of once plus k partial layers.
        self.reset_staged();

        // Stateless embedding of every row: history enters only through
        // the KV cache.
        self.x.resize(rows * h, 0.0);
        let mut row = 0usize;
        for r in runs {
            for (j, &tok) in r.tokens.iter().enumerate() {
                let xr = &mut self.x[row * h..(row + 1) * h];
                for (i, xi) in xr.iter_mut().enumerate() {
                    *xi = embed(tok, r.start_pos + j, i);
                }
                row += 1;
            }
        }

        for l in 0..self.layers.len() {
            self.attention_block(l, runs)?;
            self.ffn_block(l)?;
        }

        if all_logits {
            // Verify mode: the head runs at batch = rows — every fed
            // position predicts, so a k-token draft is judged in one pass.
            rmsnorm_rows(&self.x, &mut self.xn, h);
            requantize_rows(&mut self.quant_h, &self.xn, h);
        } else {
            // Output head: only each run's last row predicts a next token.
            self.head_x.resize(runs.len() * h, 0.0);
            let mut row = 0usize;
            for (ri, r) in runs.iter().enumerate() {
                row += r.tokens.len();
                self.head_x[ri * h..(ri + 1) * h]
                    .copy_from_slice(&self.x[(row - 1) * h..row * h]);
            }
            rmsnorm_rows(&self.head_x, &mut self.xn, h);
            requantize_rows(&mut self.quant_h, &self.xn, h);
        }
        self.staged.head +=
            self.head.gemv_batch_into(&self.quant_h, &self.pool, &mut self.logits)?;
        self.staged.steps += 1;
        self.staged.tokens += rows as u64;
        self.commit_staged();
        Ok(())
    }

    /// Zero the staging counters at the start of a forward (any residue
    /// belongs to a previous *failed* pass and must be discarded).
    fn reset_staged(&mut self) {
        for l in &mut self.staged.layers {
            *l = LayerGemvStats::default();
        }
        self.staged.head = GemvStats::default();
        self.staged.steps = 0;
        self.staged.tokens = 0;
    }

    /// Fold a completed forward's staged counters into the public stats.
    fn commit_staged(&mut self) {
        for (dst, src) in self.stats.layers.iter_mut().zip(&self.staged.layers) {
            dst.q += src.q;
            dst.k += src.k;
            dst.v += src.v;
            dst.o += src.o;
            dst.gate += src.gate;
            dst.up += src.up;
            dst.down += src.down;
        }
        self.stats.head += self.staged.head;
        self.stats.steps += self.staged.steps;
        self.stats.tokens += self.staged.tokens;
    }

    /// Q/K/V projections for all rows, ranged KV-cache append per run,
    /// causal attention per row over its window, O projection, residual
    /// add. Pool dispatch failures and KV-write rejections (including
    /// injected ones) surface as typed errors; a retried call re-embeds
    /// and rewrites the same KV values, so a failed iteration leaves no
    /// divergent state behind.
    fn attention_block(&mut self, l: usize, runs: &[DecodeRun]) -> Result<()> {
        let h = self.spec.hidden;
        let hd = self.spec.head_dim();
        let heads = self.spec.heads;
        let kvd = self.spec.kv_dim();
        let heads_per_kv = heads / self.spec.kv_heads;
        let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
        let rows = self.x.len() / h;

        rmsnorm_rows(&self.x, &mut self.xn, h);
        requantize_rows(&mut self.quant_h, &self.xn, h);
        let lw = &self.layers[l];
        let ls = &mut self.staged.layers[l];
        ls.q += lw.wq.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_q)?;
        ls.k += lw.wk.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_k)?;
        ls.v += lw.wv.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_v)?;

        // Append every row's K/V — one ranged write per run
        // (`KvCache::write_run`: a single base/bounds computation for the
        // whole chunk). Writing all rows before attending is safe: causal
        // masking is the *read window* below, so row i never sees a later
        // row's K/V; and the current rows' K/V pass through storage
        // precision too, treating cached and fresh history identically.
        let fault_plan = self.pool.fault_plan();
        let mut row0 = 0usize;
        for r in runs {
            let len = r.tokens.len();
            let mut start_pos = r.start_pos;
            if let Some(plan) = fault_plan.as_deref() {
                match plan.kv_write_fault(r.slot) {
                    Some(KvFault::Fail) => {
                        bail!("injected fault: KV write failed for slot {}", r.slot)
                    }
                    // Drive the corrupted position through the cache's own
                    // bounds check — it must come back as a typed error,
                    // never land in a neighbouring pane.
                    Some(KvFault::CorruptPosition) => start_pos = self.spec.max_context,
                    None => {}
                }
            }
            self.kv.write_run(
                l,
                r.slot,
                start_pos,
                &self.out_k.as_slice()[row0 * kvd..(row0 + len) * kvd],
                &self.out_v.as_slice()[row0 * kvd..(row0 + len) * kvd],
            )?;
            row0 += len;
        }

        self.attn.resize(rows * h, 0.0);
        self.attn.fill(0.0);
        self.kbuf.resize(kvd, 0.0);
        self.vbuf.resize(kvd, 0.0);
        let mut i = 0usize;
        for r in runs {
            for j in 0..r.tokens.len() {
                let pos = r.start_pos + j;
                let ctx = pos + 1;
                let q_row = self.out_q.row(i);
                self.scores.resize(heads * ctx, 0.0);
                // Pass 1: one K read per cached position, scores for all
                // heads.
                for t in 0..ctx {
                    self.kv.read_k(l, r.slot, t, &mut self.kbuf);
                    for hi in 0..heads {
                        let kh = hi / heads_per_kv;
                        let q_h = &q_row[hi * hd..(hi + 1) * hd];
                        let k_h = &self.kbuf[kh * hd..(kh + 1) * hd];
                        let dot =
                            q_h.iter().zip(k_h).fold(0.0f32, |acc, (&a, &b)| acc + a * b);
                        self.scores[hi * ctx + t] = dot * inv_sqrt_hd;
                    }
                }
                // Softmax per head (max-subtracted, sequential —
                // deterministic).
                for head_scores in self.scores.chunks_exact_mut(ctx) {
                    let max = head_scores.iter().fold(f32::NEG_INFINITY, |m, &s| m.max(s));
                    let mut sum = 0.0f32;
                    for s in head_scores.iter_mut() {
                        *s = (*s - max).exp();
                        sum += *s;
                    }
                    for s in head_scores.iter_mut() {
                        *s /= sum;
                    }
                }
                // Pass 2: one V read per cached position, weighted
                // accumulate.
                let out_row = &mut self.attn[i * h..(i + 1) * h];
                for t in 0..ctx {
                    self.kv.read_v(l, r.slot, t, &mut self.vbuf);
                    for hi in 0..heads {
                        let kh = hi / heads_per_kv;
                        let w = self.scores[hi * ctx + t];
                        let v_h = &self.vbuf[kh * hd..(kh + 1) * hd];
                        for (o, &v) in out_row[hi * hd..(hi + 1) * hd].iter_mut().zip(v_h) {
                            *o += w * v;
                        }
                    }
                }
                i += 1;
            }
        }

        requantize_rows(&mut self.quant_h, &self.attn, h);
        let ls = &mut self.staged.layers[l];
        ls.o += self.layers[l].wo.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_m)?;
        let orows = self.out_m.as_slice();
        for (xrow, orow) in self.x.chunks_exact_mut(h).zip(orows.chunks_exact(h)) {
            for (xi, &oi) in xrow.iter_mut().zip(orow) {
                *xi += oi;
            }
        }
        Ok(())
    }

    /// SwiGLU FFN: gate/up projections, `silu(gate) ⊙ up`, down
    /// projection, residual add.
    fn ffn_block(&mut self, l: usize) -> Result<()> {
        let h = self.spec.hidden;
        let ffn = self.spec.ffn;
        rmsnorm_rows(&self.x, &mut self.xn, h);
        requantize_rows(&mut self.quant_h, &self.xn, h);
        let lw = &self.layers[l];
        let ls = &mut self.staged.layers[l];
        ls.gate += lw.w_gate.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_g)?;
        ls.up += lw.w_up.gemv_batch_into(&self.quant_h, &self.pool, &mut self.out_u)?;
        self.mlp.resize(self.out_g.as_slice().len(), 0.0);
        for ((m, &g), &u) in
            self.mlp.iter_mut().zip(self.out_g.as_slice()).zip(self.out_u.as_slice())
        {
            *m = silu(g) * u;
        }
        requantize_rows(&mut self.quant_f, &self.mlp, ffn);
        let ls = &mut self.staged.layers[l];
        ls.down +=
            self.layers[l].w_down.gemv_batch_into(&self.quant_f, &self.pool, &mut self.out_m)?;
        let drows = self.out_m.as_slice();
        for (xrow, drow) in self.x.chunks_exact_mut(h).zip(drows.chunks_exact(h)) {
            for (xi, &di) in xrow.iter_mut().zip(drow) {
                *xi += di;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool1() -> Arc<WorkerPool> {
        WorkerPool::shared(1)
    }

    fn items(pairs: &[(usize, i32, usize)]) -> Vec<DecodeItem> {
        pairs.iter().map(|&(slot, token, pos)| DecodeItem { slot, token, pos }).collect()
    }

    #[test]
    fn spec_validation_catches_malformed_shapes() {
        let ok = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.layer_specs.clear();
        assert!(bad.validate().is_err(), "no layers");
        let mut bad = ok.clone();
        bad.heads = 5; // 32 % 5 != 0
        assert!(bad.validate().is_err(), "hidden not divisible by heads");
        let mut bad = ok.clone();
        bad.kv_heads = 3; // 4 % 3 != 0
        assert!(bad.validate().is_err(), "heads not divisible by kv_heads");
        let mut bad = ok.clone();
        bad.group = 24; // divides neither 32 nor 64
        assert!(bad.validate().is_err(), "group must divide hidden and ffn");
        let mut bad = ok.clone();
        bad.layer_specs[0].nbw = 20;
        assert!(bad.validate().is_err(), "nbw out of range");
        assert!(LutTransformer::random(ok, 1, 0, pool1()).is_err(), "zero batch");
    }

    #[test]
    fn same_seed_same_logits() {
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let mut a = LutTransformer::random(spec.clone(), 7, 2, pool1()).unwrap();
        let mut b = LutTransformer::random(spec, 7, 2, pool1()).unwrap();
        let its = items(&[(0, 3, 0), (1, 11, 0)]);
        a.step(&its).unwrap();
        b.step(&its).unwrap();
        assert_eq!(a.logits(), b.logits());
        assert!(a.logits().row(0) != a.logits().row(1), "different tokens, same logits");
    }

    #[test]
    fn kv_cache_is_actually_read_by_attention() {
        // Two models, identical weights; write *different* history at
        // position 0, then feed the *same* token at position 1. If the
        // attention step reads the cache, the logits must differ; if the
        // cache were decorative (the pre-PR state of model/kv.rs) they
        // would be identical.
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let mut a = LutTransformer::random(spec.clone(), 7, 1, pool1()).unwrap();
        let mut b = LutTransformer::random(spec, 7, 1, pool1()).unwrap();
        a.step(&items(&[(0, 3, 0)])).unwrap();
        b.step(&items(&[(0, 50, 0)])).unwrap();
        a.step(&items(&[(0, 5, 1)])).unwrap();
        b.step(&items(&[(0, 5, 1)])).unwrap();
        assert!(
            a.logits().row(0) != b.logits().row(0),
            "logits ignored the differing cached history"
        );
        // And resetting the slot erases that history dependence.
        let mut c = LutTransformer::random(
            DecodeSpec::tiny(2, KvCacheSpec::fp16()),
            7,
            1,
            pool1(),
        )
        .unwrap();
        c.step(&items(&[(0, 50, 0)])).unwrap();
        c.reset_slot(0).unwrap();
        c.step(&items(&[(0, 3, 0)])).unwrap();
        c.step(&items(&[(0, 5, 1)])).unwrap();
        assert_eq!(a.logits(), c.logits(), "reset_slot did not clear the pane");
    }

    #[test]
    fn mixed_per_layer_precision_is_materialized() {
        let spec = DecodeSpec::tiny(3, KvCacheSpec::q8());
        // The tiny cycle really is mixed.
        assert_ne!(spec.layer_specs[0], spec.layer_specs[1]);
        let m = LutTransformer::random(spec, 7, 1, pool1()).unwrap();
        assert_eq!(m.layers[0].wq.weights().level, QuantLevel::Q8);
        assert_eq!(m.layers[1].wq.weights().level, QuantLevel::Q4);
        assert_eq!(m.layers[2].wq.weights().level, QuantLevel::Q6);
        assert_eq!(m.layers[2].wq.nbw(), 2);
        assert_eq!(m.head.weights().level, QuantLevel::Q4);
    }

    #[test]
    fn out_of_window_position_is_an_error_not_a_panic() {
        let spec = DecodeSpec::tiny(1, KvCacheSpec::fp16());
        let ctx = spec.max_context;
        let mut m = LutTransformer::random(spec, 7, 1, pool1()).unwrap();
        assert!(m.step(&items(&[(0, 1, ctx)])).is_err());
        assert!(m.step(&items(&[(2, 1, 0)])).is_err(), "slot outside batch");
        // The model still serves after a rejected call.
        m.step(&items(&[(0, 1, 0)])).unwrap();
    }

    #[test]
    fn empty_item_list_is_a_no_op() {
        let mut m =
            LutTransformer::random(DecodeSpec::tiny(1, KvCacheSpec::fp16()), 7, 1, pool1())
                .unwrap();
        m.step(&[]).unwrap();
        assert_eq!(m.logits().batch(), 0);
        assert_eq!(m.stats.tokens, 0);
    }

    #[test]
    fn kv_allocation_matches_spec_accounting() {
        // Layout-aware: the contiguous slab allocates exactly
        // `batch_bytes`; the paged pool allocates exactly
        // `pool_pages × page_bytes` (worst case + budget). `random` reads
        // SAIL_KV, so this test must hold under either CI leg.
        use super::super::kv::KvLayout;
        for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let spec = DecodeSpec::tiny(3, kv);
            let cfg = spec.to_model_config();
            let m = LutTransformer::random(spec, 7, 4, pool1()).unwrap();
            match m.kv().layout() {
                KvLayout::Contiguous => {
                    assert_eq!(m.kv().data_bytes(), kv.batch_bytes(&cfg, cfg.max_context, 4));
                }
                KvLayout::Paged { page_tokens } => {
                    let store = m.kv().paged().unwrap();
                    assert_eq!(
                        m.kv().data_bytes(),
                        store.pool_pages() as u64 * kv.page_bytes(&cfg, page_tokens)
                    );
                }
            }
            // And the explicit paged constructor, independent of the env.
            let spec = DecodeSpec::tiny(3, kv);
            let p = LutTransformer::random_with_kv(
                spec, 7, 4, pool1(), KvRuntimeConfig::paged(16),
            )
            .unwrap();
            let store = p.kv().paged().unwrap();
            assert_eq!(
                p.kv().data_bytes(),
                store.pool_pages() as u64 * kv.page_bytes(&cfg, 16)
            );
        }
    }

    #[test]
    fn prefix_attach_matches_cold_prefill_bit_for_bit() {
        let spec = DecodeSpec::tiny(2, KvCacheSpec::q8());
        let mut m =
            LutTransformer::random_with_kv(spec, 7, 2, pool1(), KvRuntimeConfig::paged(4))
                .unwrap();
        let prompt: Vec<i32> = vec![3, 50, 7, 21, 9, 12, 6, 8, 40];
        // Cold prefill on slot 0, published into the prefix tree.
        assert_eq!(m.prefix_attach(0, &prompt).unwrap(), 0, "empty tree must miss");
        m.step_runs(&[DecodeRun { slot: 0, tokens: &prompt, start_pos: 0 }]).unwrap();
        let cold = m.logits().row(0).to_vec();
        m.prefix_insert(0, &prompt).unwrap();
        let tokens_after_cold = m.stats.tokens;
        // Warm admission on slot 1: the two full pages (8 of 9 tokens)
        // attach; only the tail past the split is ever fed.
        let split = m.prefix_attach(1, &prompt).unwrap();
        assert_eq!(split, 8);
        m.step_runs(&[DecodeRun { slot: 1, tokens: &prompt[split..], start_pos: split }])
            .unwrap();
        assert_eq!(m.stats.tokens - tokens_after_cold, 1, "shared span must not be re-fed");
        assert_eq!(m.logits().row(0), cold.as_slice(), "warm logits diverged from cold");
        // The decode trajectories stay identical too.
        m.step(&items(&[(0, 5, 9), (1, 5, 9)])).unwrap();
        assert_eq!(m.logits().row(0), m.logits().row(1), "post-attach decode diverged");
        let km = m.kv_metrics().unwrap();
        assert_eq!((km.prefix_hits, km.prefix_misses), (1, 1));
        assert!((km.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(km.cow_copies, 0, "the tail wrote a fresh page, not a shared one");
    }

    #[test]
    fn full_prefix_hit_cows_the_last_shared_page() {
        // An exactly-page-aligned full-prompt hit re-feeds the last token
        // (split ≤ len − 1), which rewrites a shared page → exactly one
        // COW — and the original page keeps the original bits, so the
        // cold slot's stream is untouched.
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let mut m =
            LutTransformer::random_with_kv(spec, 7, 2, pool1(), KvRuntimeConfig::paged(4))
                .unwrap();
        let prompt: Vec<i32> = vec![3, 50, 7, 21, 9, 12, 6, 8]; // two exact pages
        m.step_runs(&[DecodeRun { slot: 0, tokens: &prompt, start_pos: 0 }]).unwrap();
        let cold = m.logits().row(0).to_vec();
        m.prefix_insert(0, &prompt).unwrap();
        let split = m.prefix_attach(1, &prompt).unwrap();
        assert_eq!(split, 7, "full match clamps to len − 1");
        m.step_runs(&[DecodeRun { slot: 1, tokens: &prompt[7..], start_pos: 7 }]).unwrap();
        assert_eq!(m.logits().row(0), cold.as_slice());
        assert_eq!(m.kv_metrics().unwrap().cow_copies, 1, "shared-page rewrite must COW once");
        // Both slots now decode identically (the COW copy carried the
        // shared history bit-for-bit).
        m.step(&items(&[(0, 5, 8), (1, 5, 8)])).unwrap();
        assert_eq!(m.logits().row(0), m.logits().row(1));
        // Refcounts balance: with both slots reset, only the tree's two
        // retained pages stay in use.
        m.reset_slot(0).unwrap();
        m.reset_slot(1).unwrap();
        assert_eq!(m.kv_metrics().unwrap().pages_in_use, 2);
    }

    #[test]
    fn chunked_run_bit_identical_to_sequential_steps() {
        // The step_runs bit-identity contract at the model layer: feeding
        // a prompt as one chunk must leave the exact KV state and final
        // logits that token-at-a-time feeding produces — for both KV
        // precisions (they round differently, so each must match its own
        // sequential oracle).
        for kv in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let spec = DecodeSpec::tiny(2, kv);
            let mut seq = LutTransformer::random(spec.clone(), 7, 2, pool1()).unwrap();
            let mut chk = LutTransformer::random(spec, 7, 2, WorkerPool::shared(2)).unwrap();
            let prompt = [3i32, 50, 7, 21, 9];
            for (p, &t) in prompt.iter().enumerate() {
                seq.step(&items(&[(0, t, p)])).unwrap();
            }
            chk.step_runs(&[DecodeRun { slot: 0, tokens: &prompt, start_pos: 0 }]).unwrap();
            assert_eq!(
                seq.logits().row(0),
                chk.logits().row(0),
                "{kv:?}: chunked logits diverged at the prompt's last position"
            );
            // The cached history must be identical too: decode a few
            // tokens from each and compare the streams.
            let mut a = vec![5i32];
            let mut b = vec![5i32];
            for p in prompt.len()..prompt.len() + 4 {
                seq.step(&items(&[(0, a[0], p)])).unwrap();
                chk.step(&items(&[(0, b[0], p)])).unwrap();
                a = vec![crate::coordinator::argmax_logits(seq.logits().row(0))];
                b = vec![crate::coordinator::argmax_logits(chk.logits().row(0))];
                assert_eq!(a, b, "{kv:?}: decode diverged after chunked prefill at pos {p}");
            }
        }
    }

    #[test]
    fn mixed_length_runs_share_one_iteration() {
        // Slot 0 prefills 4 tokens while slot 1 decodes 1 — one forward
        // pass, 5 rows, 2 logits rows. Both must equal their isolated
        // sequential trajectories.
        let spec = DecodeSpec::tiny(2, KvCacheSpec::q8());
        let mut iso0 = LutTransformer::random(spec.clone(), 7, 1, pool1()).unwrap();
        let mut iso1 = LutTransformer::random(spec.clone(), 7, 1, pool1()).unwrap();
        let mut mix = LutTransformer::random(spec, 7, 2, pool1()).unwrap();

        // Warm slot 1 with one token of history everywhere.
        iso1.step(&items(&[(0, 40, 0)])).unwrap();
        mix.step(&items(&[(1, 40, 0)])).unwrap();

        let p0 = [3i32, 9, 12, 6];
        for (p, &t) in p0.iter().enumerate() {
            iso0.step(&items(&[(0, t, p)])).unwrap();
        }
        let want0 = iso0.logits().row(0).to_vec();
        iso1.step(&items(&[(0, 8, 1)])).unwrap();
        let want1 = iso1.logits().row(0).to_vec();

        mix.step_runs(&[
            DecodeRun { slot: 0, tokens: &p0, start_pos: 0 },
            DecodeRun { slot: 1, tokens: &[8], start_pos: 1 },
        ])
        .unwrap();
        assert_eq!(mix.logits().batch(), 2, "one logits row per run");
        assert_eq!(mix.logits().row(0), want0.as_slice(), "prefill run diverged");
        assert_eq!(mix.logits().row(1), want1.as_slice(), "co-scheduled decode row diverged");
        assert_eq!(mix.stats.tokens, 1 + 5, "5 rows this iteration plus the warm-up token");
    }

    #[test]
    fn chunked_prefill_amortizes_lut_builds_exactly() {
        // LUT builds per GEMV call depend only on the weight matrix, not
        // on the batch — so a 16-token prompt fed as one run must build
        // exactly 1/16th the LUTs of sixteen single-token steps, while
        // reading the same per-row LUT traffic.
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let prompt: Vec<i32> = (1..=16).collect();
        let mut seq = LutTransformer::random(spec.clone(), 7, 1, pool1()).unwrap();
        for (p, &t) in prompt.iter().enumerate() {
            seq.step(&items(&[(0, t, p)])).unwrap();
        }
        let mut chk = LutTransformer::random(spec, 7, 1, pool1()).unwrap();
        chk.step_runs(&[DecodeRun { slot: 0, tokens: &prompt, start_pos: 0 }]).unwrap();
        let layer_luts = |m: &LutTransformer| -> u64 {
            m.stats.layers.iter().map(|l| l.total().luts_built).sum()
        };
        assert_eq!(layer_luts(&seq), 16 * layer_luts(&chk), "LUT builds did not amortize 16x");
        assert_eq!(seq.stats.head.luts_built, 16 * chk.stats.head.luts_built);
        // Same LUT *reads* per row in the layers: 16 rows either way.
        let layer_reads = |m: &LutTransformer| -> u64 {
            m.stats.layers.iter().map(|l| l.total().lut_reads).sum()
        };
        assert_eq!(layer_reads(&seq), layer_reads(&chk), "per-row LUT traffic changed");
    }

    #[test]
    fn injected_kv_faults_are_typed_and_heal_on_slot_reset() {
        use crate::runtime::{FaultKind, FaultPlan};
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let pool = WorkerPool::shared(1);
        let mut m = LutTransformer::random(spec.clone(), 7, 2, pool.clone()).unwrap();
        // Fault-free oracle for slot 1's trajectory.
        let mut oracle = LutTransformer::random(spec, 7, 2, pool1()).unwrap();

        // kv_write_fail latches its victim: the first KV write faults and
        // every retry keeps faulting until the slot is reset.
        pool.arm_faults(Arc::new(FaultPlan::new(9).with(FaultKind::KvWriteFail, 1)));
        let err = m.step(&items(&[(0, 3, 0)])).unwrap_err();
        assert!(err.to_string().contains("injected fault: KV write failed"), "{err}");
        assert!(m.step(&items(&[(0, 3, 0)])).is_err(), "victim must stay latched");
        // The *other* slot is untouched by slot 0's latched fault and
        // stays bit-identical to the fault-free model.
        m.step(&items(&[(1, 11, 0)])).unwrap();
        oracle.step(&items(&[(1, 11, 0)])).unwrap();
        assert_eq!(m.logits(), oracle.logits(), "healthy slot diverged under a latched fault");
        // reset_slot clears the latch along with the pane.
        m.reset_slot(0).unwrap();
        m.step(&items(&[(0, 3, 0)])).unwrap();

        // kv_corrupt is one-shot: the corrupted position is caught by the
        // cache's own bounds check (typed), and the retry succeeds.
        pool.arm_faults(Arc::new(FaultPlan::new(9).with(FaultKind::KvCorrupt, 1)));
        let err = m.step(&items(&[(0, 5, 1)])).unwrap_err();
        assert!(err.to_string().contains("outside the"), "{err}");
        m.step(&items(&[(0, 5, 1)])).unwrap();
        pool.disarm_faults();
    }

    #[test]
    fn failed_forward_commits_no_stats_and_retry_counts_once() {
        use crate::runtime::{FaultKind, FaultPlan};
        let spec = DecodeSpec::tiny(2, KvCacheSpec::fp16());
        let pool = WorkerPool::shared(1);
        let mut m = LutTransformer::random(spec.clone(), 7, 1, pool.clone()).unwrap();
        let mut oracle = LutTransformer::random(spec, 7, 1, pool1()).unwrap();

        // kv_corrupt is one-shot and fires on the very first KV write —
        // *after* layer 0's Q/K/V GEMVs already ran. Regression (pre-fix
        // failing): the failed pass committed those partial layer-0
        // counters, so the successful retry was double-counted.
        pool.arm_faults(Arc::new(FaultPlan::new(9).with(FaultKind::KvCorrupt, 1)));
        assert!(m.step(&items(&[(0, 3, 0)])).is_err());
        assert_eq!(m.stats.steps, 0, "a failed forward must not count as a step");
        assert_eq!(m.stats.tokens, 0);
        assert_eq!(m.stats.head, GemvStats::default());
        assert!(
            m.stats.layers.iter().all(|l| *l == LayerGemvStats::default()),
            "a failed forward leaked partial per-layer stats: {:?}",
            m.stats.layers
        );
        // The retry succeeds (one-shot fault) and must count exactly once.
        m.step(&items(&[(0, 3, 0)])).unwrap();
        pool.disarm_faults();
        oracle.step(&items(&[(0, 3, 0)])).unwrap();
        assert_eq!(m.stats, oracle.stats, "retried work must be counted exactly once");
        assert_eq!(m.logits(), oracle.logits(), "retry changed the logits");
    }

    #[test]
    fn healing_pool_faults_leave_stats_equal_to_fault_free() {
        use crate::runtime::{FaultKind, FaultPlan};
        // worker_panic / slow_tile / poison_scratch heal inside the pool
        // dispatch: the forward succeeds, so both the logits and the
        // committed stats must equal the fault-free run (tile reports are
        // delivered exactly once per tile even when its worker died).
        let spec = DecodeSpec::tiny(2, KvCacheSpec::q8());
        let mut oracle = LutTransformer::random(spec.clone(), 7, 1, pool1()).unwrap();
        let pool = WorkerPool::shared(2);
        pool.arm_faults(Arc::new(
            FaultPlan::new(11)
                .with_seeded(FaultKind::WorkerPanic, 3, 0)
                .with_seeded(FaultKind::SlowTile, 4, 0)
                .with_seeded(FaultKind::PoisonScratch, 5, 0),
        ));
        let mut m = LutTransformer::random(spec, 7, 1, pool.clone()).unwrap();
        for (p, t) in [3i32, 50, 7, 21].into_iter().enumerate() {
            m.step(&items(&[(0, t, p)])).unwrap();
            oracle.step(&items(&[(0, t, p)])).unwrap();
            assert_eq!(m.logits(), oracle.logits(), "pos {p} diverged under healing faults");
        }
        pool.disarm_faults();
        assert_eq!(m.stats, oracle.stats, "healed faults skewed the kernel stats");
    }

    #[test]
    fn run_crossing_the_window_is_an_error_not_a_panic() {
        let spec = DecodeSpec::tiny(1, KvCacheSpec::fp16());
        let ctx = spec.max_context;
        let mut m = LutTransformer::random(spec, 7, 1, pool1()).unwrap();
        let long: Vec<i32> = (0..ctx as i32 + 1).collect();
        assert!(
            m.step_runs(&[DecodeRun { slot: 0, tokens: &long, start_pos: 0 }]).is_err(),
            "run longer than the window must be rejected before any KV write"
        );
        assert!(m
            .step_runs(&[DecodeRun { slot: 0, tokens: &[1, 2], start_pos: ctx - 1 }])
            .is_err());
        assert!(m.step_runs(&[DecodeRun { slot: 0, tokens: &[], start_pos: 0 }]).is_err());
        // The model still serves after rejected calls.
        m.step_runs(&[DecodeRun { slot: 0, tokens: &long[..ctx], start_pos: 0 }]).unwrap();
    }
}
