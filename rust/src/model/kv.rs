//! KV-cache sizing, placement, and storage (paper §III-B).
//!
//! SAIL supports quantized (8-bit) and non-quantized (fp16) KV caches; the
//! KV matrices are mapped *column-wise* across C-SRAM arrays (Fig 5) so the
//! per-token `Q × K_cacheᵀ` product streams without rebuilding large LUTs.
//! The GPU baselines' batch capacity is governed by this module's byte
//! accounting, and the serving-path decode model reads and writes its
//! per-slot history through [`KvCache`] — a real store whose element
//! payload is allocated exactly as [`KvCacheSpec::seq_bytes`] accounts it
//! (cross-checked in tests and in `tests/decode_serving.rs`).

use anyhow::{bail, Result};

use super::ModelConfig;

/// KV-cache precision and layout for one serving deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    /// Bits per stored K/V element (16 = fp16, 8 = the paper's extended
    /// llama.cpp 8-bit quantized KV).
    pub bits: u32,
}

impl KvCacheSpec {
    pub fn fp16() -> Self {
        KvCacheSpec { bits: 16 }
    }

    pub fn q8() -> Self {
        KvCacheSpec { bits: 8 }
    }

    /// Bytes for one sequence at `ctx` cached tokens.
    pub fn seq_bytes(&self, m: &ModelConfig, ctx: usize) -> u64 {
        m.kv_bytes_per_token(self.bits) * ctx as u64
    }

    /// Bytes for a batch of sequences at the same context length.
    pub fn batch_bytes(&self, m: &ModelConfig, ctx: usize, batch: usize) -> u64 {
        self.seq_bytes(m, ctx) * batch as u64
    }

    /// Largest batch fitting in `capacity_bytes` alongside the weights —
    /// the constraint that yields Table III's shrinking batch columns and
    /// "X" (does-not-fit) entries.
    pub fn max_batch(
        &self,
        m: &ModelConfig,
        ctx: usize,
        capacity_bytes: u64,
        weight_bytes: u64,
        reserve_bytes: u64,
    ) -> usize {
        let need = weight_bytes + reserve_bytes;
        if need >= capacity_bytes {
            return 0;
        }
        ((capacity_bytes - need) / self.seq_bytes(m, ctx).max(1)) as usize
    }
}

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even (the storage
/// rounding an fp16 KV cache applies to every cached K/V element).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (preserve NaN-ness with a quiet payload bit).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        // Subnormal half (or zero). Values below the smallest subnormal
        // flush to signed zero.
        if e < -10 {
            return sign;
        }
        let man = man | 0x0080_0000; // make the implicit bit explicit
        let shift = (14 - e) as u32;
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let midpoint = 1u32 << (shift - 1);
        let rounded =
            if rem > midpoint || (rem == midpoint && half & 1 == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    // Round to nearest even; a mantissa carry walks into the exponent
    // field, which is exactly right (and yields ±inf at the top).
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE-754 binary16 bits → f32 (exact: every half is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: man × 2⁻²⁴.
        let v = man as f32 / 16_777_216.0;
        return if sign != 0 { -v } else { v };
    }
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Element storage for one side (K or V) of the cache, per
/// [`KvCacheSpec`]: fp16 elements, or int8 codes with one f32 scale per
/// cached vector (the llama.cpp-style 8-bit KV the paper extends).
#[derive(Debug, Clone)]
enum KvStore {
    F16(Vec<u16>),
    Q8 { data: Vec<i8>, scales: Vec<f32> },
}

impl KvStore {
    fn new(spec: KvCacheSpec, elems: usize, vectors: usize) -> Result<KvStore> {
        Ok(match spec.bits {
            16 => KvStore::F16(vec![0; elems]),
            8 => KvStore::Q8 { data: vec![0; elems], scales: vec![1.0; vectors] },
            b => bail!("unsupported KV precision: {b} bits (16 = fp16, 8 = q8)"),
        })
    }

    /// Bytes of element payload — the quantity [`KvCacheSpec::seq_bytes`]
    /// accounts. Q8 per-vector scales are metadata on top (see
    /// [`KvCache::scale_bytes`]).
    fn data_bytes(&self) -> u64 {
        match self {
            KvStore::F16(d) => 2 * d.len() as u64,
            KvStore::Q8 { data, .. } => data.len() as u64,
        }
    }

    /// Store one vector at element offset `base` (vector index
    /// `base / len`), rounding through the storage precision.
    fn write(&mut self, base: usize, src: &[f32]) {
        match self {
            KvStore::F16(d) => {
                for (dst, &x) in d[base..base + src.len()].iter_mut().zip(src) {
                    *dst = f32_to_f16_bits(x);
                }
            }
            KvStore::Q8 { data, scales } => {
                let amax = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if amax == 0.0 { 1.0 } else { amax / 127.0 };
                scales[base / src.len()] = scale;
                for (dst, &x) in data[base..base + src.len()].iter_mut().zip(src) {
                    *dst = (x / scale).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }

    /// Dequantize one vector at element offset `base` into `dst`.
    fn read(&self, base: usize, dst: &mut [f32]) {
        match self {
            KvStore::F16(d) => {
                for (out, &h) in dst.iter_mut().zip(&d[base..base + dst.len()]) {
                    *out = f16_bits_to_f32(h);
                }
            }
            KvStore::Q8 { data, scales } => {
                let scale = scales[base / dst.len()];
                for (out, &q) in dst.iter_mut().zip(&data[base..base + dst.len()]) {
                    *out = q as f32 * scale;
                }
            }
        }
    }

    fn reset_range(&mut self, base: usize, elems: usize, vec_len: usize) {
        match self {
            KvStore::F16(d) => d[base..base + elems].fill(0),
            KvStore::Q8 { data, scales } => {
                data[base..base + elems].fill(0);
                scales[base / vec_len..(base + elems) / vec_len].fill(1.0);
            }
        }
    }
}

/// The slot-indexed KV cache the decode model reads every iteration: per
/// layer and batch slot, `max_context` cached K and V vectors of width
/// `kv_dim` (= kv_heads × head_dim), stored through the precision the
/// [`KvCacheSpec`] names. Element index layout is
/// `((layer · batch + slot) · max_context + pos) · kv_dim + i`, i.e. one
/// contiguous `[max_context, kv_dim]` pane per (layer, slot) — the
/// column-wise streaming unit of Fig 5.
#[derive(Debug, Clone)]
pub struct KvCache {
    spec: KvCacheSpec,
    layers: usize,
    batch: usize,
    max_context: usize,
    kv_dim: usize,
    k: KvStore,
    v: KvStore,
}

impl KvCache {
    pub fn new(
        spec: KvCacheSpec,
        layers: usize,
        batch: usize,
        max_context: usize,
        kv_dim: usize,
    ) -> Result<KvCache> {
        assert!(layers > 0 && batch > 0 && max_context > 0 && kv_dim > 0);
        let vectors = layers * batch * max_context;
        let elems = vectors * kv_dim;
        Ok(KvCache {
            spec,
            layers,
            batch,
            max_context,
            kv_dim,
            k: KvStore::new(spec, elems, vectors)?,
            v: KvStore::new(spec, elems, vectors)?,
        })
    }

    pub fn spec(&self) -> KvCacheSpec {
        self.spec
    }

    pub fn max_context(&self) -> usize {
        self.max_context
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    #[inline]
    fn base(&self, layer: usize, slot: usize, pos: usize) -> usize {
        debug_assert!(layer < self.layers && slot < self.batch);
        ((layer * self.batch + slot) * self.max_context + pos) * self.kv_dim
    }

    /// Cache the K and V vectors of one token. Positions at or beyond
    /// `max_context` are a caller bug (the batcher finishes requests with
    /// `ContextFull` before ever issuing one) — enforced here so an
    /// admission-layer regression cannot silently corrupt a neighbouring
    /// (layer, slot) pane. The violation surfaces as a typed error —
    /// never a panic — which the serving path maps to `EngineFault` for
    /// the offending request alone.
    pub fn write(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        if pos >= self.max_context {
            bail!(
                "KV write at position {pos} outside the {}-token window",
                self.max_context
            );
        }
        if k.len() != self.kv_dim || v.len() != self.kv_dim {
            bail!(
                "KV write payloads ({}, {}) do not match kv_dim {}",
                k.len(),
                v.len(),
                self.kv_dim
            );
        }
        let base = self.base(layer, slot, pos);
        self.k.write(base, k);
        self.v.write(base, v);
        Ok(())
    }

    /// Cache the K and V vectors of a **run** of contiguous positions of
    /// one (layer, slot): row `r` of `k`/`v` (each `count × kv_dim`
    /// elements) lands at position `start_pos + r`. This is the chunked-
    /// prefill write path: one `base()`/bounds computation per run
    /// instead of one per token, bit-identical to `count` single
    /// [`write`](Self::write)s (cross-checked in tests). The same
    /// window-bound contract applies to the whole run — the batcher
    /// raises `ContextFull` before any row could land at `max_context`.
    pub fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        if k.len() != v.len() {
            bail!("K and V runs must cover the same positions ({} vs {})", k.len(), v.len());
        }
        if k.is_empty() || k.len() % self.kv_dim != 0 {
            bail!(
                "run payload {} is not a positive multiple of kv_dim {}",
                k.len(),
                self.kv_dim
            );
        }
        let count = k.len() / self.kv_dim;
        if start_pos + count > self.max_context {
            bail!(
                "KV run at positions {start_pos}..{} outside the {}-token window",
                start_pos + count,
                self.max_context
            );
        }
        let base = self.base(layer, slot, start_pos);
        for r in 0..count {
            let off = base + r * self.kv_dim;
            self.k.write(off, &k[r * self.kv_dim..(r + 1) * self.kv_dim]);
            self.v.write(off, &v[r * self.kv_dim..(r + 1) * self.kv_dim]);
        }
        Ok(())
    }

    /// Read the cached K vector of one position (dequantized to f32).
    pub fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        self.k.read(self.base(layer, slot, pos), dst);
    }

    /// Read the cached V vector of one position (dequantized to f32).
    pub fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        self.v.read(self.base(layer, slot, pos), dst);
    }

    /// Zero one slot's panes across all layers (no KV leakage into the
    /// next admitted request — the batcher invariant).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(slot < self.batch);
        let pane = self.max_context * self.kv_dim;
        for layer in 0..self.layers {
            let base = self.base(layer, slot, 0);
            self.k.reset_range(base, pane, self.kv_dim);
            self.v.reset_range(base, pane, self.kv_dim);
        }
    }

    /// Bytes of element payload actually allocated — by construction equal
    /// to [`KvCacheSpec::batch_bytes`] at `max_context` for the matching
    /// [`ModelConfig`] (pinned by tests): 2 (K and V) × layers × kv_dim ×
    /// max_context × batch elements at `spec.bits` per element.
    pub fn data_bytes(&self) -> u64 {
        self.k.data_bytes() + self.v.data_bytes()
    }

    /// Metadata bytes on top of the element payload (Q8 per-vector f32
    /// scales; zero for fp16). `seq_bytes` deliberately excludes these,
    /// matching the paper's element-payload accounting.
    pub fn scale_bytes(&self) -> u64 {
        match &self.k {
            KvStore::F16(_) => 0,
            KvStore::Q8 { scales, .. } => 2 * 4 * scales.len() as u64,
        }
    }
}

/// Per-token cycles the KV path adds on SAIL: the Q×K_cacheᵀ and
/// attention×V products stream through the same C-SRAM hardware
/// column-wise; profiling in the paper attributes ~5% of end-to-end
/// latency to this path (§III-B), which the pipeline model charges as a
/// multiplicative factor.
pub const KV_PATH_OVERHEAD: f64 = 0.05;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantLevel;

    #[test]
    fn fp16_vs_q8_halving() {
        let m = ModelConfig::llama2_7b();
        let f = KvCacheSpec::fp16().seq_bytes(&m, 4096);
        let q = KvCacheSpec::q8().seq_bytes(&m, 4096);
        assert_eq!(f, 2 * q);
        assert_eq!(f, 2 * 1024 * 1024 * 1024); // 2 GiB
    }

    #[test]
    fn table3_x_entry_reproduced() {
        // 13B-Q8 at ctx 4096 does not fit one V100 (16 GB).
        let m = ModelConfig::llama2_13b();
        let w = m.weight_bytes(QuantLevel::Q8, 32);
        let cap = 16u64 * 1_000_000_000;
        let b = KvCacheSpec::fp16().max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert_eq!(b, 0, "13B-Q8@4K must not fit a single V100");
        // …but fits 2×V100 (32 GB) at batch ≥ 1.
        let b2 = KvCacheSpec::fp16().max_batch(&m, 4096, 2 * cap, w, 1_000_000_000);
        assert!(b2 >= 1, "got {b2}");
    }

    #[test]
    fn f16_roundtrip_known_values() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (0.5, 0x3800),
            (2.0, 0x4000),
            (65504.0, 0x7bff), // largest finite half
            (6.103_515_6e-5, 0x0400), // smallest normal half
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "encoding {x}");
            assert_eq!(f16_bits_to_f32(bits), x, "decoding {x}");
        }
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00, "overflow must saturate to inf");
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Round-to-nearest-even at the 1.0 + ulp/2 midpoint: 1 + 2^-11
        // is exactly halfway between 0x3c00 and 0x3c01 → even (0x3c00).
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
    }

    #[test]
    fn f16_roundtrip_error_bounded() {
        let mut prng = crate::util::Prng::new(21);
        for _ in 0..500 {
            let x = prng.normal() as f32;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            // Relative error of binary16 round-to-nearest: ≤ 2⁻¹¹.
            assert!((x - y).abs() <= x.abs() * 4.9e-4 + 1e-7, "{x} -> {y}");
            // Idempotent: a value already on the f16 grid re-encodes to
            // itself.
            assert_eq!(f32_to_f16_bits(y), f32_to_f16_bits(x));
        }
    }

    #[test]
    fn kv_cache_roundtrip_both_precisions() {
        let mut prng = crate::util::Prng::new(33);
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let mut kv = KvCache::new(spec, 2, 3, 4, 8).unwrap();
            let kvec: Vec<f32> = (0..8).map(|_| prng.normal() as f32).collect();
            let vvec: Vec<f32> = (0..8).map(|_| prng.normal() as f32).collect();
            kv.write(1, 2, 3, &kvec, &vvec).unwrap();
            let mut back = vec![0.0f32; 8];
            kv.read_k(1, 2, 3, &mut back);
            let amax = kvec.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let tol = if spec.bits == 16 { amax * 4.9e-4 + 1e-7 } else { amax / 254.0 + 1e-7 };
            for (a, b) in kvec.iter().zip(&back) {
                assert!((a - b).abs() <= tol, "{spec:?}: {a} vs {b}");
            }
            kv.read_v(1, 2, 3, &mut back);
            for (a, b) in vvec.iter().zip(&back) {
                assert!((a - b).abs() <= tol, "{spec:?}: {a} vs {b}");
            }
            // Neighbouring positions and slots untouched.
            kv.read_k(1, 2, 2, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
            kv.read_k(1, 1, 3, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
            // Slot reset clears only that slot.
            kv.reset_slot(2);
            kv.read_k(1, 2, 3, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn kv_cache_allocation_matches_seq_bytes_accounting() {
        // The cross-check the serving path relies on: the store's element
        // payload is exactly what `KvCacheSpec::seq_bytes` accounts.
        let m = ModelConfig {
            name: "kv-acct".into(),
            hidden: 64,
            layers: 3,
            heads: 8,
            kv_heads: 4,
            ffn: 128,
            vocab: 97,
            max_context: 40,
        };
        let kv_dim = m.kv_heads * m.head_dim();
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            for batch in [1usize, 2, 5] {
                let kv = KvCache::new(spec, m.layers, batch, m.max_context, kv_dim).unwrap();
                assert_eq!(
                    kv.data_bytes(),
                    spec.batch_bytes(&m, m.max_context, batch),
                    "{spec:?} batch {batch}"
                );
            }
        }
        // fp16 carries no scale metadata; q8 carries one f32 per cached
        // vector on top of the accounted payload.
        let f = KvCache::new(KvCacheSpec::fp16(), 2, 1, 8, 16).unwrap();
        assert_eq!(f.scale_bytes(), 0);
        let q = KvCache::new(KvCacheSpec::q8(), 2, 1, 8, 16).unwrap();
        assert_eq!(q.scale_bytes(), 2 * 4 * 2 * 8);
    }

    #[test]
    fn kv_cache_rejects_out_of_window_write() {
        // A typed error, not a panic: the serving path degrades the one
        // offending request instead of taking the process down.
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write(0, 0, 4, &[0.0; 8], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("outside the 4-token window"), "{err}");
        // The cache stays usable and untouched after the rejection.
        kv.write(0, 0, 3, &[1.0; 8], &[1.0; 8]).unwrap();
        let mut back = vec![0.0f32; 8];
        kv.read_k(0, 0, 3, &mut back);
        assert!(back.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn write_run_matches_per_token_writes_bit_for_bit() {
        // The ranged chunked-prefill write must be indistinguishable from
        // the per-token path, for both storage precisions (q8 re-derives
        // one scale per vector — the run must slice vectors identically).
        let mut prng = crate::util::Prng::new(55);
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let (layers, batch, ctx, dim) = (2usize, 3usize, 6usize, 8usize);
            let mut per_token = KvCache::new(spec, layers, batch, ctx, dim).unwrap();
            let mut ranged = KvCache::new(spec, layers, batch, ctx, dim).unwrap();
            let count = 4usize;
            let start = 1usize;
            let kr: Vec<f32> = (0..count * dim).map(|_| prng.normal() as f32).collect();
            let vr: Vec<f32> = (0..count * dim).map(|_| prng.normal() as f32).collect();
            for r in 0..count {
                per_token
                    .write(
                        1,
                        2,
                        start + r,
                        &kr[r * dim..(r + 1) * dim],
                        &vr[r * dim..(r + 1) * dim],
                    )
                    .unwrap();
            }
            ranged.write_run(1, 2, start, &kr, &vr).unwrap();
            // Element payload and accounting are untouched by the write
            // path taken…
            assert_eq!(ranged.data_bytes(), per_token.data_bytes());
            assert_eq!(ranged.scale_bytes(), per_token.scale_bytes());
            // …and every cached vector in the store round-trips
            // identically (positions outside the run stay zero).
            let mut a = vec![0.0f32; dim];
            let mut b = vec![0.0f32; dim];
            for l in 0..layers {
                for s in 0..batch {
                    for p in 0..ctx {
                        per_token.read_k(l, s, p, &mut a);
                        ranged.read_k(l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: K diverged at ({l},{s},{p})");
                        per_token.read_v(l, s, p, &mut a);
                        ranged.read_v(l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: V diverged at ({l},{s},{p})");
                    }
                }
            }
        }
    }

    #[test]
    fn write_run_rejects_runs_crossing_the_window() {
        // Positions 2..5 of a 4-token window: the *run*, not just its
        // first row, must fit — rejected (typed) before any row is
        // written.
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write_run(0, 0, 2, &[1.0; 3 * 8], &[1.0; 3 * 8]).unwrap_err();
        assert!(err.to_string().contains("outside the 4-token window"), "{err}");
        let mut back = vec![0.0f32; 8];
        for p in 0..4 {
            kv.read_k(0, 0, p, &mut back);
            assert!(back.iter().all(|&x| x == 0.0), "row {p} written despite rejection");
        }
    }

    #[test]
    fn write_run_rejects_ragged_payloads() {
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write_run(0, 0, 0, &[0.0; 12], &[0.0; 12]).unwrap_err();
        assert!(err.to_string().contains("not a positive multiple of kv_dim"), "{err}");
        let err = kv.write_run(0, 0, 0, &[0.0; 16], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("must cover the same positions"), "{err}");
    }

    #[test]
    fn unsupported_precision_is_an_error() {
        assert!(KvCache::new(KvCacheSpec { bits: 4 }, 1, 1, 4, 8).is_err());
    }

    #[test]
    fn batch_capacity_shrinks_with_context() {
        let m = ModelConfig::llama2_7b();
        let w = m.weight_bytes(QuantLevel::Q4, 32);
        let cap = 16u64 * 1_000_000_000;
        let spec = KvCacheSpec::fp16();
        let b512 = spec.max_batch(&m, 512, cap, w, 1_000_000_000);
        let b4k = spec.max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert!(b512 > b4k, "{b512} vs {b4k}");
        assert!(b4k >= 1 && b4k <= 8, "7B-Q4@4K on V100: small batch, got {b4k}");
    }
}
