//! KV-cache sizing, placement, and storage (paper §III-B).
//!
//! SAIL supports quantized (8-bit) and non-quantized (fp16) KV caches; the
//! KV matrices are mapped *column-wise* across C-SRAM arrays (Fig 5) so the
//! per-token `Q × K_cacheᵀ` product streams without rebuilding large LUTs.
//! The GPU baselines' batch capacity is governed by this module's byte
//! accounting, and the serving-path decode model reads and writes its
//! per-slot history through a [`KvStore`] — either the contiguous
//! slab-per-slot [`KvCache`] or the [`PagedKvCache`], a shared page pool
//! with per-slot page tables, refcounted copy-on-write sharing, and a
//! typed-exhaustion free list. `SAIL_KV=contiguous|paged:<page_tokens>`
//! selects the store at runtime ([`kv_layout_from_env`]); both are
//! bit-identical through the decode path (pinned in `tests/paged_kv.rs`).

use std::fmt;

use anyhow::{bail, Result};

use super::prefix::RadixPrefixCache;
use super::ModelConfig;

/// Bytes per page-table entry the paged store spends per mapped page
/// (`u32` page id), counted by [`KvCacheSpec::paged_seq_bytes`] so
/// capacity math covers the metadata the contiguous slab does not have.
pub const PAGE_TABLE_ENTRY_BYTES: u64 = 4;

/// Typed accounting failure from [`KvCacheSpec::slots_for`]: the spec is
/// degenerate (a sequence accounts to zero bytes), so "how many sequences
/// fit" has no meaningful answer. The old `max_batch` divisor silently
/// clamped this to 1 byte/sequence and returned garbage capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvAccountingError {
    /// `seq_bytes(m, ctx) == 0`: zero context length or a model whose KV
    /// geometry collapses to zero bytes per token.
    DegenerateSpec { ctx: usize },
}

impl fmt::Display for KvAccountingError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvAccountingError::DegenerateSpec { ctx } => write!(
                f,
                "degenerate KV spec: a sequence at ctx {ctx} accounts to 0 bytes \
                 (zero context or zero kv geometry)"
            ),
        }
    }
}

impl std::error::Error for KvAccountingError {}

/// Typed allocation failure from the paged store: every page in the pool
/// is referenced (by slot tables and/or the prefix tree). The backend
/// reacts by evicting prefix-tree leaves and retrying
/// ([`KvBackend::write_run`]); if nothing is evictable the error
/// propagates and the batcher finishes the one offending request
/// `EngineFault`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagePoolExhausted {
    pub pool_pages: usize,
}

impl fmt::Display for PagePoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "KV page pool exhausted: all {} pages referenced", self.pool_pages)
    }
}

impl std::error::Error for PagePoolExhausted {}

/// KV-cache precision and layout for one serving deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    /// Bits per stored K/V element (16 = fp16, 8 = the paper's extended
    /// llama.cpp 8-bit quantized KV).
    pub bits: u32,
}

impl KvCacheSpec {
    pub fn fp16() -> Self {
        KvCacheSpec { bits: 16 }
    }

    pub fn q8() -> Self {
        KvCacheSpec { bits: 8 }
    }

    /// Bytes for one sequence at `ctx` cached tokens.
    pub fn seq_bytes(&self, m: &ModelConfig, ctx: usize) -> u64 {
        m.kv_bytes_per_token(self.bits) * ctx as u64
    }

    /// Bytes for a batch of sequences at the same context length.
    pub fn batch_bytes(&self, m: &ModelConfig, ctx: usize, batch: usize) -> u64 {
        self.seq_bytes(m, ctx) * batch as u64
    }

    /// Element-payload bytes of one KV page holding `page_tokens` tokens
    /// (all layers, K and V) — the allocation granule of the paged store.
    pub fn page_bytes(&self, m: &ModelConfig, page_tokens: usize) -> u64 {
        m.kv_bytes_per_token(self.bits) * page_tokens as u64
    }

    /// Worst-case bytes for one sequence under the paged store: whole
    /// pages (the last page is allocated in full even when partially
    /// occupied) **plus** the page-table entries mapping them. The
    /// contiguous [`seq_bytes`](Self::seq_bytes) has neither rounding nor
    /// table overhead, so `paged_seq_bytes ≥ seq_bytes` always.
    pub fn paged_seq_bytes(&self, m: &ModelConfig, ctx: usize, page_tokens: usize) -> u64 {
        let pages = ctx.div_ceil(page_tokens.max(1)) as u64;
        pages * self.page_bytes(m, page_tokens) + pages * PAGE_TABLE_ENTRY_BYTES
    }

    /// How many sequences fit in `capacity_bytes` alongside the weights
    /// and a reserve — the typed replacement for the old `max_batch`
    /// arithmetic. A degenerate spec (zero bytes per sequence) is a
    /// [`KvAccountingError`] instead of a silently clamped divisor; an
    /// over-committed capacity (`weights + reserve ≥ capacity`) is a
    /// legitimate answer of 0.
    pub fn slots_for(
        &self,
        m: &ModelConfig,
        ctx: usize,
        capacity_bytes: u64,
        weight_bytes: u64,
        reserve_bytes: u64,
    ) -> Result<usize, KvAccountingError> {
        let per_seq = self.seq_bytes(m, ctx);
        if per_seq == 0 {
            return Err(KvAccountingError::DegenerateSpec { ctx });
        }
        let need = weight_bytes + reserve_bytes;
        if need >= capacity_bytes {
            return Ok(0);
        }
        Ok(((capacity_bytes - need) / per_seq) as usize)
    }

    /// [`slots_for`](Self::slots_for) for the paged store: per-sequence
    /// cost is [`paged_seq_bytes`](Self::paged_seq_bytes) (whole pages +
    /// page-table entries) and `radix_bytes` of prefix-tree node overhead
    /// is charged against the capacity up front — capacity math stays
    /// honest about the metadata the slab-per-slot layout never had.
    pub fn slots_for_paged(
        &self,
        m: &ModelConfig,
        ctx: usize,
        page_tokens: usize,
        capacity_bytes: u64,
        weight_bytes: u64,
        reserve_bytes: u64,
        radix_bytes: u64,
    ) -> Result<usize, KvAccountingError> {
        let per_seq = self.paged_seq_bytes(m, ctx, page_tokens);
        if per_seq == 0 {
            return Err(KvAccountingError::DegenerateSpec { ctx });
        }
        let need = weight_bytes + reserve_bytes + radix_bytes;
        if need >= capacity_bytes {
            return Ok(0);
        }
        Ok(((capacity_bytes - need) / per_seq) as usize)
    }

    /// Largest batch fitting in `capacity_bytes` alongside the weights —
    /// the constraint that yields Table III's shrinking batch columns and
    /// "X" (does-not-fit) entries. Thin wrapper over
    /// [`slots_for`](Self::slots_for); a degenerate spec is a programmer
    /// error here (the typed API is for validating external specs) and
    /// panics loudly instead of returning garbage capacity.
    pub fn max_batch(
        &self,
        m: &ModelConfig,
        ctx: usize,
        capacity_bytes: u64,
        weight_bytes: u64,
        reserve_bytes: u64,
    ) -> usize {
        self.slots_for(m, ctx, capacity_bytes, weight_bytes, reserve_bytes)
            .expect("degenerate KvCacheSpec (zero seq_bytes); validate with slots_for")
    }
}

/// Which KV store a deployment runs: the PR-3 contiguous slab (one
/// `[max_context, kv_dim]` pane per layer/slot) or the paged pool with
/// `page_tokens` tokens per page. Selected at runtime by `SAIL_KV`
/// (see [`parse_kv_layout`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    Contiguous,
    Paged { page_tokens: usize },
}

impl fmt::Display for KvLayout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvLayout::Contiguous => write!(f, "contiguous"),
            KvLayout::Paged { page_tokens } => write!(f, "paged:{page_tokens}"),
        }
    }
}

/// Strict `SAIL_KV` grammar: `contiguous`, or `paged:<page_tokens>` with
/// `page_tokens ≥ 1`. Anything else is an error (the env reader warns and
/// falls back; explicit config paths propagate it typed).
pub fn parse_kv_layout(v: &str) -> Result<KvLayout, String> {
    let t = v.trim();
    if t == "contiguous" {
        return Ok(KvLayout::Contiguous);
    }
    if let Some(n) = t.strip_prefix("paged:") {
        return match n.trim().parse::<usize>() {
            Ok(p) if p >= 1 => Ok(KvLayout::Paged { page_tokens: p }),
            _ => Err(format!("invalid page size {n:?} (want paged:<tokens ≥ 1>)")),
        };
    }
    Err(format!("invalid KV layout {t:?} (want contiguous or paged:<page_tokens>)"))
}

/// Lenient `SAIL_KV` reader for default-construction paths: unset or
/// empty → `None` (caller picks its default), malformed → warn on stderr
/// and `None` — the decode path keeps serving rather than dying on a
/// typo'd env var. Strict validation lives in [`parse_kv_layout`] and
/// the manifest loader.
pub fn kv_layout_from_env() -> Option<KvLayout> {
    let v = std::env::var("SAIL_KV").ok()?;
    if v.trim().is_empty() {
        return None;
    }
    match parse_kv_layout(&v) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("SAIL_KV: {e}; using the contiguous store");
            None
        }
    }
}

/// Runtime KV configuration a transformer is built with: the store
/// layout, whether the radix-tree prefix cache rides on the paged store,
/// and the shared-page budget (pool pages beyond the per-slot worst
/// case; also the prefix tree's retention cap — see [`KvBackend::build`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvRuntimeConfig {
    pub layout: KvLayout,
    /// Enable the radix-tree prefix cache (paged layout only; ignored —
    /// there is nothing to share — on the contiguous slab).
    pub prefix_cache: bool,
    /// Extra pool pages housing shared prefixes, and the prefix tree's
    /// page-retention budget. `None` → one slot's worth
    /// (`ceil(max_context / page_tokens)`).
    pub pages_budget: Option<usize>,
}

impl Default for KvRuntimeConfig {
    fn default() -> Self {
        KvRuntimeConfig { layout: KvLayout::Contiguous, prefix_cache: true, pages_budget: None }
    }
}

impl KvRuntimeConfig {
    /// `SAIL_KV`-selected layout with default prefix-cache settings.
    pub fn from_env() -> Self {
        KvRuntimeConfig {
            layout: kv_layout_from_env().unwrap_or(KvLayout::Contiguous),
            ..Default::default()
        }
    }

    pub fn contiguous() -> Self {
        KvRuntimeConfig::default()
    }

    pub fn paged(page_tokens: usize) -> Self {
        KvRuntimeConfig { layout: KvLayout::Paged { page_tokens }, ..Default::default() }
    }
}

/// Paged-store observability snapshot, surfaced through
/// `DecodeEngine::kv_metrics` into `ServingMetrics` and the benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvMetrics {
    pub page_tokens: usize,
    /// Physical pages in the pool (slot worst case + shared budget).
    pub pool_pages: usize,
    /// Pages currently referenced by any slot table or the prefix tree.
    pub pages_in_use: usize,
    /// High-water mark of *distinct* pages referenced by slot tables —
    /// the "resident KV" to compare against the contiguous worst case.
    pub peak_slot_resident_pages: usize,
    /// What the contiguous slab would always hold resident:
    /// `batch × ceil(max_context / page_tokens)` pages.
    pub contiguous_worst_case_pages: usize,
    pub cow_copies: u64,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_insertions: u64,
    pub prefix_evictions: u64,
    /// Pages currently retained by the prefix tree (≤ its budget).
    pub prefix_pages_held: usize,
    /// Distinct NUMA nodes the page frames are interleaved across
    /// (1 when placement is off/single-node).
    pub numa_nodes: usize,
}

impl KvMetrics {
    /// Fraction of prefix lookups that attached at least one shared page.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }
}

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even (the storage
/// rounding an fp16 KV cache applies to every cached K/V element).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (preserve NaN-ness with a quiet payload bit).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        // Subnormal half (or zero). Values below the smallest subnormal
        // flush to signed zero.
        if e < -10 {
            return sign;
        }
        let man = man | 0x0080_0000; // make the implicit bit explicit
        let shift = (14 - e) as u32;
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let midpoint = 1u32 << (shift - 1);
        let rounded =
            if rem > midpoint || (rem == midpoint && half & 1 == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    // Round to nearest even; a mantissa carry walks into the exponent
    // field, which is exactly right (and yields ±inf at the top).
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE-754 binary16 bits → f32 (exact: every half is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: man × 2⁻²⁴.
        let v = man as f32 / 16_777_216.0;
        return if sign != 0 { -v } else { v };
    }
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Element storage for one side (K or V) of a cache, per
/// [`KvCacheSpec`]: fp16 elements, or int8 codes with one f32 scale per
/// cached vector (the llama.cpp-style 8-bit KV the paper extends). Both
/// the contiguous slab and the paged pool allocate their payload through
/// this enum, so precision behaviour is identical by construction.
/// `PartialEq` is part of the contract surface: the speculative-decode
/// rollback tests compare whole stores bit-for-bit against a never-
/// drafted twin.
#[derive(Debug, Clone, PartialEq)]
enum KvPayload {
    F16(Vec<u16>),
    Q8 { data: Vec<i8>, scales: Vec<f32> },
}

impl KvPayload {
    fn new(spec: KvCacheSpec, elems: usize, vectors: usize) -> Result<KvPayload> {
        Ok(match spec.bits {
            16 => KvPayload::F16(vec![0; elems]),
            8 => KvPayload::Q8 { data: vec![0; elems], scales: vec![1.0; vectors] },
            b => bail!("unsupported KV precision: {b} bits (16 = fp16, 8 = q8)"),
        })
    }

    /// Bytes of element payload — the quantity [`KvCacheSpec::seq_bytes`]
    /// accounts. Q8 per-vector scales are metadata on top (see
    /// [`KvCache::scale_bytes`]).
    fn data_bytes(&self) -> u64 {
        match self {
            KvPayload::F16(d) => 2 * d.len() as u64,
            KvPayload::Q8 { data, .. } => data.len() as u64,
        }
    }

    fn scale_bytes(&self) -> u64 {
        match self {
            KvPayload::F16(_) => 0,
            KvPayload::Q8 { scales, .. } => 4 * scales.len() as u64,
        }
    }

    /// Store one vector at element offset `base` (vector index
    /// `base / len`), rounding through the storage precision.
    fn write(&mut self, base: usize, src: &[f32]) {
        match self {
            KvPayload::F16(d) => {
                for (dst, &x) in d[base..base + src.len()].iter_mut().zip(src) {
                    *dst = f32_to_f16_bits(x);
                }
            }
            KvPayload::Q8 { data, scales } => {
                let amax = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if amax == 0.0 { 1.0 } else { amax / 127.0 };
                scales[base / src.len()] = scale;
                for (dst, &x) in data[base..base + src.len()].iter_mut().zip(src) {
                    *dst = (x / scale).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }

    /// Dequantize one vector at element offset `base` into `dst`.
    fn read(&self, base: usize, dst: &mut [f32]) {
        match self {
            KvPayload::F16(d) => {
                for (out, &h) in dst.iter_mut().zip(&d[base..base + dst.len()]) {
                    *out = f16_bits_to_f32(h);
                }
            }
            KvPayload::Q8 { data, scales } => {
                let scale = scales[base / dst.len()];
                for (out, &q) in dst.iter_mut().zip(&data[base..base + dst.len()]) {
                    *out = q as f32 * scale;
                }
            }
        }
    }

    fn reset_range(&mut self, base: usize, elems: usize, vec_len: usize) {
        match self {
            KvPayload::F16(d) => d[base..base + elems].fill(0),
            KvPayload::Q8 { data, scales } => {
                data[base..base + elems].fill(0);
                scales[base / vec_len..(base + elems) / vec_len].fill(1.0);
            }
        }
    }

    /// Bit-exact copy of `elems` elements (and their Q8 scales) from
    /// `src_base` to `dst_base` — the COW page copy. Both bases and
    /// `elems` must be `vec_len`-aligned so scales map one-to-one.
    fn copy_region(&mut self, src_base: usize, dst_base: usize, elems: usize, vec_len: usize) {
        debug_assert!(src_base % vec_len == 0 && dst_base % vec_len == 0 && elems % vec_len == 0);
        match self {
            KvPayload::F16(d) => d.copy_within(src_base..src_base + elems, dst_base),
            KvPayload::Q8 { data, scales } => {
                data.copy_within(src_base..src_base + elems, dst_base);
                scales.copy_within(
                    src_base / vec_len..(src_base + elems) / vec_len,
                    dst_base / vec_len,
                );
            }
        }
    }
}

/// The storage contract both KV stores implement — what the decode path
/// needs and nothing more.
///
/// # Invariants (shared by both implementations)
///
/// - **Validation precedes mutation.** A rejected write (`Err`) leaves
///   every *other* referent's visible state bit-identical to before the
///   call: window and payload-shape checks run before any element,
///   scale, refcount, or page-table mutation. The serving path relies on
///   this to degrade exactly one request on a fault.
/// - **Ranged ≡ per-token.** `write_run` of `n` rows is bit-identical to
///   `n` single-position writes (Q8 re-derives one scale per vector
///   either way).
/// - **Unwritten reads are zero.** Reading a position never written (or
///   reset) yields zeros — both stores present the same fresh state.
/// - **Reset isolates slots.** `reset_slot` erases exactly one slot's
///   visible history; no other slot's reads change.
pub trait KvStore {
    fn spec(&self) -> KvCacheSpec;
    fn max_context(&self) -> usize;
    fn kv_dim(&self) -> usize;
    /// Cache K and V vectors for a run of contiguous positions: row `r`
    /// of `k`/`v` (each `kv_dim` elements) lands at `start_pos + r`.
    fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()>;
    /// Read the cached K vector of one position (dequantized to f32).
    fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]);
    /// Read the cached V vector of one position (dequantized to f32).
    fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]);
    /// Erase one slot's visible history (no KV leakage into the next
    /// admitted request — the batcher invariant).
    fn reset_slot(&mut self, slot: usize);
    /// Roll back one slot's history tail: positions `keep .. written`
    /// (previously written by this slot) return to the never-written
    /// state, positions `< keep` stay untouched. This is the speculative-
    /// decode rejection path — after a verify forward wrote `written`
    /// positions and only `keep` of them were accepted, the store must be
    /// indistinguishable from one that never saw the rejected tail
    /// (pinned in `tests/speculative_decode.rs`, including free-list
    /// order on the paged store). `keep > written` or a tail outside the
    /// window is a typed error; `keep == written` is a no-op.
    fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()>;
    /// Bytes of element payload allocated.
    fn data_bytes(&self) -> u64;
    /// Metadata bytes on top of the element payload (Q8 scales).
    fn scale_bytes(&self) -> u64;
}

/// The slot-indexed contiguous KV cache: per layer and batch slot,
/// `max_context` cached K and V vectors of width `kv_dim`
/// (= kv_heads × head_dim), stored through the precision the
/// [`KvCacheSpec`] names. Element index layout is
/// `((layer · batch + slot) · max_context + pos) · kv_dim + i`, i.e. one
/// contiguous `[max_context, kv_dim]` pane per (layer, slot) — the
/// column-wise streaming unit of Fig 5. Memory scales with the worst
/// case (`batch × max_context`) regardless of occupancy; the
/// [`PagedKvCache`] is the usage-proportional alternative. Two caches
/// compare equal (`PartialEq`) iff every stored element and Q8 scale is
/// bit-identical — the rollback tests' equality oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct KvCache {
    spec: KvCacheSpec,
    layers: usize,
    batch: usize,
    max_context: usize,
    kv_dim: usize,
    k: KvPayload,
    v: KvPayload,
}

impl KvCache {
    pub fn new(
        spec: KvCacheSpec,
        layers: usize,
        batch: usize,
        max_context: usize,
        kv_dim: usize,
    ) -> Result<KvCache> {
        assert!(layers > 0 && batch > 0 && max_context > 0 && kv_dim > 0);
        let vectors = layers * batch * max_context;
        let elems = vectors * kv_dim;
        Ok(KvCache {
            spec,
            layers,
            batch,
            max_context,
            kv_dim,
            k: KvPayload::new(spec, elems, vectors)?,
            v: KvPayload::new(spec, elems, vectors)?,
        })
    }

    pub fn spec(&self) -> KvCacheSpec {
        self.spec
    }

    pub fn max_context(&self) -> usize {
        self.max_context
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    #[inline]
    fn base(&self, layer: usize, slot: usize, pos: usize) -> usize {
        debug_assert!(layer < self.layers && slot < self.batch);
        ((layer * self.batch + slot) * self.max_context + pos) * self.kv_dim
    }

    /// Cache the K and V vectors of one token. Positions at or beyond
    /// `max_context` are a caller bug (the batcher finishes requests with
    /// `ContextFull` before ever issuing one) — enforced here so an
    /// admission-layer regression cannot silently corrupt a neighbouring
    /// (layer, slot) pane. The violation surfaces as a typed error —
    /// never a panic — which the serving path maps to `EngineFault` for
    /// the offending request alone.
    pub fn write(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        if pos >= self.max_context {
            bail!(
                "KV write at position {pos} outside the {}-token window",
                self.max_context
            );
        }
        if k.len() != self.kv_dim || v.len() != self.kv_dim {
            bail!(
                "KV write payloads ({}, {}) do not match kv_dim {}",
                k.len(),
                v.len(),
                self.kv_dim
            );
        }
        let base = self.base(layer, slot, pos);
        self.k.write(base, k);
        self.v.write(base, v);
        Ok(())
    }

    /// Cache the K and V vectors of a **run** of contiguous positions of
    /// one (layer, slot): row `r` of `k`/`v` (each `count × kv_dim`
    /// elements) lands at position `start_pos + r`. This is the chunked-
    /// prefill write path: one `base()`/bounds computation per run
    /// instead of one per token, bit-identical to `count` single
    /// [`write`](Self::write)s (cross-checked in tests). The same
    /// window-bound contract applies to the whole run — the batcher
    /// raises `ContextFull` before any row could land at `max_context`.
    pub fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        validate_run_shape(k, v, self.kv_dim)?;
        let count = k.len() / self.kv_dim;
        if start_pos + count > self.max_context {
            bail!(
                "KV run at positions {start_pos}..{} outside the {}-token window",
                start_pos + count,
                self.max_context
            );
        }
        let base = self.base(layer, slot, start_pos);
        for r in 0..count {
            let off = base + r * self.kv_dim;
            self.k.write(off, &k[r * self.kv_dim..(r + 1) * self.kv_dim]);
            self.v.write(off, &v[r * self.kv_dim..(r + 1) * self.kv_dim]);
        }
        Ok(())
    }

    /// Read the cached K vector of one position (dequantized to f32).
    pub fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        self.k.read(self.base(layer, slot, pos), dst);
    }

    /// Read the cached V vector of one position (dequantized to f32).
    pub fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        self.v.read(self.base(layer, slot, pos), dst);
    }

    /// Zero one slot's panes across all layers (no KV leakage into the
    /// next admitted request — the batcher invariant).
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(slot < self.batch);
        let pane = self.max_context * self.kv_dim;
        for layer in 0..self.layers {
            let base = self.base(layer, slot, 0);
            self.k.reset_range(base, pane, self.kv_dim);
            self.v.reset_range(base, pane, self.kv_dim);
        }
    }

    /// Roll back positions `keep .. written` of one slot to the
    /// never-written state (zero elements; Q8 scales back to their fresh
    /// 1.0), leaving positions `< keep` untouched. On the slab "written"
    /// carries no allocation state, so the rolled-back pane is literally
    /// bit-identical to one that never saw the rejected tail.
    pub fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("truncate of slot {slot} outside batch {}", self.batch);
        }
        if keep > written || written > self.max_context {
            bail!(
                "invalid truncate range keep {keep} .. written {written} \
                 (window {})",
                self.max_context
            );
        }
        if keep == written {
            return Ok(());
        }
        let elems = (written - keep) * self.kv_dim;
        for layer in 0..self.layers {
            let base = self.base(layer, slot, keep);
            self.k.reset_range(base, elems, self.kv_dim);
            self.v.reset_range(base, elems, self.kv_dim);
        }
        Ok(())
    }

    /// Bytes of element payload actually allocated — by construction equal
    /// to [`KvCacheSpec::batch_bytes`] at `max_context` for the matching
    /// [`ModelConfig`] (pinned by tests): 2 (K and V) × layers × kv_dim ×
    /// max_context × batch elements at `spec.bits` per element.
    pub fn data_bytes(&self) -> u64 {
        self.k.data_bytes() + self.v.data_bytes()
    }

    /// Metadata bytes on top of the element payload (Q8 per-vector f32
    /// scales; zero for fp16). `seq_bytes` deliberately excludes these,
    /// matching the paper's element-payload accounting.
    pub fn scale_bytes(&self) -> u64 {
        self.k.scale_bytes() + self.v.scale_bytes()
    }
}

impl KvStore for KvCache {
    fn spec(&self) -> KvCacheSpec {
        KvCache::spec(self)
    }
    fn max_context(&self) -> usize {
        KvCache::max_context(self)
    }
    fn kv_dim(&self) -> usize {
        KvCache::kv_dim(self)
    }
    fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        KvCache::write_run(self, layer, slot, start_pos, k, v)
    }
    fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        KvCache::read_k(self, layer, slot, pos, dst)
    }
    fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        KvCache::read_v(self, layer, slot, pos, dst)
    }
    fn reset_slot(&mut self, slot: usize) {
        KvCache::reset_slot(self, slot)
    }
    fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        KvCache::truncate_slot(self, slot, keep, written)
    }
    fn data_bytes(&self) -> u64 {
        KvCache::data_bytes(self)
    }
    fn scale_bytes(&self) -> u64 {
        KvCache::scale_bytes(self)
    }
}

fn validate_run_shape(k: &[f32], v: &[f32], kv_dim: usize) -> Result<()> {
    if k.len() != v.len() {
        bail!("K and V runs must cover the same positions ({} vs {})", k.len(), v.len());
    }
    if k.is_empty() || k.len() % kv_dim != 0 {
        bail!("run payload {} is not a positive multiple of kv_dim {}", k.len(), kv_dim);
    }
    Ok(())
}

/// The paged KV store: a shared pool of fixed-size pages (each holding
/// `page_tokens` token positions across **all** layers, K and V), a free
/// list, per-page refcounts, and one page table per batch slot mapping
/// `pos / page_tokens → page id`. Memory held resident scales with
/// tokens actually cached, not `batch × max_context`; identical prompt
/// prefixes share pages read-only (refcount > 1) and are copied on first
/// write (copy-on-write), so sharing is invisible to the decode math.
///
/// Element index layout within the pool is
/// `((page · layers + layer) · page_tokens + pos % page_tokens) · kv_dim + i`
/// — one page is one contiguous region, which keeps the COW copy a pair
/// of `copy_within`s and lets page frames be interleaved across NUMA
/// nodes as whole units.
///
/// # Refcounting invariants
///
/// - A page's refcount is exactly the number of slot-table entries
///   mapping it plus the number of prefix-tree nodes retaining it.
/// - `refcount == 0 ⇔` the page is on the free list; allocation zeroes
///   the page so reuse is indistinguishable from fresh state.
/// - A write to a page with `refcount > 1` copies the page first (the
///   writer gets a private copy; every other referent keeps the original
///   bits). The copy covers all layers, K, V, and Q8 scales.
/// - A failed write (window/shape validation, pool exhaustion mid-COW)
///   never leaves a half-copied page visible: validation runs first, and
///   a COW copy is published into the table only after it completed.
#[derive(Debug, Clone)]
pub struct PagedKvCache {
    spec: KvCacheSpec,
    layers: usize,
    batch: usize,
    max_context: usize,
    kv_dim: usize,
    page_tokens: usize,
    pages_per_slot: usize,
    pool_pages: usize,
    k: KvPayload,
    v: KvPayload,
    refcount: Vec<u32>,
    /// Per-page count of *slot-table* references only (tree refs
    /// excluded) — feeds the resident-vs-worst-case metric.
    slot_refs: Vec<u32>,
    free: Vec<u32>,
    tables: Vec<Vec<u32>>,
    slot_resident: usize,
    peak_slot_resident: usize,
    cow_copies: u64,
    /// Deterministic page-frame → NUMA-node interleave map (observability
    /// + first-touch guidance; identity 0s when placement is off).
    page_nodes: Vec<usize>,
}

impl PagedKvCache {
    /// Build a pool of `batch × ceil(max_context/page_tokens) +
    /// extra_pages` pages. The first term is the worst case — every slot
    /// simultaneously at full context with nothing shared — so slot
    /// allocation cannot starve as long as prefix-tree retention stays
    /// within `extra_pages` (the tree's budget; see [`KvBackend::build`]).
    pub fn new(
        spec: KvCacheSpec,
        layers: usize,
        batch: usize,
        max_context: usize,
        kv_dim: usize,
        page_tokens: usize,
        extra_pages: usize,
    ) -> Result<PagedKvCache> {
        assert!(layers > 0 && batch > 0 && max_context > 0 && kv_dim > 0);
        if page_tokens == 0 {
            bail!("paged KV page_tokens must be ≥ 1");
        }
        let pages_per_slot = max_context.div_ceil(page_tokens);
        let pool_pages = batch * pages_per_slot + extra_pages;
        let vectors = pool_pages * layers * page_tokens;
        let elems = vectors * kv_dim;
        Ok(PagedKvCache {
            spec,
            layers,
            batch,
            max_context,
            kv_dim,
            page_tokens,
            pages_per_slot,
            pool_pages,
            k: KvPayload::new(spec, elems, vectors)?,
            v: KvPayload::new(spec, elems, vectors)?,
            refcount: vec![0; pool_pages],
            slot_refs: vec![0; pool_pages],
            // Reverse so pop() hands out page 0, 1, 2, … — allocation
            // order is deterministic and readable in tests.
            free: (0..pool_pages as u32).rev().collect(),
            tables: vec![Vec::new(); batch],
            slot_resident: 0,
            peak_slot_resident: 0,
            cow_copies: 0,
            page_nodes: vec![0; pool_pages],
        })
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn pages_per_slot(&self) -> usize {
        self.pages_per_slot
    }

    pub fn pool_pages(&self) -> usize {
        self.pool_pages
    }

    pub fn pages_in_use(&self) -> usize {
        self.pool_pages - self.free.len()
    }

    pub fn peak_slot_resident_pages(&self) -> usize {
        self.peak_slot_resident
    }

    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Current refcount of one page (tests and invariant checks).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// One slot's page table (page ids in position order).
    pub fn table(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// The free list, in pop order from the **back** (tests and invariant
    /// checks — the rollback tests assert a truncated slot restores the
    /// free list exactly, not just its length).
    pub fn free_pages(&self) -> &[u32] {
        &self.free
    }

    /// Actual page-table bytes currently mapped (the worst case is
    /// budgeted by [`KvCacheSpec::paged_seq_bytes`]).
    pub fn table_bytes(&self) -> u64 {
        self.tables.iter().map(|t| t.len() as u64 * PAGE_TABLE_ENTRY_BYTES).sum()
    }

    /// Install the deterministic page-frame → NUMA-node interleave map
    /// (from `Placement::interleave_pages`). Observability + first-touch
    /// guidance; does not move already-allocated memory.
    pub fn set_numa_interleave(&mut self, nodes: Vec<usize>) {
        assert_eq!(nodes.len(), self.pool_pages);
        self.page_nodes = nodes;
    }

    /// NUMA node assigned to one page frame.
    pub fn page_node(&self, page: u32) -> usize {
        self.page_nodes[page as usize]
    }

    /// Distinct NUMA nodes the pool is interleaved across.
    pub fn numa_nodes(&self) -> usize {
        let mut nodes: Vec<usize> = self.page_nodes.clone();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.len()
    }

    #[inline]
    fn page_base(&self, page: u32, layer: usize, off: usize) -> usize {
        debug_assert!(layer < self.layers && off < self.page_tokens);
        ((page as usize * self.layers + layer) * self.page_tokens + off) * self.kv_dim
    }

    fn page_elems(&self) -> usize {
        self.layers * self.page_tokens * self.kv_dim
    }

    /// Pop a free page, zeroed to fresh state, refcount 1.
    fn alloc_page(&mut self) -> Result<u32> {
        let Some(p) = self.free.pop() else {
            return Err(PagePoolExhausted { pool_pages: self.pool_pages }.into());
        };
        let elems = self.page_elems();
        let base = p as usize * elems;
        self.k.reset_range(base, elems, self.kv_dim);
        self.v.reset_range(base, elems, self.kv_dim);
        self.refcount[p as usize] = 1;
        Ok(p)
    }

    fn add_slot_ref(&mut self, page: u32) {
        self.slot_refs[page as usize] += 1;
        if self.slot_refs[page as usize] == 1 {
            self.slot_resident += 1;
            self.peak_slot_resident = self.peak_slot_resident.max(self.slot_resident);
        }
    }

    fn drop_slot_ref(&mut self, page: u32) {
        self.slot_refs[page as usize] -= 1;
        if self.slot_refs[page as usize] == 0 {
            self.slot_resident -= 1;
        }
    }

    /// Drop one reference; a page reaching refcount 0 returns to the
    /// free list (its content is dead — allocation re-zeroes).
    pub(crate) fn release(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "release of unreferenced page {page}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    /// Add one reference (prefix-tree retention).
    pub(crate) fn retain(&mut self, page: u32) {
        debug_assert!(self.refcount[page as usize] > 0, "retain of free page {page}");
        self.refcount[page as usize] += 1;
    }

    /// Map already-populated shared pages read-only into an empty slot's
    /// table (prefix attach): refcount bump per page, zero copies.
    /// Writes into these pages COW.
    pub(crate) fn map_shared(&mut self, slot: usize, pages: &[u32]) {
        assert!(self.tables[slot].is_empty(), "map_shared on a non-empty slot table");
        for &p in pages {
            debug_assert!(self.refcount[p as usize] > 0);
            self.refcount[p as usize] += 1;
            self.add_slot_ref(p);
            self.tables[slot].push(p);
        }
    }

    /// Make positions `start_pos .. start_pos + count` of `slot`
    /// privately writable: validate the window, extend the table with
    /// fresh zeroed pages, and COW any shared page in range. On `Err`
    /// (window violation or pool exhaustion) no *other* referent's
    /// visible state changed; pages already allocated for this slot stay
    /// mapped and are reused when the write is retried.
    fn ensure_writable(&mut self, slot: usize, start_pos: usize, count: usize) -> Result<()> {
        if start_pos + count > self.max_context {
            bail!(
                "KV run at positions {start_pos}..{} outside the {}-token window",
                start_pos + count,
                self.max_context
            );
        }
        let first = start_pos / self.page_tokens;
        let last = (start_pos + count - 1) / self.page_tokens;
        while self.tables[slot].len() <= last {
            let p = self.alloc_page()?;
            self.add_slot_ref(p);
            self.tables[slot].push(p);
        }
        for pi in first..=last {
            let old = self.tables[slot][pi];
            if self.refcount[old as usize] > 1 {
                // Shared → copy-on-write: private copy first, published
                // into the table only once the copy completed.
                let fresh = self.alloc_page()?;
                let elems = self.page_elems();
                self.k.copy_region(old as usize * elems, fresh as usize * elems, elems, self.kv_dim);
                self.v.copy_region(old as usize * elems, fresh as usize * elems, elems, self.kv_dim);
                self.refcount[old as usize] -= 1;
                self.drop_slot_ref(old);
                self.add_slot_ref(fresh);
                self.tables[slot][pi] = fresh;
                self.cow_copies += 1;
            }
        }
        Ok(())
    }

    /// Roll back positions `keep .. written` of one slot: whole pages
    /// past `ceil(keep / page_tokens)` are unmapped and released in
    /// **reverse allocation order** — `alloc_page` pops the free list's
    /// tail and `release` pushes it, so reverse-order release restores
    /// the free list bit-exactly, and a later never-drafted run allocates
    /// the very same page ids. The kept boundary page's rejected tail is
    /// re-zeroed across all layers (K, V, and Q8 scales), matching the
    /// fresh-allocation state byte-for-byte. A *shared* boundary page
    /// (refcount > 1) is left untouched: sharing means this slot never
    /// wrote into it — any speculative write would have COWed it private
    /// first — so there is no tail to erase.
    pub fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("truncate of slot {slot} outside batch {}", self.batch);
        }
        if keep > written || written > self.max_context {
            bail!(
                "invalid truncate range keep {keep} .. written {written} \
                 (window {})",
                self.max_context
            );
        }
        if keep == written {
            return Ok(());
        }
        let keep_pages = keep.div_ceil(self.page_tokens);
        while self.tables[slot].len() > keep_pages {
            let p = self.tables[slot].pop().expect("len > keep_pages implies non-empty");
            self.drop_slot_ref(p);
            self.release(p);
        }
        let off = keep % self.page_tokens;
        if off != 0 && self.tables[slot].len() == keep_pages {
            let page = self.tables[slot][keep_pages - 1];
            if self.refcount[page as usize] == 1 {
                let elems = (self.page_tokens - off) * self.kv_dim;
                for layer in 0..self.layers {
                    let base = self.page_base(page, layer, off);
                    self.k.reset_range(base, elems, self.kv_dim);
                    self.v.reset_range(base, elems, self.kv_dim);
                }
            }
        }
        Ok(())
    }
}

impl KvStore for PagedKvCache {
    fn spec(&self) -> KvCacheSpec {
        self.spec
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        validate_run_shape(k, v, self.kv_dim)?;
        let count = k.len() / self.kv_dim;
        self.ensure_writable(slot, start_pos, count)?;
        for r in 0..count {
            let pos = start_pos + r;
            let page = self.tables[slot][pos / self.page_tokens];
            let base = self.page_base(page, layer, pos % self.page_tokens);
            self.k.write(base, &k[r * self.kv_dim..(r + 1) * self.kv_dim]);
            self.v.write(base, &v[r * self.kv_dim..(r + 1) * self.kv_dim]);
        }
        Ok(())
    }

    fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        match self.tables[slot].get(pos / self.page_tokens) {
            Some(&page) => self.k.read(self.page_base(page, layer, pos % self.page_tokens), dst),
            None => dst.fill(0.0), // never written — same fresh state as the slab
        }
    }

    fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        assert!(pos < self.max_context);
        assert_eq!(dst.len(), self.kv_dim);
        match self.tables[slot].get(pos / self.page_tokens) {
            Some(&page) => self.v.read(self.page_base(page, layer, pos % self.page_tokens), dst),
            None => dst.fill(0.0),
        }
    }

    fn reset_slot(&mut self, slot: usize) {
        assert!(slot < self.batch);
        let pages: Vec<u32> = std::mem::take(&mut self.tables[slot]);
        for p in pages {
            self.drop_slot_ref(p);
            self.release(p);
        }
    }

    fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        PagedKvCache::truncate_slot(self, slot, keep, written)
    }

    fn data_bytes(&self) -> u64 {
        self.k.data_bytes() + self.v.data_bytes()
    }

    fn scale_bytes(&self) -> u64 {
        self.k.scale_bytes() + self.v.scale_bytes()
    }
}

/// The concrete store a `LutTransformer` carries, selected by
/// [`KvRuntimeConfig`] (`SAIL_KV` by default): the contiguous slab, or
/// the paged pool with an optional radix-tree prefix cache orchestrated
/// on top. Both sides are [`KvStore`]s; this enum is the zero-generics
/// dispatch point plus the place where page sharing, tree eviction under
/// pool pressure, and observability meet.
#[derive(Debug, Clone)]
pub enum KvBackend {
    Contiguous(KvCache),
    Paged { store: PagedKvCache, prefix: Option<RadixPrefixCache> },
}

impl KvBackend {
    /// Build the store a [`KvRuntimeConfig`] names. For the paged layout
    /// the pool is sized `batch × ceil(max_context/page_tokens)` (worst
    /// case, nothing shared) **plus** the shared-page budget, and the
    /// prefix tree's retention budget is that same extra — so pages held
    /// only by the tree can never starve slot allocation; the
    /// evict-under-pressure path in [`write_run`](Self::write_run) is a
    /// safety valve for explicitly over-budgeted trees.
    pub fn build(
        cfg: KvRuntimeConfig,
        spec: KvCacheSpec,
        layers: usize,
        batch: usize,
        max_context: usize,
        kv_dim: usize,
    ) -> Result<KvBackend> {
        match cfg.layout {
            KvLayout::Contiguous => {
                Ok(KvBackend::Contiguous(KvCache::new(spec, layers, batch, max_context, kv_dim)?))
            }
            KvLayout::Paged { page_tokens } => {
                if page_tokens == 0 {
                    bail!("paged KV page_tokens must be ≥ 1");
                }
                let budget = cfg.pages_budget.unwrap_or(max_context.div_ceil(page_tokens));
                let store = PagedKvCache::new(
                    spec,
                    layers,
                    batch,
                    max_context,
                    kv_dim,
                    page_tokens,
                    budget,
                )?;
                let prefix = cfg.prefix_cache.then(|| RadixPrefixCache::new(page_tokens, budget));
                Ok(KvBackend::Paged { store, prefix })
            }
        }
    }

    pub fn layout(&self) -> KvLayout {
        match self {
            KvBackend::Contiguous(_) => KvLayout::Contiguous,
            KvBackend::Paged { store, .. } => KvLayout::Paged { page_tokens: store.page_tokens() },
        }
    }

    /// The paged store, when that is what this backend runs (tests,
    /// benches, invariant checks).
    pub fn paged(&self) -> Option<&PagedKvCache> {
        match self {
            KvBackend::Contiguous(_) => None,
            KvBackend::Paged { store, .. } => Some(store),
        }
    }

    /// The contiguous slab, when that is what this backend runs (the
    /// rollback tests compare whole slabs bit-for-bit).
    pub fn contiguous(&self) -> Option<&KvCache> {
        match self {
            KvBackend::Contiguous(c) => Some(c),
            KvBackend::Paged { .. } => None,
        }
    }

    /// The prefix tree, when enabled.
    pub fn prefix_cache(&self) -> Option<&RadixPrefixCache> {
        match self {
            KvBackend::Contiguous(_) => None,
            KvBackend::Paged { prefix, .. } => prefix.as_ref(),
        }
    }

    /// Install the page-frame → NUMA-node interleave map (no-op on the
    /// contiguous slab).
    pub fn set_numa_interleave(&mut self, nodes: Vec<usize>) {
        if let KvBackend::Paged { store, .. } = self {
            store.set_numa_interleave(nodes);
        }
    }

    /// Paged-store observability; `None` on the contiguous slab (there
    /// is no pool to meter).
    pub fn metrics(&self) -> Option<KvMetrics> {
        match self {
            KvBackend::Contiguous(_) => None,
            KvBackend::Paged { store, prefix } => Some(KvMetrics {
                page_tokens: store.page_tokens(),
                pool_pages: store.pool_pages(),
                pages_in_use: store.pages_in_use(),
                peak_slot_resident_pages: store.peak_slot_resident_pages(),
                contiguous_worst_case_pages: store.batch * store.pages_per_slot(),
                cow_copies: store.cow_copies(),
                prefix_hits: prefix.as_ref().map_or(0, |t| t.hits()),
                prefix_misses: prefix.as_ref().map_or(0, |t| t.misses()),
                prefix_insertions: prefix.as_ref().map_or(0, |t| t.insertions()),
                prefix_evictions: prefix.as_ref().map_or(0, |t| t.evictions()),
                prefix_pages_held: prefix.as_ref().map_or(0, |t| t.pages_held()),
                numa_nodes: store.numa_nodes(),
            }),
        }
    }

    /// Longest-cached-prefix attach for a freshly reset slot: map the
    /// matched full pages read-only (refcount bump, zero copies — and
    /// zero LUT builds for the span, since those feed tokens are never
    /// run) and return the feed index prefill should start from. The
    /// split is always ≤ `feed.len() − 1`: the final feed token is re-run
    /// so the request's first logits are computed exactly as a cold
    /// prefill would (a full-prefix hit rewrites one shared page
    /// position with identical bits, exercising COW, not correctness).
    /// Contiguous stores and disabled prefix caches return 0 (cold path).
    pub fn prefix_attach(&mut self, slot: usize, feed: &[i32]) -> Result<usize> {
        match self {
            KvBackend::Contiguous(_) => Ok(0),
            KvBackend::Paged { store, prefix } => {
                let Some(tree) = prefix else { return Ok(0) };
                if !store.tables[slot].is_empty() {
                    bail!("prefix attach on slot {slot} with a non-empty page table");
                }
                if feed.is_empty() {
                    return Ok(0);
                }
                let m = tree.lookup(feed);
                let split = m.tokens.min(feed.len() - 1);
                if split == 0 {
                    tree.record(false);
                    return Ok(0);
                }
                store.map_shared(slot, &m.pages);
                tree.record(true);
                Ok(split)
            }
        }
    }

    /// Publish a completed prefill's full pages into the prefix tree
    /// (refcount bump per newly retained page; chunks already cached are
    /// no-ops), then trim the tree back under its page budget (LRU leaf
    /// eviction). Keyed on the *feed* — the prompt, or prompt ⊕ generated
    /// for a preemption resume — so recompute-resumes share too.
    pub fn prefix_insert(&mut self, slot: usize, feed: &[i32]) -> Result<()> {
        match self {
            KvBackend::Contiguous(_) => Ok(()),
            KvBackend::Paged { store, prefix } => {
                let Some(tree) = prefix else { return Ok(()) };
                let full = feed.len() / store.page_tokens();
                if full == 0 {
                    return Ok(());
                }
                if store.tables[slot].len() < full {
                    bail!(
                        "prefix insert for slot {slot}: table holds {} pages, feed needs {full}",
                        store.tables[slot].len()
                    );
                }
                let pages: Vec<u32> = store.tables[slot][..full].to_vec();
                for p in tree.insert_chunks(feed, &pages) {
                    store.retain(p);
                }
                for p in tree.trim() {
                    store.release(p);
                }
                Ok(())
            }
        }
    }
}

impl KvStore for KvBackend {
    fn spec(&self) -> KvCacheSpec {
        match self {
            KvBackend::Contiguous(c) => c.spec(),
            KvBackend::Paged { store, .. } => store.spec,
        }
    }

    fn max_context(&self) -> usize {
        match self {
            KvBackend::Contiguous(c) => c.max_context(),
            KvBackend::Paged { store, .. } => store.max_context,
        }
    }

    fn kv_dim(&self) -> usize {
        match self {
            KvBackend::Contiguous(c) => c.kv_dim(),
            KvBackend::Paged { store, .. } => store.kv_dim,
        }
    }

    /// Ranged write, with the paged path's pool-pressure reaction: on
    /// [`PagePoolExhausted`], evict one LRU prefix-tree leaf and retry;
    /// only when nothing is left to evict does the error propagate (the
    /// batcher then finishes the one offending request `EngineFault`).
    fn write_run(
        &mut self,
        layer: usize,
        slot: usize,
        start_pos: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        match self {
            KvBackend::Contiguous(c) => c.write_run(layer, slot, start_pos, k, v),
            KvBackend::Paged { store, prefix } => loop {
                match store.write_run(layer, slot, start_pos, k, v) {
                    Ok(()) => return Ok(()),
                    Err(e) if e.is::<PagePoolExhausted>() => {
                        match prefix.as_mut().and_then(|t| t.evict_one()) {
                            Some(page) => store.release(page),
                            None => return Err(e),
                        }
                    }
                    Err(e) => return Err(e),
                }
            },
        }
    }

    fn read_k(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        match self {
            KvBackend::Contiguous(c) => c.read_k(layer, slot, pos, dst),
            KvBackend::Paged { store, .. } => store.read_k(layer, slot, pos, dst),
        }
    }

    fn read_v(&self, layer: usize, slot: usize, pos: usize, dst: &mut [f32]) {
        match self {
            KvBackend::Contiguous(c) => c.read_v(layer, slot, pos, dst),
            KvBackend::Paged { store, .. } => store.read_v(layer, slot, pos, dst),
        }
    }

    fn reset_slot(&mut self, slot: usize) {
        match self {
            KvBackend::Contiguous(c) => c.reset_slot(slot),
            KvBackend::Paged { store, .. } => KvStore::reset_slot(store, slot),
        }
    }

    fn truncate_slot(&mut self, slot: usize, keep: usize, written: usize) -> Result<()> {
        match self {
            KvBackend::Contiguous(c) => c.truncate_slot(slot, keep, written),
            KvBackend::Paged { store, .. } => store.truncate_slot(slot, keep, written),
        }
    }

    fn data_bytes(&self) -> u64 {
        match self {
            KvBackend::Contiguous(c) => c.data_bytes(),
            KvBackend::Paged { store, .. } => KvStore::data_bytes(store),
        }
    }

    fn scale_bytes(&self) -> u64 {
        match self {
            KvBackend::Contiguous(c) => c.scale_bytes(),
            KvBackend::Paged { store, .. } => KvStore::scale_bytes(store),
        }
    }
}

impl KvBackend {
    /// Convenience mirrors of the [`KvStore`] surface so existing
    /// `model.kv().data_bytes()`-style call sites keep reading naturally
    /// without importing the trait.
    pub fn data_bytes(&self) -> u64 {
        KvStore::data_bytes(self)
    }

    pub fn scale_bytes(&self) -> u64 {
        KvStore::scale_bytes(self)
    }

    pub fn spec(&self) -> KvCacheSpec {
        KvStore::spec(self)
    }
}

/// Per-token cycles the KV path adds on SAIL: the Q×K_cacheᵀ and
/// attention×V products stream through the same C-SRAM hardware
/// column-wise; profiling in the paper attributes ~5% of end-to-end
/// latency to this path (§III-B), which the pipeline model charges as a
/// multiplicative factor.
pub const KV_PATH_OVERHEAD: f64 = 0.05;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantLevel;

    #[test]
    fn fp16_vs_q8_halving() {
        let m = ModelConfig::llama2_7b();
        let f = KvCacheSpec::fp16().seq_bytes(&m, 4096);
        let q = KvCacheSpec::q8().seq_bytes(&m, 4096);
        assert_eq!(f, 2 * q);
        assert_eq!(f, 2 * 1024 * 1024 * 1024); // 2 GiB
    }

    #[test]
    fn table3_x_entry_reproduced() {
        // 13B-Q8 at ctx 4096 does not fit one V100 (16 GB).
        let m = ModelConfig::llama2_13b();
        let w = m.weight_bytes(QuantLevel::Q8, 32);
        let cap = 16u64 * 1_000_000_000;
        let b = KvCacheSpec::fp16().max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert_eq!(b, 0, "13B-Q8@4K must not fit a single V100");
        // …but fits 2×V100 (32 GB) at batch ≥ 1.
        let b2 = KvCacheSpec::fp16().max_batch(&m, 4096, 2 * cap, w, 1_000_000_000);
        assert!(b2 >= 1, "got {b2}");
    }

    #[test]
    fn slots_for_degenerate_spec_is_a_typed_error() {
        // Regression for the `.max(1)` divisor: a zero-`seq_bytes` spec
        // used to yield a garbage huge capacity; it is now a typed
        // validation error, and the valid path is unchanged.
        let m = ModelConfig::llama2_7b();
        let spec = KvCacheSpec::fp16();
        let cap = 16u64 * 1_000_000_000;
        let w = m.weight_bytes(QuantLevel::Q4, 32);
        assert_eq!(
            spec.slots_for(&m, 0, cap, w, 0),
            Err(KvAccountingError::DegenerateSpec { ctx: 0 })
        );
        assert_eq!(
            spec.slots_for_paged(&m, 0, 16, cap, w, 0, 0),
            Err(KvAccountingError::DegenerateSpec { ctx: 0 })
        );
        let err = spec.slots_for(&m, 0, cap, w, 0).unwrap_err();
        assert!(err.to_string().contains("0 bytes"), "{err}");
        // Valid specs agree with the legacy wrapper, including the
        // legitimate zero when weights alone overflow capacity.
        assert_eq!(spec.slots_for(&m, 4096, cap, w, 0).unwrap(), spec.max_batch(&m, 4096, cap, w, 0));
        assert_eq!(spec.slots_for(&m, 4096, 1, w, 0).unwrap(), 0);
    }

    #[test]
    fn paged_accounting_covers_page_and_table_overhead() {
        let m = ModelConfig::llama2_7b();
        let spec = KvCacheSpec::q8();
        // Whole-page rounding + table entries: paged ≥ contiguous, and
        // exactly pages × (page_bytes + entry) at page granularity.
        for ctx in [1usize, 15, 16, 17, 4096] {
            let paged = spec.paged_seq_bytes(&m, ctx, 16);
            assert!(paged >= spec.seq_bytes(&m, ctx), "ctx {ctx}");
            let pages = ctx.div_ceil(16) as u64;
            assert_eq!(paged, pages * spec.page_bytes(&m, 16) + pages * PAGE_TABLE_ENTRY_BYTES);
        }
        // The per-sequence overhead shrinks the slot count, never grows it.
        let cap = 16u64 * 1_000_000_000;
        let w = m.weight_bytes(QuantLevel::Q4, 32);
        let flat = spec.slots_for(&m, 4096, cap, w, 0).unwrap();
        let paged = spec.slots_for_paged(&m, 4096, 16, cap, w, 0, 1 << 20).unwrap();
        assert!(paged <= flat, "{paged} vs {flat}");
    }

    #[test]
    fn kv_layout_grammar() {
        assert_eq!(parse_kv_layout("contiguous"), Ok(KvLayout::Contiguous));
        assert_eq!(parse_kv_layout(" paged:16 "), Ok(KvLayout::Paged { page_tokens: 16 }));
        assert_eq!(parse_kv_layout("paged:1"), Ok(KvLayout::Paged { page_tokens: 1 }));
        for bad in ["", "slab", "paged", "paged:", "paged:0", "paged:-4", "paged:x", "16"] {
            assert!(parse_kv_layout(bad).is_err(), "{bad:?} must be rejected");
        }
        assert_eq!(KvLayout::Paged { page_tokens: 8 }.to_string(), "paged:8");
        assert_eq!(KvLayout::Contiguous.to_string(), "contiguous");
    }

    #[test]
    fn f16_roundtrip_known_values() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (0.5, 0x3800),
            (2.0, 0x4000),
            (65504.0, 0x7bff), // largest finite half
            (6.103_515_6e-5, 0x0400), // smallest normal half
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "encoding {x}");
            assert_eq!(f16_bits_to_f32(bits), x, "decoding {x}");
        }
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00, "overflow must saturate to inf");
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Round-to-nearest-even at the 1.0 + ulp/2 midpoint: 1 + 2^-11
        // is exactly halfway between 0x3c00 and 0x3c01 → even (0x3c00).
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
    }

    #[test]
    fn f16_roundtrip_error_bounded() {
        let mut prng = crate::util::Prng::new(21);
        for _ in 0..500 {
            let x = prng.normal() as f32;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            // Relative error of binary16 round-to-nearest: ≤ 2⁻¹¹.
            assert!((x - y).abs() <= x.abs() * 4.9e-4 + 1e-7, "{x} -> {y}");
            // Idempotent: a value already on the f16 grid re-encodes to
            // itself.
            assert_eq!(f32_to_f16_bits(y), f32_to_f16_bits(x));
        }
    }

    #[test]
    fn kv_cache_roundtrip_both_precisions() {
        let mut prng = crate::util::Prng::new(33);
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let mut kv = KvCache::new(spec, 2, 3, 4, 8).unwrap();
            let kvec: Vec<f32> = (0..8).map(|_| prng.normal() as f32).collect();
            let vvec: Vec<f32> = (0..8).map(|_| prng.normal() as f32).collect();
            kv.write(1, 2, 3, &kvec, &vvec).unwrap();
            let mut back = vec![0.0f32; 8];
            kv.read_k(1, 2, 3, &mut back);
            let amax = kvec.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let tol = if spec.bits == 16 { amax * 4.9e-4 + 1e-7 } else { amax / 254.0 + 1e-7 };
            for (a, b) in kvec.iter().zip(&back) {
                assert!((a - b).abs() <= tol, "{spec:?}: {a} vs {b}");
            }
            kv.read_v(1, 2, 3, &mut back);
            for (a, b) in vvec.iter().zip(&back) {
                assert!((a - b).abs() <= tol, "{spec:?}: {a} vs {b}");
            }
            // Neighbouring positions and slots untouched.
            kv.read_k(1, 2, 2, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
            kv.read_k(1, 1, 3, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
            // Slot reset clears only that slot.
            kv.reset_slot(2);
            kv.read_k(1, 2, 3, &mut back);
            assert!(back.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn kv_cache_allocation_matches_seq_bytes_accounting() {
        // The cross-check the serving path relies on: the store's element
        // payload is exactly what `KvCacheSpec::seq_bytes` accounts.
        let m = ModelConfig {
            name: "kv-acct".into(),
            hidden: 64,
            layers: 3,
            heads: 8,
            kv_heads: 4,
            ffn: 128,
            vocab: 97,
            max_context: 40,
        };
        let kv_dim = m.kv_heads * m.head_dim();
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            for batch in [1usize, 2, 5] {
                let kv = KvCache::new(spec, m.layers, batch, m.max_context, kv_dim).unwrap();
                assert_eq!(
                    kv.data_bytes(),
                    spec.batch_bytes(&m, m.max_context, batch),
                    "{spec:?} batch {batch}"
                );
            }
        }
        // fp16 carries no scale metadata; q8 carries one f32 per cached
        // vector on top of the accounted payload.
        let f = KvCache::new(KvCacheSpec::fp16(), 2, 1, 8, 16).unwrap();
        assert_eq!(f.scale_bytes(), 0);
        let q = KvCache::new(KvCacheSpec::q8(), 2, 1, 8, 16).unwrap();
        assert_eq!(q.scale_bytes(), 2 * 4 * 2 * 8);
    }

    #[test]
    fn paged_pool_allocation_matches_page_accounting() {
        // Pool payload = pool_pages × page_bytes, at any occupancy; the
        // table bytes grow with mapped pages only.
        let m = ModelConfig {
            name: "kv-paged-acct".into(),
            hidden: 64,
            layers: 3,
            heads: 8,
            kv_heads: 4,
            ffn: 128,
            vocab: 97,
            max_context: 40,
        };
        let kv_dim = m.kv_heads * m.head_dim();
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            for (batch, pt, extra) in [(1usize, 16usize, 0usize), (2, 8, 3), (5, 7, 1)] {
                let mut kv =
                    PagedKvCache::new(spec, m.layers, batch, m.max_context, kv_dim, pt, extra)
                        .unwrap();
                let pages = batch * m.max_context.div_ceil(pt) + extra;
                assert_eq!(kv.pool_pages(), pages);
                assert_eq!(KvStore::data_bytes(&kv), pages as u64 * spec.page_bytes(&m, pt));
                assert_eq!(kv.pages_in_use(), 0);
                assert_eq!(kv.table_bytes(), 0);
                kv.write_run(0, 0, 0, &vec![1.0; kv_dim], &vec![1.0; kv_dim]).unwrap();
                assert_eq!(kv.pages_in_use(), 1);
                assert_eq!(kv.table_bytes(), PAGE_TABLE_ENTRY_BYTES);
            }
        }
    }

    #[test]
    fn paged_matches_contiguous_bit_for_bit() {
        // Same writes through the KvStore trait → bit-identical reads,
        // both precisions, page size coprime with the run lengths.
        fn exercise<S: KvStore>(s: &mut S, seed: u64) {
            let dim = s.kv_dim();
            let mut prng = crate::util::Prng::new(seed);
            // Slot 1: a 5-row run at 0, then single rows; slot 0: rows
            // written out of lockstep; slot 2 reset mid-way.
            for (slot, start, rows) in
                [(1usize, 0usize, 5usize), (0, 0, 3), (1, 5, 1), (2, 0, 4), (0, 3, 2), (1, 6, 2)]
            {
                let k: Vec<f32> = (0..rows * dim).map(|_| prng.normal() as f32).collect();
                let v: Vec<f32> = (0..rows * dim).map(|_| prng.normal() as f32).collect();
                for layer in 0..2 {
                    s.write_run(layer, slot, start, &k, &v).unwrap();
                }
            }
            s.reset_slot(2);
        }
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let (layers, batch, ctx, dim, pt) = (2usize, 3usize, 9usize, 8usize, 4usize);
            let mut slab = KvCache::new(spec, layers, batch, ctx, dim).unwrap();
            let mut paged = PagedKvCache::new(spec, layers, batch, ctx, dim, pt, 0).unwrap();
            exercise(&mut slab, 91);
            exercise(&mut paged, 91);
            let mut a = vec![0.0f32; dim];
            let mut b = vec![0.0f32; dim];
            for l in 0..layers {
                for s in 0..batch {
                    for p in 0..ctx {
                        slab.read_k(l, s, p, &mut a);
                        KvStore::read_k(&paged, l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: K diverged at ({l},{s},{p})");
                        slab.read_v(l, s, p, &mut a);
                        KvStore::read_v(&paged, l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: V diverged at ({l},{s},{p})");
                    }
                }
            }
        }
    }

    #[test]
    fn cow_write_preserves_the_shared_original() {
        let (layers, batch, ctx, dim, pt) = (2usize, 2usize, 8usize, 4usize, 4usize);
        let mut kv =
            PagedKvCache::new(KvCacheSpec::q8(), layers, batch, ctx, dim, pt, 2).unwrap();
        let mut prng = crate::util::Prng::new(7);
        let k: Vec<f32> = (0..8 * dim).map(|_| prng.normal() as f32).collect();
        let v: Vec<f32> = (0..8 * dim).map(|_| prng.normal() as f32).collect();
        for layer in 0..layers {
            kv.write_run(layer, 0, 0, &k, &v).unwrap();
        }
        let shared: Vec<u32> = kv.table(0).to_vec();
        assert_eq!(shared.len(), 2);
        // Snapshot slot 0's visible content, then share its pages into
        // slot 1 and overwrite one shared position there.
        let snap = |kv: &PagedKvCache, slot: usize| -> Vec<f32> {
            let mut out = Vec::new();
            let mut buf = vec![0.0f32; dim];
            for l in 0..layers {
                for p in 0..ctx {
                    kv.read_k(l, slot, p, &mut buf);
                    out.extend_from_slice(&buf);
                    kv.read_v(l, slot, p, &mut buf);
                    out.extend_from_slice(&buf);
                }
            }
            out
        };
        let before = snap(&kv, 0);
        kv.map_shared(1, &shared);
        assert_eq!(kv.refcount(shared[0]), 2);
        assert_eq!(snap(&kv, 1), before, "shared mapping must read identically");
        for layer in 0..layers {
            kv.write_run(layer, 1, 5, &vec![9.0; dim], &vec![-9.0; dim]).unwrap();
        }
        // Exactly one COW (page 1 holds positions 4..8; layer 1's write
        // sees the already-private copy).
        assert_eq!(kv.cow_copies(), 1);
        assert_eq!(snap(&kv, 0), before, "original mutated through a COW write");
        assert_ne!(kv.table(1)[1], shared[1], "writer must hold a private copy");
        assert_eq!(kv.table(1)[0], shared[0], "read-only page stays shared");
        assert_eq!(kv.refcount(shared[1]), 1, "original's refcount back to its owner");
        // Slot 1's un-overwritten positions still match the original.
        let mut buf = vec![0.0f32; dim];
        let mut orig = vec![0.0f32; dim];
        kv.read_k(0, 1, 4, &mut buf);
        kv.read_k(0, 0, 4, &mut orig);
        assert_eq!(buf, orig, "COW copy must carry the original bits");
        // Releasing both slots balances every refcount.
        KvStore::reset_slot(&mut kv, 0);
        KvStore::reset_slot(&mut kv, 1);
        assert_eq!(kv.pages_in_use(), 0);
    }

    #[test]
    fn page_pool_exhaustion_is_typed_and_recoverable() {
        // batch 1 × 2 pages + 0 extra: retaining a page (as the prefix
        // tree would) and COW-ing forces exhaustion — a typed error the
        // backend reacts to by eviction, after which the write succeeds.
        let dim = 4usize;
        let mut kv = PagedKvCache::new(KvCacheSpec::fp16(), 1, 1, 8, dim, 4, 0).unwrap();
        kv.write_run(0, 0, 0, &vec![1.0; 8 * dim], &vec![1.0; 8 * dim]).unwrap();
        let held = kv.table(0)[0];
        kv.retain(held); // tree-style retention
        KvStore::reset_slot(&mut kv, 0);
        assert_eq!(kv.pages_in_use(), 1); // only the retained page
        kv.map_shared(0, &[held]);
        // COW of the shared page takes the last free page; extending to
        // page index 1 then exhausts the pool.
        let err = kv
            .write_run(0, 0, 0, &vec![2.0; 8 * dim], &vec![2.0; 8 * dim])
            .unwrap_err();
        let typed = err.downcast_ref::<PagePoolExhausted>().expect("typed exhaustion");
        assert_eq!(typed.pool_pages, 2);
        // Evict the tree-held page (rc 1 → free) and retry: succeeds,
        // and the interrupted COW left no half-state behind.
        kv.release(held);
        kv.write_run(0, 0, 0, &vec![2.0; 8 * dim], &vec![2.0; 8 * dim]).unwrap();
        let mut buf = vec![0.0f32; dim];
        kv.read_k(0, 0, 7, &mut buf);
        assert!(buf.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn failed_write_on_shared_pages_mutates_nothing() {
        // Window-violating writes (the KvCorrupt fault redirects
        // start_pos to max_context) must reject before any allocation,
        // COW, or refcount motion.
        let dim = 4usize;
        let mut kv = PagedKvCache::new(KvCacheSpec::q8(), 1, 2, 8, dim, 4, 1).unwrap();
        kv.write_run(0, 0, 0, &vec![3.0; 8 * dim], &vec![3.0; 8 * dim]).unwrap();
        let shared: Vec<u32> = kv.table(0).to_vec();
        kv.map_shared(1, &shared);
        let in_use = kv.pages_in_use();
        let err = kv.write_run(0, 1, 8, &vec![0.0; dim], &vec![0.0; dim]).unwrap_err();
        assert!(err.to_string().contains("outside the 8-token window"), "{err}");
        assert_eq!(kv.pages_in_use(), in_use, "failed write leaked a page");
        assert_eq!(kv.cow_copies(), 0, "failed write ran a COW copy");
        assert_eq!(kv.refcount(shared[0]), 2);
        assert_eq!(kv.table(1), shared.as_slice(), "table rewritten on a failed write");
    }

    #[test]
    fn kv_cache_rejects_out_of_window_write() {
        // A typed error, not a panic: the serving path degrades the one
        // offending request instead of taking the process down.
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write(0, 0, 4, &[0.0; 8], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("outside the 4-token window"), "{err}");
        // The cache stays usable and untouched after the rejection.
        kv.write(0, 0, 3, &[1.0; 8], &[1.0; 8]).unwrap();
        let mut back = vec![0.0f32; 8];
        kv.read_k(0, 0, 3, &mut back);
        assert!(back.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn write_run_matches_per_token_writes_bit_for_bit() {
        // The ranged chunked-prefill write must be indistinguishable from
        // the per-token path, for both storage precisions (q8 re-derives
        // one scale per vector — the run must slice vectors identically).
        let mut prng = crate::util::Prng::new(55);
        for spec in [KvCacheSpec::fp16(), KvCacheSpec::q8()] {
            let (layers, batch, ctx, dim) = (2usize, 3usize, 6usize, 8usize);
            let mut per_token = KvCache::new(spec, layers, batch, ctx, dim).unwrap();
            let mut ranged = KvCache::new(spec, layers, batch, ctx, dim).unwrap();
            let count = 4usize;
            let start = 1usize;
            let kr: Vec<f32> = (0..count * dim).map(|_| prng.normal() as f32).collect();
            let vr: Vec<f32> = (0..count * dim).map(|_| prng.normal() as f32).collect();
            for r in 0..count {
                per_token
                    .write(
                        1,
                        2,
                        start + r,
                        &kr[r * dim..(r + 1) * dim],
                        &vr[r * dim..(r + 1) * dim],
                    )
                    .unwrap();
            }
            ranged.write_run(1, 2, start, &kr, &vr).unwrap();
            // Element payload and accounting are untouched by the write
            // path taken…
            assert_eq!(ranged.data_bytes(), per_token.data_bytes());
            assert_eq!(ranged.scale_bytes(), per_token.scale_bytes());
            // …and every cached vector in the store round-trips
            // identically (positions outside the run stay zero).
            let mut a = vec![0.0f32; dim];
            let mut b = vec![0.0f32; dim];
            for l in 0..layers {
                for s in 0..batch {
                    for p in 0..ctx {
                        per_token.read_k(l, s, p, &mut a);
                        ranged.read_k(l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: K diverged at ({l},{s},{p})");
                        per_token.read_v(l, s, p, &mut a);
                        ranged.read_v(l, s, p, &mut b);
                        assert_eq!(a, b, "{spec:?}: V diverged at ({l},{s},{p})");
                    }
                }
            }
        }
    }

    #[test]
    fn write_run_rejects_runs_crossing_the_window() {
        // Positions 2..5 of a 4-token window: the *run*, not just its
        // first row, must fit — rejected (typed) before any row is
        // written.
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write_run(0, 0, 2, &[1.0; 3 * 8], &[1.0; 3 * 8]).unwrap_err();
        assert!(err.to_string().contains("outside the 4-token window"), "{err}");
        let mut back = vec![0.0f32; 8];
        for p in 0..4 {
            kv.read_k(0, 0, p, &mut back);
            assert!(back.iter().all(|&x| x == 0.0), "row {p} written despite rejection");
        }
    }

    #[test]
    fn write_run_rejects_ragged_payloads() {
        let mut kv = KvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8).unwrap();
        let err = kv.write_run(0, 0, 0, &[0.0; 12], &[0.0; 12]).unwrap_err();
        assert!(err.to_string().contains("not a positive multiple of kv_dim"), "{err}");
        let err = kv.write_run(0, 0, 0, &[0.0; 16], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("must cover the same positions"), "{err}");
        // Same contract through the paged store.
        let mut pv = PagedKvCache::new(KvCacheSpec::fp16(), 1, 1, 4, 8, 2, 0).unwrap();
        assert!(pv.write_run(0, 0, 0, &[0.0; 12], &[0.0; 12]).is_err());
        assert!(pv.write_run(0, 0, 0, &[0.0; 16], &[0.0; 8]).is_err());
    }

    #[test]
    fn unsupported_precision_is_an_error() {
        assert!(KvCache::new(KvCacheSpec { bits: 4 }, 1, 1, 4, 8).is_err());
        assert!(PagedKvCache::new(KvCacheSpec { bits: 4 }, 1, 1, 4, 8, 2, 0).is_err());
        assert!(PagedKvCache::new(KvCacheSpec::q8(), 1, 1, 4, 8, 0, 0).is_err());
    }

    #[test]
    fn batch_capacity_shrinks_with_context() {
        let m = ModelConfig::llama2_7b();
        let w = m.weight_bytes(QuantLevel::Q4, 32);
        let cap = 16u64 * 1_000_000_000;
        let spec = KvCacheSpec::fp16();
        let b512 = spec.max_batch(&m, 512, cap, w, 1_000_000_000);
        let b4k = spec.max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert!(b512 > b4k, "{b512} vs {b4k}");
        assert!(b4k >= 1 && b4k <= 8, "7B-Q4@4K on V100: small batch, got {b4k}");
    }
}
