//! KV-cache sizing and placement (paper §III-B).
//!
//! SAIL supports quantized (8-bit) and non-quantized (fp16) KV caches; the
//! KV matrices are mapped *column-wise* across C-SRAM arrays (Fig 5) so the
//! per-token `Q × K_cacheᵀ` product streams without rebuilding large LUTs.
//! The GPU baselines' batch capacity is governed by this module's byte
//! accounting.

use super::ModelConfig;

/// KV-cache precision and layout for one serving deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    /// Bits per stored K/V element (16 = fp16, 8 = the paper's extended
    /// llama.cpp 8-bit quantized KV).
    pub bits: u32,
}

impl KvCacheSpec {
    pub fn fp16() -> Self {
        KvCacheSpec { bits: 16 }
    }

    pub fn q8() -> Self {
        KvCacheSpec { bits: 8 }
    }

    /// Bytes for one sequence at `ctx` cached tokens.
    pub fn seq_bytes(&self, m: &ModelConfig, ctx: usize) -> u64 {
        m.kv_bytes_per_token(self.bits) * ctx as u64
    }

    /// Bytes for a batch of sequences at the same context length.
    pub fn batch_bytes(&self, m: &ModelConfig, ctx: usize, batch: usize) -> u64 {
        self.seq_bytes(m, ctx) * batch as u64
    }

    /// Largest batch fitting in `capacity_bytes` alongside the weights —
    /// the constraint that yields Table III's shrinking batch columns and
    /// "X" (does-not-fit) entries.
    pub fn max_batch(
        &self,
        m: &ModelConfig,
        ctx: usize,
        capacity_bytes: u64,
        weight_bytes: u64,
        reserve_bytes: u64,
    ) -> usize {
        let need = weight_bytes + reserve_bytes;
        if need >= capacity_bytes {
            return 0;
        }
        ((capacity_bytes - need) / self.seq_bytes(m, ctx).max(1)) as usize
    }
}

/// Per-token cycles the KV path adds on SAIL: the Q×K_cacheᵀ and
/// attention×V products stream through the same C-SRAM hardware
/// column-wise; profiling in the paper attributes ~5% of end-to-end
/// latency to this path (§III-B), which the pipeline model charges as a
/// multiplicative factor.
pub const KV_PATH_OVERHEAD: f64 = 0.05;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantLevel;

    #[test]
    fn fp16_vs_q8_halving() {
        let m = ModelConfig::llama2_7b();
        let f = KvCacheSpec::fp16().seq_bytes(&m, 4096);
        let q = KvCacheSpec::q8().seq_bytes(&m, 4096);
        assert_eq!(f, 2 * q);
        assert_eq!(f, 2 * 1024 * 1024 * 1024); // 2 GiB
    }

    #[test]
    fn table3_x_entry_reproduced() {
        // 13B-Q8 at ctx 4096 does not fit one V100 (16 GB).
        let m = ModelConfig::llama2_13b();
        let w = m.weight_bytes(QuantLevel::Q8, 32);
        let cap = 16u64 * 1_000_000_000;
        let b = KvCacheSpec::fp16().max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert_eq!(b, 0, "13B-Q8@4K must not fit a single V100");
        // …but fits 2×V100 (32 GB) at batch ≥ 1.
        let b2 = KvCacheSpec::fp16().max_batch(&m, 4096, 2 * cap, w, 1_000_000_000);
        assert!(b2 >= 1, "got {b2}");
    }

    #[test]
    fn batch_capacity_shrinks_with_context() {
        let m = ModelConfig::llama2_7b();
        let w = m.weight_bytes(QuantLevel::Q4, 32);
        let cap = 16u64 * 1_000_000_000;
        let spec = KvCacheSpec::fp16();
        let b512 = spec.max_batch(&m, 512, cap, w, 1_000_000_000);
        let b4k = spec.max_batch(&m, 4096, cap, w, 1_000_000_000);
        assert!(b512 > b4k, "{b512} vs {b4k}");
        assert!(b4k >= 1 && b4k <= 8, "7B-Q4@4K on V100: small batch, got {b4k}");
    }
}
