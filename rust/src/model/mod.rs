//! Transformer model shape inventory — and the executable decode model.
//!
//! The timing models need exact tensor shapes, parameter counts, byte
//! sizes per quantization level, and KV-cache growth — all derivable from
//! the public architecture configs of the benchmarked models (Llama-2-7B,
//! Llama-2-13B, TinyMistral-248M) plus the tiny llama-style model we
//! execute end-to-end through the JAX→HLO→PJRT path.
//!
//! [`decode`] turns the inventory into a running workload: a multi-layer
//! KV-cached transformer whose every projection executes on the LUT-GEMV
//! backend ([`LutTransformer`]), reading and writing a real [`KvCache`].
//!
//! Accounting contract: [`KvCacheSpec::seq_bytes`] is not an estimate —
//! the executable cache allocates its element payload as *exactly* that
//! many bytes (`kv_bytes_per_token × context`, fp16 or q8 codes;
//! per-vector q8 scales are tracked separately by
//! [`KvCache::scale_bytes`]), pinned by tests on both the cache and the
//! serving path. The capacity planner ([`KvCacheSpec::max_batch`]) and
//! the memory-traffic models therefore describe the same bytes the
//! running system touches.

pub mod decode;
pub mod kv;
pub mod prefix;

pub use decode::{
    DecodeItem, DecodeRun, DecodeSpec, DecodeStats, DraftSpec, FloatWeights, LayerGemvStats,
    LayerSpec, LutTransformer,
};
pub use kv::{
    kv_layout_from_env, parse_kv_layout, KvAccountingError, KvBackend, KvCache, KvCacheSpec,
    KvLayout, KvMetrics, KvRuntimeConfig, KvStore, PagePoolExhausted, PagedKvCache,
    PAGE_TABLE_ENTRY_BYTES,
};
pub use prefix::{PrefixMatch, RadixPrefixCache};

use crate::quant::QuantLevel;
use crate::util::ceil_div;

/// Decoder-only transformer configuration (llama-style: RMSNorm, RoPE,
/// SwiGLU MLP; MHA or GQA).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    /// KV heads (== heads for MHA; < heads for GQA).
    pub kv_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_context: usize,
}

impl ModelConfig {
    /// Llama-2-7B (Touvron et al. 2023).
    pub fn llama2_7b() -> Self {
        ModelConfig {
            name: "Llama-2-7B".into(),
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 32,
            ffn: 11008,
            vocab: 32000,
            max_context: 4096,
        }
    }

    /// Llama-2-13B.
    pub fn llama2_13b() -> Self {
        ModelConfig {
            name: "Llama-2-13B".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            ffn: 13824,
            vocab: 32000,
            max_context: 4096,
        }
    }

    /// TinyMistral-248M (Locutusque), the small benchmark model.
    pub fn tinymistral_248m() -> Self {
        ModelConfig {
            name: "TinyMistral-248M".into(),
            hidden: 1024,
            layers: 12,
            heads: 32,
            kv_heads: 8,
            ffn: 4096,
            vocab: 32005,
            max_context: 2048,
        }
    }

    /// The tiny llama-style model executed for real through PJRT in the
    /// end-to-end example (shapes chosen so every projection is a multiple
    /// of the quant group and small enough for interpret-mode Pallas).
    pub fn tiny_e2e() -> Self {
        ModelConfig {
            name: "tiny-e2e-13M".into(),
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 8,
            ffn: 1024,
            vocab: 2048,
            max_context: 256,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Per-layer weight-matrix shapes `[K, N]` in GEMV orientation
    /// (y[1,N] = x[1,K]·W): Q/K/V/O projections + SwiGLU gate/up/down.
    pub fn layer_matrices(&self) -> Vec<(usize, usize)> {
        let h = self.hidden;
        let kvh = self.kv_heads * self.head_dim();
        vec![
            (h, h),        // Wq
            (h, kvh),      // Wk
            (h, kvh),      // Wv
            (h, h),        // Wo
            (h, self.ffn), // W_gate
            (h, self.ffn), // W_up
            (self.ffn, h), // W_down
        ]
    }

    /// Parameters in the repeated decoder stack.
    pub fn layer_params(&self) -> u64 {
        self.layer_matrices().iter().map(|&(k, n)| (k * n) as u64).sum::<u64>()
            * self.layers as u64
    }

    /// Embedding + LM head parameters.
    pub fn embed_params(&self) -> u64 {
        2 * (self.vocab * self.hidden) as u64
    }

    /// Total parameter count (norms are negligible and omitted, as in the
    /// usual "7B" accounting).
    pub fn params(&self) -> u64 {
        self.layer_params() + self.embed_params()
    }

    /// Weight bytes at a quantization level (codes + f16 group scales).
    pub fn weight_bytes(&self, level: QuantLevel, group: usize) -> u64 {
        (self.params() as f64 * level.bits_per_weight(group) / 8.0).ceil() as u64
    }

    /// Bytes of one decoder layer's weights (the tensor-level scheduling
    /// staging unit).
    pub fn layer_bytes(&self, level: QuantLevel, group: usize) -> u64 {
        let p: u64 = self.layer_matrices().iter().map(|&(k, n)| (k * n) as u64).sum();
        (p as f64 * level.bits_per_weight(group) / 8.0).ceil() as u64
    }

    /// `lutmm_1k` tiles (1024×1024) needed for one full token's GEMVs:
    /// every layer matrix plus the LM head, padded up to tile boundaries.
    pub fn tiles_per_token(&self) -> u64 {
        let tile = crate::isa::TILE_DIM;
        let mut tiles: u64 = 0;
        for &(k, n) in &self.layer_matrices() {
            tiles += (ceil_div(k, tile) * ceil_div(n, tile)) as u64;
        }
        tiles *= self.layers as u64;
        tiles += (ceil_div(self.hidden, tile) * ceil_div(self.vocab, tile)) as u64;
        tiles
    }

    /// Dense FLOPs per generated token (2 per weight).
    pub fn flops_per_token(&self) -> u64 {
        2 * self.params()
    }

    /// KV-cache bytes appended per generated token at `kv_bits` precision.
    pub fn kv_bytes_per_token(&self, kv_bits: u32) -> u64 {
        let kvh = self.kv_heads * self.head_dim();
        (2 * self.layers * kvh) as u64 * kv_bits as u64 / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        let m7 = ModelConfig::llama2_7b();
        let p7 = m7.params() as f64 / 1e9;
        assert!((6.4..=7.0).contains(&p7), "7B params {p7}");
        let m13 = ModelConfig::llama2_13b();
        let p13 = m13.params() as f64 / 1e9;
        assert!((12.7..=13.3).contains(&p13), "13B params {p13}");
        let tm = ModelConfig::tinymistral_248m();
        let ptm = tm.params() as f64 / 1e6;
        assert!((200.0..=280.0).contains(&ptm), "248M params {ptm}");
    }

    #[test]
    fn weight_bytes_q4_7b() {
        // ~6.6G params × 4.5 bits ≈ 3.7 GB.
        let m = ModelConfig::llama2_7b();
        let gb = m.weight_bytes(QuantLevel::Q4, 32) as f64 / 1e9;
        assert!((3.4..=4.1).contains(&gb), "{gb}");
    }

    #[test]
    fn kv_cache_llama7b_fp16() {
        // Known figure: Llama-2-7B fp16 KV = 512 KB/token
        // (2 × 32 layers × 4096 × 2 bytes).
        let m = ModelConfig::llama2_7b();
        assert_eq!(m.kv_bytes_per_token(16), 524_288);
        // At context 4096 that is 2 GB — same order as Q2 weights,
        // the paper's §II-A observation.
        let ctx_bytes = m.kv_bytes_per_token(16) * 4096;
        assert!(ctx_bytes > m.weight_bytes(QuantLevel::Q2, 32) / 2);
    }

    #[test]
    fn tiles_per_token_7b() {
        let m = ModelConfig::llama2_7b();
        // Per layer: Wq/Wk/Wv/Wo = 4×(4×4) = 64 tiles; gate/up = 2×(4×11)=88;
        // down = 11×4 = 44 → 196; ×32 = 6272; lm_head 4×32=128 → 6400.
        assert_eq!(m.tiles_per_token(), 6400);
    }

    #[test]
    fn layer_exceeds_llc_but_tile_column_fits() {
        // A 7B layer (~120 MB at Q4) exceeds the whole 32 MB LLC — which
        // is why the schedule stages sub-tensor shards: a single tile
        // column (K×1024) of the widest tensor fits the 16 MB ping-pong
        // half at every quantization level.
        let m = ModelConfig::llama2_7b();
        assert!(m.layer_bytes(QuantLevel::Q4, 32) > 32 * 1024 * 1024);
        for level in QuantLevel::ALL {
            let col_bytes =
                (m.ffn as f64 * 1024.0 * level.bits_per_weight(32) / 8.0) as u64;
            assert!(col_bytes < 16 * 1024 * 1024, "{level}: {col_bytes}");
        }
    }

    #[test]
    fn tiny_model_shapes_are_group_aligned() {
        let m = ModelConfig::tiny_e2e();
        for (k, n) in m.layer_matrices() {
            assert_eq!(k % 32, 0, "K {k} not group-aligned");
            assert_eq!(n % 32, 0, "N {n} not group-aligned");
        }
        assert_eq!(m.head_dim(), 32);
    }
}
